"""Public surface of the flight-recorder tracing layer (service-layer
name; see :mod:`repro.tracing` for the implementation and design notes).

The implementation lives at the top of the ``repro`` namespace because
``repro.core`` modules (privacy_engine, orchestrator, cohort_engine)
instrument their hot paths with it: importing ``repro.fl.tracing`` from
core would run ``repro/fl/__init__.py`` mid-import of the very core
modules the service layer is built on (a hard cycle). ``repro.tracing``
is stdlib-only, so ANY layer may import it first.

All state is module-global in ``repro.tracing`` and every name here is a
re-export, so ``fl.tracing.set_tracer(...)`` and ``repro.tracing
.get_tracer()`` observe the same tracer.
"""
from repro.tracing import (FlightRecorder, NullTracer, Span, Tracer,
                           enabled, get_tracer, jit_cache_sizes,
                           jit_cache_total, perfetto_from_flight,
                           register_jit, round_event, set_tracer, span,
                           stage_list, use_tracer)

__all__ = [
    "FlightRecorder", "NullTracer", "Span", "Tracer", "enabled",
    "get_tracer", "jit_cache_sizes", "jit_cache_total",
    "perfetto_from_flight", "register_jit", "round_event", "set_tracer",
    "span", "stage_list", "use_tracer",
]
