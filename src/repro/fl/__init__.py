"""Florida service layer: Management/Selection/Authentication services,
client SDK (paper Fig. 3 API), and the multi-client simulator."""
from repro.fl.auth import AttestationAuthority, AuthenticationService
from repro.fl.client import (ConsoleLogger, FederatedLearningClient,
                             NullLogger, WorkflowDetails,
                             load_model_snapshot)
from repro.fl.population import (DEFAULT_TIERS, DeviceProfile, DeviceTier,
                                 PopulationConfig, make_population_clients,
                                 population_summary, sample_population)
from repro.fl.selection import SelectionService
from repro.fl.server import ManagementService
from repro.fl.simulator import (SimClient, SimResult,
                                make_heterogeneous_clients,
                                run_async_simulation, run_sync_simulation)
from repro.fl.task import (SelectionCriteria, TaskConfig, TaskRecord,
                           TaskStatus)
from repro.fl.telemetry import MetricsStore
