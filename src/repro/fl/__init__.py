"""Florida service layer: Management/Selection/Authentication services,
client SDK (paper Fig. 3 API), the multi-tenant control plane (device
directory, round scheduler, model registry), and the multi-client
simulator."""
from repro.fl.auth import AttestationAuthority, AuthenticationService
from repro.fl.client import (ConsoleLogger, FederatedLearningClient,
                             NullLogger, WorkflowDetails,
                             load_model_snapshot)
from repro.fl.directory import DeviceDirectory, DeviceEntry, LeaseConflict
from repro.fl.population import (DEFAULT_TIERS, DeviceProfile, DeviceTier,
                                 PopulationArrays, PopulationConfig,
                                 client_id, client_ids, enroll_fleet,
                                 make_population_clients,
                                 population_summary, sample_population)
from repro.fl.registry import ModelRegistry, RegistryEntry
from repro.fl.scheduler import ControlPlane, RoundGrant
from repro.fl.selection import SelectionService
from repro.fl.server import ManagementService
from repro.fl.simulator import (MultiTaskResult, SimClient, SimResult,
                                make_heterogeneous_clients,
                                run_async_simulation,
                                run_multi_task_simulation,
                                run_sync_simulation)
from repro.fl.task import (SelectionCriteria, TaskConfig, TaskRecord,
                           TaskStatus)
from repro.fl.telemetry import MetricsStore
