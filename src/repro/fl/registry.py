"""Model registry: the durable output of a completed task.

The paper's FLaaS loop ends with the service handing the tenant a trained
model, not a live python object inside a simulator process. When the
control plane completes a task (``n_rounds`` reached, target metric hit,
or epsilon budget exhausted), the ``ManagementService`` publishes a
:class:`RegistryEntry` here: the final global model (as a
``checkpoint.serialize_pytree`` blob — framework-portable npz bytes), a
JSON-able summary of the task config, the full round history, the
realized privacy cost (epsilon at the ACTUAL participation rates, from
the per-task ``RdpAccountant``), and the stop reason.

Persistence reuses the checkpoint module's format: ``save(dir)`` writes
one ``task_<id>.json`` (metadata) + ``task_<id>.model.npz`` (the pytree
blob, byte-for-byte the ``serialize_pytree`` output) per entry, and
``load(dir)`` round-trips them, so a registry survives the process and a
fresh service can serve models it never trained.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, fields as dc_fields
from typing import Any, Optional

from repro.checkpoint import deserialize_pytree, serialize_pytree


def _config_summary(cfg) -> dict:
    """The JSON-able scalars of a TaskConfig (callables, nested configs
    and pytrees are summarized or skipped — the registry stores what a
    tenant needs to identify the artifact, not a pickle)."""
    out = {}
    for f in dc_fields(cfg):
        v = getattr(cfg, f.name)
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[f.name] = v
        elif isinstance(v, (tuple, list)) and all(
                isinstance(x, (str, int, float, bool)) for x in v):
            out[f.name] = list(v)
    dp = getattr(cfg, "dp", None)
    if dp is not None:
        out["dp"] = {"mechanism": dp.mechanism, "clip_norm": dp.clip_norm,
                     "noise_multiplier": dp.noise_multiplier,
                     "delta": dp.delta}
    sa = getattr(cfg, "secure_agg", None)
    if sa is not None:
        out["secure_agg"] = {"bits": sa.bits, "clip": sa.clip,
                             "min_survivors_per_vg":
                                 getattr(sa, "min_survivors_per_vg", 1)}
    return out


@dataclass
class RegistryEntry:
    task_id: int
    task_name: str
    stop_reason: str
    rounds_run: int
    epsilon: Optional[float]
    config: dict                       # JSON-able TaskConfig summary
    history: list                      # per-round metric dicts
    model_blob: bytes                  # serialize_pytree output
    published_at: float = field(default_factory=time.time)

    def model(self, like: Any = None):
        """The final global model pytree (``like`` restores structure and
        dtypes, exactly as ``checkpoint.deserialize_pytree``)."""
        return deserialize_pytree(self.model_blob, like=like)


class ModelRegistry:
    def __init__(self):
        self._entries: dict[int, RegistryEntry] = {}

    def publish(self, rec, epsilon: Optional[float] = None) -> RegistryEntry:
        """Publish a completed TaskRecord. Re-publishing a task_id
        overwrites (idempotent completion)."""
        entry = RegistryEntry(
            task_id=rec.task_id,
            task_name=rec.config.task_name,
            stop_reason=rec.stop_reason or "n_rounds",
            rounds_run=rec.round_idx,
            epsilon=None if epsilon is None else float(epsilon),
            config=_config_summary(rec.config),
            history=[dict(h) for h in rec.history],
            model_blob=serialize_pytree(rec.model))
        self._entries[rec.task_id] = entry
        return entry

    def get(self, task_id: int) -> RegistryEntry:
        return self._entries[task_id]

    def entries(self) -> list:
        return [self._entries[t] for t in sorted(self._entries)]

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, task_id: int) -> bool:
        return task_id in self._entries

    # -- persistence (checkpoint-module format) ---------------------------
    def save(self, dir_path: str) -> list:
        """Write every entry under ``dir_path``; returns written paths."""
        os.makedirs(dir_path, exist_ok=True)
        written = []
        for entry in self.entries():
            stem = os.path.join(dir_path, f"task_{entry.task_id}")
            blob_path = stem + ".model.npz"
            with open(blob_path, "wb") as f:
                f.write(entry.model_blob)
            meta = {k: getattr(entry, k) for k in
                    ("task_id", "task_name", "stop_reason", "rounds_run",
                     "epsilon", "config", "history", "published_at")}
            meta["model_file"] = os.path.basename(blob_path)
            meta_path = stem + ".json"
            with open(meta_path, "w") as f:
                json.dump(meta, f, indent=1, default=float)
            written += [meta_path, blob_path]
        return written

    @classmethod
    def load(cls, dir_path: str) -> "ModelRegistry":
        reg = cls()
        for name in sorted(os.listdir(dir_path)):
            if not (name.startswith("task_") and name.endswith(".json")):
                continue
            with open(os.path.join(dir_path, name)) as f:
                meta = json.load(f)
            with open(os.path.join(dir_path, meta.pop("model_file")),
                      "rb") as f:
                blob = f.read()
            reg._entries[meta["task_id"]] = RegistryEntry(model_blob=blob,
                                                          **meta)
        return reg
