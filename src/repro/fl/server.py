"""Management Service (paper §3.1.1): the user-interface API (create /
manage / monitor tasks) and the task orchestrator (advertise to Selection,
drive Secure/Master aggregation, track progress).

Task state is an in-process store (production: Redis); the aggregation math
is ``repro.core`` — this layer only sequences it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro import tracing
from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core.orchestrator import (AsyncServer, ClientResult,
                                     run_sync_round, run_sync_round_stacked)
from repro.core.secure_agg import AggregationRefused
from repro.core.strategies import FedBuff, make_strategy
from repro.fl.auth import AuthenticationService
from repro.fl.directory import DeviceDirectory
from repro.fl.registry import ModelRegistry
from repro.fl.selection import SelectionService
from repro.fl.task import TaskConfig, TaskRecord, TaskStatus
from repro.fl.telemetry import MetricsRegistry, MetricsStore
from repro.checkpoint import deserialize_pytree, serialize_pytree


class PermissionError_(Exception):
    pass


def _churn_metrics(info) -> dict:
    """Per-round churn telemetry from a RoundInfo: selection/survival/
    dropout counts always, mask-recovery wall time when anyone dropped."""
    out = {"n_selected": info.n_selected, "n_survived": info.n_participants,
           "n_dropped": info.n_dropped}
    if info.n_dropped:
        out["recovery_s"] = info.recovery_s
    if info.upload_bytes:
        out["upload_bytes_per_client"] = info.upload_bytes
    return out


def _model_size(model) -> int:
    """Flat coordinate count of a model pytree (the compressor's domain)."""
    import numpy as np
    return int(sum(int(np.prod(jnp.shape(leaf)) or 1)
                   for leaf in jax.tree.leaves(model)))


@dataclass
class _RoundCollector:
    round_idx: int
    cohort: list
    results: dict = field(default_factory=dict)
    dropped: set = field(default_factory=set)

    def complete(self):
        """Every cohort member accounted for — submitted OR dropped. A
        straggling VG no longer blocks the round: once the stragglers are
        reported dropped, aggregation proceeds over the survivors with
        mask recovery."""
        return set(self.results) | self.dropped >= set(self.cohort)


class ManagementService:
    def __init__(self, seed: int = 0,
                 directory: DeviceDirectory | None = None):
        self.auth = AuthenticationService()
        # the shared physical fleet; inject one directory into several
        # services (or, normally, several tasks into ONE service under a
        # ControlPlane) to make leases mutually exclusive across tasks
        self.directory = directory if directory is not None \
            else DeviceDirectory()
        self.selection = SelectionService(self.auth, seed=seed,
                                          directory=self.directory)
        self.metrics = MetricsStore()
        self.meters = MetricsRegistry()
        # flight recorder (per-task JSONL round transcripts); None = off.
        # The CLI run path installs one next to the session file
        self.flight: tracing.FlightRecorder | None = None
        # jit-cache watermark for the per-round jit_cache_misses delta
        self._jit_snapshot = tracing.jit_cache_total()
        self.registry = ModelRegistry()
        self._tasks: dict[int, TaskRecord] = {}
        self._strategies: dict[int, Any] = {}
        self._strategy_state: dict[int, Any] = {}
        self._collectors: dict[int, _RoundCollector] = {}
        self._async: dict[int, AsyncServer] = {}
        self._accountants: dict[int, dp_mod.RdpAccountant] = {}
        # task_id -> TopKCompressor (tasks with compression.kind != "none");
        # holds the per-client error-feedback residuals, so it must live as
        # long as the task
        self._compressors: dict[int, Any] = {}

    # ------------------------------------------------------------------
    # user-interface API (dashboard / CLI)
    # ------------------------------------------------------------------

    def create_task(self, config: TaskConfig, initial_model,
                    user: str = "default-user", deploy: bool = True) -> int:
        """Create a task: CREATED, then (by default) deployed to RUNNING.

        ``deploy=False`` leaves the task in CREATED — the control-plane
        lifecycle, where :meth:`deploy_task` is the explicit transition to
        RUNNING (the ``ControlPlane`` creates tasks this way). The default
        keeps the one-call convenience path for single-task use.

        The task id is derived from the service's own task store (max +
        1), NOT the module-global counter in ``fl.task`` — that counter
        resets in every fresh process, so a CLI session reloaded from disk
        would mint ids that silently overwrite persisted tasks."""
        config.owner = user
        rec = TaskRecord(config=config, model=initial_model,
                         task_id=max(self._tasks, default=0) + 1)
        self._tasks[rec.task_id] = rec
        kw = dict(config.strategy_kwargs)
        if config.mode == "async":
            strategy = FedBuff(buffer_size=config.buffer_size, **kw)
            self._async[rec.task_id] = AsyncServer(
                initial_model, strategy, config.dp)
        else:
            strategy = make_strategy(config.strategy, **kw)
            self._strategy_state[rec.task_id] = strategy.init_state(
                initial_model)
        self._strategies[rec.task_id] = strategy
        comp = config.compression.make_compressor(_model_size(initial_model))
        if comp is not None:
            self._compressors[rec.task_id] = comp
        if config.dp.mechanism != "off":
            self._accountants[rec.task_id] = dp_mod.RdpAccountant(
                config.dp, sample_rate=1.0)  # rate set per round below
        if deploy:
            self.deploy_task(rec.task_id, user=user)
        return rec.task_id

    def deploy_task(self, task_id: int, user: str = "default-user"):
        """CREATED -> RUNNING. The explicit lifecycle step between task
        definition and the scheduler granting it rounds."""
        self._check_perm(task_id, user)
        rec = self._tasks[task_id]
        if rec.status is not TaskStatus.CREATED:
            raise ValueError(f"task {task_id} is {rec.status.value}, "
                             "only CREATED tasks can be deployed")
        rec.status = TaskStatus.RUNNING

    def get_task(self, task_id: int) -> TaskRecord:
        return self._tasks[task_id]

    def list_tasks(self, app_name=None, workflow_name=None):
        tasks = list(self._tasks.values())
        if app_name is not None:
            tasks = [t for t in tasks if t.config.app_name == app_name]
        if workflow_name is not None:
            tasks = [t for t in tasks
                     if t.config.workflow_name == workflow_name]
        return tasks

    def _check_perm(self, task_id: int, user: str):
        if not self._tasks[task_id].can_manage(user):
            raise PermissionError_(f"user {user!r} cannot manage {task_id}")

    def _abort_round(self, task_id: int):
        """Discard any in-flight round: drop the collector and release
        every device lease so other tasks can select them immediately —
        pausing/cancelling one task must never pin fleet capacity."""
        rec = self._tasks[task_id]
        self._collectors.pop(task_id, None)
        self.selection.reset_round(rec)

    def pause_task(self, task_id: int, user="default-user"):
        self._check_perm(task_id, user)
        self._tasks[task_id].status = TaskStatus.PAUSED
        self._abort_round(task_id)   # round is re-selected on resume

    def resume_task(self, task_id: int, user="default-user"):
        self._check_perm(task_id, user)
        self._tasks[task_id].status = TaskStatus.RUNNING

    def cancel_task(self, task_id: int, user="default-user"):
        self._check_perm(task_id, user)
        self._tasks[task_id].status = TaskStatus.CANCELLED
        self._abort_round(task_id)

    def epsilon(self, task_id: int):
        acc = self._accountants.get(task_id)
        return acc.epsilon() if acc else None

    # ------------------------------------------------------------------
    # client-facing API (via the SDK)
    # ------------------------------------------------------------------

    def register_client(self, task_id: int, client_id: str, device_info: dict,
                        certificate=None, profile=None) -> bool:
        """``profile``: optional ``population.DeviceProfile`` recorded in
        the shared device directory (availability windows, dropout hazard
        — physical facts, shared by every task the device enrolls in)."""
        return self.selection.register(self._tasks[task_id], client_id,
                                       device_info, certificate,
                                       profile=profile)

    def register_fleet(self, task_id: int, population,
                       device_info: dict | None = None) -> int:
        """Bulk-enroll a ``population.PopulationArrays`` fleet into a task
        — the 10^6-device registration path (criteria evaluated once
        against the shared ``device_info`` template; see
        ``SelectionService.register_fleet``). Returns the enrolled count."""
        return self.selection.register_fleet(self._tasks[task_id],
                                             population,
                                             device_info=device_info)

    def model_snapshot(self, task_id: int) -> bytes:
        return serialize_pytree(self._tasks[task_id].model)

    def submit_update(self, task_id: int, client_id: str, update,
                      n_samples: int, metrics=None,
                      update_version: int | None = None) -> bool:
        """Returns True if this submission completed a server step.

        ``update_version``: the model version the client's update was
        trained FROM (async mode) — FedBuff discounts by the staleness
        ``round_idx - update_version``. Omitted => assumed current (no
        discount), which is only right for clients that fetched the
        snapshot just before training.
        """
        rec = self._tasks[task_id]
        if rec.status is not TaskStatus.RUNNING:
            return False
        result = ClientResult(update=update, n_samples=n_samples,
                              metrics=metrics or {})
        if rec.config.mode == "async":
            server = self._async[task_id]
            comp = self._compressors.get(task_id)
            if comp is not None:
                # trusted aggregation boundary (no masks): true per-client
                # top-k — the wire carries (indices, values), the buffer
                # gets the dense scatter (its math is support-agnostic)
                import numpy as np
                from repro.core import raveling
                _, _, dense = comp.compress_topk(
                    client_id, np.asarray(raveling.flat_f32(update)))
                result = ClientResult(update=jnp.asarray(dense),
                                      n_samples=n_samples,
                                      metrics=metrics or {})
            stepped = server.submit(
                result,
                update_version=rec.round_idx if update_version is None
                else update_version)
            if stepped:
                rec.model = server.params
                rec.round_idx += 1
                self._finish_round(rec, self._async_metrics(rec, server))
            return stepped
        coll = self._collectors.get(task_id)
        if coll is None or client_id not in coll.cohort \
                or client_id in coll.dropped:
            return False
        coll.results[client_id] = result
        self.selection.mark(rec, client_id, "done")   # lifecycle: submitted
        if coll.complete():
            self._run_sync_aggregation(rec, coll)
            return True
        return False

    def report_dropout(self, task_id: int, client_id: str) -> bool:
        """A selected client disconnected (or blew the round deadline)
        mid-round. Its virtual group's pairwise masks no longer cancel;
        the round proceeds anyway — aggregation runs over the survivors
        with the dropped residual recovered (``repro.core.dropout``).
        Returns True if this report CLOSED the round: aggregated over the
        survivors, or voided it (every member dropped — the round index is
        not consumed and the next ``begin_round`` re-selects)."""
        rec = self._tasks[task_id]
        coll = self._collectors.get(task_id)
        if coll is None or client_id not in coll.cohort \
                or client_id in coll.dropped or client_id in coll.results:
            return False
        coll.dropped.add(client_id)
        self.selection.drop(rec, client_id)
        if coll.complete():
            if coll.results:
                self._run_sync_aggregation(rec, coll)
            else:
                # every member dropped: void the round (no survivors to
                # aggregate); dropped members re-enter the pool at the
                # next begin_round
                self._void_round(rec, coll)
            return True
        return False

    def backfill_round(self, task_id: int, unavailable, available=None
                       ) -> list:
        """Pre-training cohort repair: ``unavailable`` members (selected
        but outside their availability window before training started)
        are RELEASED — they never entered the protocol, so no masks to
        recover and no dropout on their record — and replacements are
        drawn from the pool, topping the cohort back up toward its
        selection target. Returns the repaired cohort list. Must run
        before any member submits (the VG plan spans the final cohort)."""
        rec = self._tasks[task_id]
        coll = self._collectors.get(task_id)
        if coll is None:
            return []
        if coll.results or coll.dropped:
            raise ValueError("backfill_round must run before training "
                             "starts (submissions or dropouts already "
                             "recorded)")
        unavailable = [c for c in unavailable if c in coll.cohort]
        with tracing.span("backfill", task=task_id,
                          n_released=len(unavailable)) as sp:
            released = set(unavailable)
            for cid in unavailable:
                self.selection.release(rec, cid)
            cohort = [c for c in coll.cohort if c not in released]
            # the released members are back in the pool but must not be
            # drawn straight back into the cohort they were just removed
            # from
            refill = self.selection.backfill(
                rec, len(coll.cohort) - len(cohort),
                available=lambda cid: cid not in released
                and (available is None or available(cid)))
            sp.set(n_refilled=len(refill))
        coll.cohort = sorted(cohort + refill)
        return list(coll.cohort)

    def submit_cohort(self, task_id: int, client_ids, stacked_updates,
                      n_samples: int, metrics_list=None) -> bool:
        """Bulk sync submission — the fused fast path: the WHOLE cohort's
        updates arrive stacked along the client axis (pytree leaves
        (n_clients, ...)), straight from ``CohortEngine.run_cohort_
        stacked``, and flow into the vectorized privacy pipeline without
        ever being unstacked to per-client host copies. Completes the
        round; returns True iff the round ran.

        ``n_samples`` (per client) is telemetry only: the secure aggregate
        is the privacy-preserving UNIFORM mean on both the bulk and
        per-client paths (sample-weighting would leak per-client counts
        through the aggregate).

        With churn, ``client_ids``/``stacked_updates`` hold the round's
        SURVIVORS; every other cohort member must already be reported via
        :meth:`report_dropout` — the VG plan spans the full cohort and the
        dropped residual is recovered."""
        rec = self._tasks[task_id]
        if rec.status is not TaskStatus.RUNNING or rec.config.mode == "async":
            return False
        coll = self._collectors.get(task_id)
        cids = list(client_ids)
        if coll is None or len(set(cids)) != len(cids) \
                or set(cids) != set(coll.cohort) - coll.dropped \
                or not cids:
            return False
        strategy = self._strategies[task_id]
        state = self._strategy_state[task_id]
        metrics_list = metrics_list or [{} for _ in cids]
        try:
            with tracing.span("aggregate", task=task_id,
                              round=coll.round_idx) as agg_sp:
                rec.model, state, info = run_sync_round_stacked(
                    rec.model, strategy, state, cids, stacked_updates,
                    metrics_list,
                    round_idx=coll.round_idx, vg_size=rec.config.vg_size,
                    secure_cfg=rec.config.secure_agg,
                    dp_cfg=rec.config.dp,
                    cohort=list(coll.cohort) if coll.dropped else None,
                    compressor=self._compressors.get(task_id))
        except AggregationRefused:
            self._void_round(rec, coll, reason="aggregation_refused")
            return True
        self._strategy_state[task_id] = state
        for cid in cids:
            self.selection.mark(rec, cid, "done")
        # the round is closed — drop the collector so a straggling
        # per-client submit_update cannot re-trigger aggregation
        self._collectors.pop(task_id, None)
        rec.round_idx += 1
        self._record_flight(rec, coll, info, agg_sp, survivors=cids)
        self._finish_round(rec, dict(info.metrics, n=info.n_participants,
                                     n_groups=info.n_groups,
                                     n_shards=info.n_shards,
                                     n_samples_per_client=n_samples,
                                     stage2_route=info.stage2_route,
                                     **_churn_metrics(info)))
        return True

    def submit_updates_async(self, task_id: int, client_ids,
                             stacked_updates, n_samples, update_versions,
                             metrics_list=None) -> list:
        """Bulk async submission — the fused fast path mirroring
        ``submit_cohort``: a whole event group's updates arrive stacked
        along the client axis (pytree leaves (k, ...)) straight from
        ``CohortEngine.run_cohort_personalized_stacked``, are raveled on
        device, run through the batched local-DP rows, and land in the
        FedBuff device buffer with one write per buffer segment — no
        unstack-to-host, no per-client submit round trips. Bit-identical
        to k ``submit_update`` calls in the same order.

        ``n_samples``: per-row list (or one int for all rows);
        ``update_versions``: per-row model versions the updates were
        trained FROM. ``metrics_list`` is accepted for API symmetry with
        ``submit_cohort`` — async aggregation is metrics-blind, exactly
        like the per-client path. Returns the batch row indices that
        completed a server step ([] if none, or if the task is not an
        async RUNNING task)."""
        rec = self._tasks[task_id]
        if rec.status is not TaskStatus.RUNNING \
                or rec.config.mode != "async":
            return []
        server = self._async[task_id]
        cids = list(client_ids)
        rows = pe.ravel_rows(stacked_updates)
        comp = self._compressors.get(task_id)
        if comp is not None and rows.shape[0] == len(cids):
            # compress in submission order so the residual evolution is
            # bit-identical to len(cids) per-client submit_update calls
            import numpy as np
            host = np.asarray(rows, np.float32)
            rows = jnp.asarray(np.stack(
                [comp.compress_topk(cid, host[j])[2]
                 for j, cid in enumerate(cids)]))
        if rows.shape[0] != len(cids):
            # a shape/id mismatch is a caller bug, not a rejected
            # submission — dropping the group silently would corrupt the
            # run (the sync twin escalates the same way via the
            # simulator's RuntimeError guard)
            raise ValueError(
                f"stacked updates have {rows.shape[0]} rows for "
                f"{len(cids)} client ids")
        k = len(cids)
        weights = [float(n) for n in (n_samples if isinstance(
            n_samples, (list, tuple)) else [n_samples] * k)]
        versions = [int(v) for v in update_versions]
        # serial parity at the completion boundary: the per-client loop
        # rejects every submission after the task COMPLETES, so cap the
        # batch at the rows that fit before the final server step
        steps_left = rec.config.n_rounds - rec.round_idx
        cap = (server.strategy.room()
               + (steps_left - 1) * server.strategy.buffer_size)
        if k > cap:
            rows, weights, versions = rows[:cap], weights[:cap], \
                versions[:cap]
        steps = server.submit_batch(rows, weights, versions)
        for _ in steps:
            rec.model = server.params
            rec.round_idx += 1
            self._finish_round(rec, self._async_metrics(rec, server))
        return steps

    def _async_metrics(self, rec: TaskRecord, server: AsyncServer) -> dict:
        out = {"n": server.strategy.buffer_size}
        comp = self._compressors.get(rec.task_id)
        if comp is not None:
            out["upload_bytes_per_client"] = comp.payload_bytes(
                with_indices=True)
        return out

    def async_buffer_room(self, task_id: int) -> int:
        """Submissions until the next async server step (>= 1). Sync tasks
        report 1 (every cohort submission may complete the round)."""
        server = self._async.get(task_id)
        if server is None:
            return 1
        return max(1, server.strategy.room())

    # ------------------------------------------------------------------
    # orchestration
    # ------------------------------------------------------------------

    def begin_round(self, task_id: int, available=None):
        """Select the cohort for the next round -> (round_idx, cohort).

        Over-provisions by ``config.overprovision`` and records
        ``config.round_timeout_s`` as the round deadline; ``available`` is
        an optional ``cid -> bool`` availability predicate (device windows
        at selection time)."""
        rec = self._tasks[task_id]
        if rec.status is not TaskStatus.RUNNING:
            return rec.round_idx, []
        self.selection.reset_round(rec)   # last round's selected/done/dropped
        with tracing.span("selection", task=task_id,
                          round=rec.round_idx) as sp:
            cohort = self.selection.select_cohort(
                rec, overprovision=rec.config.overprovision,
                deadline=rec.config.round_timeout_s, available=available)
            sp.set(n_cohort=len(cohort))
        self._collectors[task_id] = _RoundCollector(rec.round_idx, cohort)
        return rec.round_idx, cohort

    def _void_round(self, rec: TaskRecord, coll: _RoundCollector,
                    reason: str = "all_dropped"):
        """Close the round WITHOUT aggregating: either nobody survived, or
        secure aggregation REFUSED the survivor set (every virtual group
        fell below ``min_survivors_per_vg`` — releasing such an aggregate
        would hand bare updates to the aggregator). The round index is not
        consumed; the next ``begin_round`` re-selects."""
        self._collectors.pop(rec.task_id, None)
        self.meters.counter("rounds_voided", task=rec.task_id).inc()
        if self.flight is not None:
            self.flight.record(rec.task_id, tracing.round_event(
                round_idx=rec.round_idx, cohort=list(coll.cohort),
                survivors=sorted(coll.results), voided=True,
                void_reason=reason))
        self.metrics.log(rec.task_id, rec.round_idx, round_voided=1,
                         n_selected=len(coll.cohort),
                         n_survived=len(coll.results),
                         n_dropped=len(coll.dropped))

    def _run_sync_aggregation(self, rec: TaskRecord, coll: _RoundCollector):
        strategy = self._strategies[rec.task_id]
        state = self._strategy_state[rec.task_id]
        try:
            with tracing.span("aggregate", task=rec.task_id,
                              round=coll.round_idx) as agg_sp:
                rec.model, state, info = run_sync_round(
                    rec.model, strategy, state, coll.results,
                    round_idx=coll.round_idx, vg_size=rec.config.vg_size,
                    secure_cfg=rec.config.secure_agg,
                    dp_cfg=rec.config.dp,
                    cohort=list(coll.cohort) if coll.dropped else None,
                    compressor=self._compressors.get(rec.task_id))
        except AggregationRefused:
            self._void_round(rec, coll, reason="aggregation_refused")
            return
        self._strategy_state[rec.task_id] = state
        # the round is closed — drop the collector so a straggling retry
        # (a late duplicate submit after a dropout report completed the
        # round) cannot re-trigger the aggregation
        self._collectors.pop(rec.task_id, None)
        rec.round_idx += 1
        self._record_flight(rec, coll, info, agg_sp,
                            survivors=sorted(coll.results))
        self._finish_round(rec, dict(info.metrics, n=info.n_participants,
                                     n_groups=info.n_groups,
                                     n_shards=info.n_shards,
                                     stage2_route=info.stage2_route,
                                     **_churn_metrics(info)))

    def _record_flight(self, rec: TaskRecord, coll: _RoundCollector,
                       info, span_tree, *, survivors):
        """Append the closed round's transcript event (cohort, survivors,
        stage timings from the aggregate span subtree, stage2 route) to
        the task's flight-recorder JSONL, when a recorder is installed."""
        if self.flight is None:
            return
        self.flight.record(rec.task_id, tracing.round_event(
            round_idx=info.round_idx, cohort=list(coll.cohort),
            survivors=list(survivors), n_shards=info.n_shards,
            stage2_route=info.stage2_route, span_tree=span_tree,
            metrics=_churn_metrics(info)))

    def _finish_round(self, rec: TaskRecord, metrics: dict):
        rec.history.append({"round": rec.round_idx, **metrics})
        self.metrics.log(rec.task_id, rec.round_idx, **metrics)
        tid = rec.task_id
        self.meters.counter("rounds_completed", task=tid).inc()
        # shape-contract probe: new compiled executables since the last
        # finished round across the shared jitted entry points
        cur = tracing.jit_cache_total()
        self.meters.counter("jit_cache_misses").inc(
            max(0, cur - self._jit_snapshot))
        self._jit_snapshot = cur
        if "upload_bytes_per_client" in metrics:
            self.meters.histogram("upload_bytes_per_client", task=tid) \
                .observe(metrics["upload_bytes_per_client"])
        if "recovery_s" in metrics:
            self.meters.histogram("recovery_s", task=tid) \
                .observe(metrics["recovery_s"])
        if self.flight is not None and rec.config.mode == "async":
            # async rounds close inside _finish_round (no collector /
            # aggregate span to lift a transcript from)
            self.flight.record(tid, {
                "event": "server_step", "round": rec.round_idx,
                "metrics": {k: v for k, v in metrics.items()
                            if isinstance(v, (int, float, str))}})
        acc = self._accountants.get(rec.task_id)
        if acc is not None:
            pool = max(1, len(self.selection.registered(rec)))
            # mode-correct sample rate: an async server step composes over
            # the buffer_size clients that filled the FedBuff buffer; a
            # sync round over the clients whose data actually entered the
            # aggregate — the REALIZED participation ("n" = survivors),
            # not clients_per_round, which over-provisioned cohorts exceed
            # (using the config target would under-report epsilon)
            per_step = (rec.config.buffer_size
                        if rec.config.mode == "async"
                        else metrics.get("n", rec.config.clients_per_round))
            acc.q = min(1.0, per_step / pool)
            acc.step()
            eps = self.epsilon(rec.task_id)
            if eps is not None:
                self.meters.gauge("epsilon_spent", task=tid).set(eps)
        self.check_stop(rec.task_id)

    def check_stop(self, task_id: int):
        """Evaluate the task's stop criteria; on the first one met,
        COMPLETE the task, record the reason and publish the final model
        (+ config, history, realized epsilon) to the model registry.
        Returns the stop reason, or None while still running. Called after
        every round; simulators may also call it after logging eval
        metrics (a ``target_metric`` may be an eval-time series)."""
        rec = self._tasks[task_id]
        if rec.status is TaskStatus.COMPLETED:
            return rec.stop_reason
        if rec.status is not TaskStatus.RUNNING:
            return None
        cfg = rec.config
        reason = None
        if rec.round_idx >= cfg.n_rounds:
            reason = "n_rounds"
        if reason is None and cfg.epsilon_budget is not None:
            eps = self.epsilon(task_id)
            if eps is not None and eps >= cfg.epsilon_budget:
                reason = "epsilon_budget"
        if reason is None and cfg.target_metric is not None \
                and cfg.target_value is not None:
            v = self.metrics.latest(task_id, cfg.target_metric)
            if v is not None and (v >= cfg.target_value
                                  if cfg.target_mode == "max"
                                  else v <= cfg.target_value):
                reason = "target_metric"
        if reason is None:
            return None
        rec.status = TaskStatus.COMPLETED
        rec.stop_reason = reason
        # free any leftover leases/round state: a completed task must not
        # pin devices other tasks could use
        self._abort_round(task_id)
        self.registry.publish(rec, epsilon=self.epsilon(task_id))
        return reason
