"""Text renderings of the Florida dashboard views (paper Figs. 6-9):
task management list, task view with round history, and metric plots
(unicode sparkline charts standing in for the web UI)."""
from __future__ import annotations

BLOCKS = " ▁▂▃▄▅▆▇█"


def sparkline(values, width=48):
    if not values:
        return "(no data)"
    vals = values[-width:]
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    return "".join(BLOCKS[1 + int((v - lo) / rng * (len(BLOCKS) - 2))]
                   for v in vals)


def render_task_list(svc) -> str:
    # round column is "rrr/nnn": 3 digits a side keeps the columns
    # aligned past round 99 (the old 2-digit field drifted)
    rows = [f"{'id':>4} {'task':<18} {'status':<10} {'mode':<6} "
            f"{'round':>7} {'clients':>7}"]
    rows.append("-" * len(rows[0]))
    for t in svc.list_tasks():
        registered = len(svc.selection.registered(t))
        rows.append(f"{t.task_id:>4} {t.config.task_name:<18} "
                    f"{t.status.value:<10} {t.config.mode:<6} "
                    f"{t.round_idx:>3}/{t.config.n_rounds:<3} "
                    f"{registered:>7}")
    return "\n".join(rows)


def render_task_view(svc, task_id: int) -> str:
    t = svc.get_task(task_id)
    c = t.config
    lines = [
        f"Task {t.task_id}: {c.task_name}   [{t.status.value}]",
        f"  app={c.app_name} workflow={c.workflow_name} mode={c.mode}",
        f"  rounds: {t.round_idx}/{c.n_rounds}   "
        f"clients/round: {c.clients_per_round}   vg_size: {c.vg_size}",
        f"  strategy: {c.strategy}   dp: {c.dp.mechanism}"
        + (f" (clip={c.dp.clip_norm}, z={c.dp.noise_multiplier})"
           if c.dp.mechanism != "off" else ""),
    ]
    eps = svc.epsilon(task_id)
    if eps is not None:
        lines.append(f"  privacy spent: epsilon={eps:.2f} "
                     f"at delta={c.dp.delta}")
    churn = svc.metrics.churn_summary(task_id)
    if churn["dropped"] or churn["rounds_voided"] \
            or c.overprovision > 1.0:
        lines.append(
            f"  churn: selected={churn['selected']} "
            f"survived={churn['survived']} dropped={churn['dropped']} "
            f"({churn['dropout_rate']:.1%}) "
            f"recovery={churn['recovery_s'] * 1e3:.1f}ms"
            + (f" voided_rounds={churn['rounds_voided']}"
               if churn["rounds_voided"] else ""))
    if t.history:
        lines.append("  round history:")
        for h in t.history[-8:]:
            extras = " ".join(f"{k}={v:.4g}" for k, v in h.items()
                              if k != "round" and isinstance(v, float))
            lines.append(f"    round {h['round']:>3}: {extras}")
    return "\n".join(lines)


def render_fleet(plane) -> str:
    """Control-plane view: the shared fleet, per-task scheduling shares
    (priority / weight / lease-seconds), and the model registry."""
    d = plane.directory
    fleet = d.fleet_summary()
    fair = plane.fairness()
    lines = [
        f"fleet: {fleet['devices']} devices, "
        f"{fleet['leased_now']} leased now, "
        f"{fleet['tasks_enrolled']} tasks enrolled",
        f"{'id':>4} {'task':<18} {'status':<10} {'mode':<6} {'prio':>4} "
        f"{'weight':>6} {'lease_s':>9} {'rounds':>6}",
    ]
    lines.append("-" * len(lines[-1]))
    for t in plane.tasks():
        f = fair.get(t.task_id, {})
        lines.append(
            f"{t.task_id:>4} {t.config.task_name:<18} "
            f"{t.status.value:<10} {t.config.mode:<6} "
            f"{f.get('priority', 0):>4} {f.get('weight', 1.0):>6.2f} "
            f"{f.get('lease_seconds', 0.0):>9.2f} "
            f"{f.get('rounds_granted', 0):>6}")
    lines.append(f"registry: {len(plane.registry)} published model(s)"
                 + ("".join(f"\n  task {e.task_id} ({e.task_name}): "
                            f"{e.rounds_run} rounds, stop={e.stop_reason}"
                            + (f", eps={e.epsilon:.2f}"
                               if e.epsilon is not None else "")
                            for e in plane.registry.entries())))
    return "\n".join(lines)


def render_metrics(svc, task_id: int) -> str:
    """Fig. 8/9 analogue: per-metric sparkline series."""
    rows = [f"metrics for task {task_id}:"]
    metrics = sorted({r["metric"] for r in svc.metrics._rows[task_id]})
    if not metrics:
        return rows[0] + " (none)"
    for m in metrics:
        rounds, vals = svc.metrics.series(task_id, m)
        if not vals:
            continue   # non-numeric series (stage2_route etc.)
        rows.append(f"  {m:<18} {sparkline(vals)}  "
                    f"last={vals[-1]:.4g} (n={len(vals)})")
    return "\n".join(rows)


def render_status(svc) -> str:
    """``florida status``: the task list plus the service's typed meter
    registry (counters / gauges / histogram means)."""
    lines = [render_task_list(svc), "", "meters:"]
    snap = svc.meters.snapshot()
    if not snap:
        lines.append("  (none)")
    for row in snap:
        labels = ",".join(f"{k}={v}" for k, v in
                          sorted(row["labels"].items()))
        name = row["name"] + (f"{{{labels}}}" if labels else "")
        if row["kind"] == "histogram":
            lines.append(f"  {name:<40} n={row['count']:<6} "
                         f"mean={row['mean']:.4g}")
        else:
            lines.append(f"  {name:<40} {row['value']:.6g}")
    return "\n".join(lines)


def render_trace(svc, task_id: int, last: int = 8) -> str:
    """``florida trace <task>``: the flight-recorder round transcript —
    per-round stage tree with wall-clock timings."""
    if svc.flight is None:
        return f"task {task_id}: no flight recorder installed"
    events = svc.flight.read(task_id)
    if not events:
        return f"task {task_id}: no flight records"
    lines = [f"flight transcript for task {task_id} "
             f"({len(events)} events, showing last {min(last, len(events))}):"]
    for ev in events[-last:]:
        head = f"round {ev.get('round'):>3} [{ev.get('event')}]"
        parts = [f"cohort={len(ev.get('cohort', []))}",
                 f"survivors={len(ev.get('survivors', []))}"]
        if ev.get("stage2_route"):
            parts.append(f"route={ev['stage2_route']}")
        if ev.get("n_shards"):
            parts.append(f"shards={ev['n_shards']}")
        if ev.get("void_reason"):
            parts.append(f"void={ev['void_reason']}")
        if ev.get("wall_ms") is not None:
            parts.append(f"wall={ev['wall_ms']:.1f}ms")
        lines.append(f"  {head}  " + " ".join(parts))
        for st in ev.get("stages", []):
            fused = " (fused)" if st.get("fused") else ""
            lines.append(f"    {'  ' * st['depth']}{st['name']:<20} "
                         f"{st['dur_ms']:>9.3f}ms{fused}")
    return "\n".join(lines)
