"""FL task definitions (paper §3.3.1): the fields of the task-creation
interface — task/app/workflow names, clients-per-round, rounds, aggregation
logic, privacy config, selection criteria, permissions."""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.dp import DPConfig
from repro.core.secure_agg import SecureAggConfig
from repro.core.sparse import SparseConfig, TopKCompressor, resolve_k


class TaskStatus(enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    PAUSED = "paused"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    FAILED = "failed"


@dataclass
class SelectionCriteria:
    """Device-participation requirements (paper §3.1.4/§3.3.1)."""
    allowed_os: tuple = ("android", "ios", "windows", "linux", "macos")
    min_samples: int = 1
    min_battery: float = 0.2
    require_attestation: bool = True
    custom: Optional[Callable[[dict], bool]] = None

    def matches(self, device_info: dict) -> bool:
        if device_info.get("os", "linux") not in self.allowed_os:
            return False
        if device_info.get("n_samples", 0) < self.min_samples:
            return False
        if device_info.get("battery", 1.0) < self.min_battery:
            return False
        if self.custom and not self.custom(device_info):
            return False
        return True


@dataclass
class CompressionConfig:
    """Update-compression policy for a task (the sub-1%-round knobs).

    ``kind``: "none" | "topk". Top-k rides the round-common shared-index
    draw with error feedback on the sync secure-agg path and true
    per-client top-k (indices + values) on the async trusted path — see
    ``repro.core.sparse``. ``k`` (absolute) wins over ``frac``
    (fraction of the flat update); residuals are carried per client when
    ``error_feedback``.

    LoRA/adapter tuning is NOT a wire transform and composes with (not
    through) this config: make the task's model the adapters pytree
    (``repro.core.lora``) and any ``kind`` here then applies to the
    adapter delta. ``lora_rank`` is recorded so the task registry keeps
    the full recipe; 0 = dense model.
    """
    kind: str = "none"
    k: int = 0
    frac: float = 0.01
    error_feedback: bool = True
    seed: int = 0
    lora_rank: int = 0

    def make_compressor(self, model_size: int):
        """-> ``TopKCompressor`` over a ``model_size``-coordinate flat
        update, or None when compression is off."""
        if self.kind == "none":
            return None
        if self.kind != "topk":
            raise ValueError(f"unknown compression kind {self.kind!r}")
        return TopKCompressor(
            SparseConfig(k=resolve_k(model_size, k=self.k, frac=self.frac),
                         error_feedback=self.error_feedback,
                         seed=self.seed),
            model_size)


@dataclass
class TaskConfig:
    task_name: str
    app_name: str
    workflow_name: str
    clients_per_round: int
    n_rounds: int
    # user-defined master aggregation logic: a strategy name (the paper also
    # accepts a python script / native executable — same role)
    strategy: str = "fedavg"
    strategy_kwargs: dict = field(default_factory=dict)
    mode: str = "sync"                      # sync | async
    buffer_size: int = 32                   # async: FedBuff K
    vg_size: int = 8                        # secure-agg virtual group size
    secure_agg: SecureAggConfig = field(default_factory=SecureAggConfig)
    dp: DPConfig = field(default_factory=DPConfig)
    compression: CompressionConfig = field(
        default_factory=CompressionConfig)
    selection: SelectionCriteria = field(default_factory=SelectionCriteria)
    eval_interval: int = 1
    round_timeout_s: float = 600.0          # sync round deadline: stragglers
                                            # past it are dropped + recovered,
                                            # not waited for
    overprovision: float = 1.0              # select ceil(cpr * this) clients
                                            # so the survivor set still hits
                                            # the target under churn
    permissions: tuple = ()                 # user ids allowed to manage
    owner: str = "default-user"
    # -- stop criteria beyond n_rounds (control-plane lifecycle) --------
    target_metric: Optional[str] = None     # e.g. "eval_accuracy" / "loss"
    target_value: Optional[float] = None    # threshold that completes the task
    target_mode: str = "max"                # "max": metric >= value stops;
                                            # "min": metric <= value stops
    epsilon_budget: Optional[float] = None  # complete when the task's RDP
                                            # accountant reaches this epsilon
    # -- scheduling policy (read by fl.scheduler.ControlPlane) ----------
    priority: int = 0                       # higher tier is granted first
    weight: float = 1.0                     # fair share within a tier
                                            # (lease-seconds are normalized
                                            # by this weight)


# Fallback id source for records built outside a ManagementService. The
# service derives ids from its own task store (max + 1) instead: this
# module-global counter resets in every fresh process, so a reloaded CLI
# session would hand out ids that collide with persisted tasks.
_task_counter = itertools.count(1)


@dataclass
class TaskRecord:
    config: TaskConfig
    model: Any                              # current global model pytree
    task_id: int = field(default_factory=lambda: next(_task_counter))
    status: TaskStatus = TaskStatus.CREATED
    round_idx: int = 0
    created_at: float = field(default_factory=time.time)
    history: list = field(default_factory=list)   # RoundInfo-like dicts
    stop_reason: Optional[str] = None       # why the task COMPLETED:
                                            # n_rounds | target_metric |
                                            # epsilon_budget

    def can_manage(self, user: str) -> bool:
        return user == self.config.owner or user in self.config.permissions
