"""FL task definitions (paper §3.3.1): the fields of the task-creation
interface — task/app/workflow names, clients-per-round, rounds, aggregation
logic, privacy config, selection criteria, permissions."""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.dp import DPConfig
from repro.core.secure_agg import SecureAggConfig


class TaskStatus(enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    PAUSED = "paused"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    FAILED = "failed"


@dataclass
class SelectionCriteria:
    """Device-participation requirements (paper §3.1.4/§3.3.1)."""
    allowed_os: tuple = ("android", "ios", "windows", "linux", "macos")
    min_samples: int = 1
    min_battery: float = 0.2
    require_attestation: bool = True
    custom: Optional[Callable[[dict], bool]] = None

    def matches(self, device_info: dict) -> bool:
        if device_info.get("os", "linux") not in self.allowed_os:
            return False
        if device_info.get("n_samples", 0) < self.min_samples:
            return False
        if device_info.get("battery", 1.0) < self.min_battery:
            return False
        if self.custom and not self.custom(device_info):
            return False
        return True


@dataclass
class TaskConfig:
    task_name: str
    app_name: str
    workflow_name: str
    clients_per_round: int
    n_rounds: int
    # user-defined master aggregation logic: a strategy name (the paper also
    # accepts a python script / native executable — same role)
    strategy: str = "fedavg"
    strategy_kwargs: dict = field(default_factory=dict)
    mode: str = "sync"                      # sync | async
    buffer_size: int = 32                   # async: FedBuff K
    vg_size: int = 8                        # secure-agg virtual group size
    secure_agg: SecureAggConfig = field(default_factory=SecureAggConfig)
    dp: DPConfig = field(default_factory=DPConfig)
    selection: SelectionCriteria = field(default_factory=SelectionCriteria)
    eval_interval: int = 1
    round_timeout_s: float = 600.0          # sync round deadline: stragglers
                                            # past it are dropped + recovered,
                                            # not waited for
    overprovision: float = 1.0              # select ceil(cpr * this) clients
                                            # so the survivor set still hits
                                            # the target under churn
    permissions: tuple = ()                 # user ids allowed to manage
    owner: str = "default-user"
    # -- stop criteria beyond n_rounds (control-plane lifecycle) --------
    target_metric: Optional[str] = None     # e.g. "eval_accuracy" / "loss"
    target_value: Optional[float] = None    # threshold that completes the task
    target_mode: str = "max"                # "max": metric >= value stops;
                                            # "min": metric <= value stops
    epsilon_budget: Optional[float] = None  # complete when the task's RDP
                                            # accountant reaches this epsilon
    # -- scheduling policy (read by fl.scheduler.ControlPlane) ----------
    priority: int = 0                       # higher tier is granted first
    weight: float = 1.0                     # fair share within a tier
                                            # (lease-seconds are normalized
                                            # by this weight)


# Fallback id source for records built outside a ManagementService. The
# service derives ids from its own task store (max + 1) instead: this
# module-global counter resets in every fresh process, so a reloaded CLI
# session would hand out ids that collide with persisted tasks.
_task_counter = itertools.count(1)


@dataclass
class TaskRecord:
    config: TaskConfig
    model: Any                              # current global model pytree
    task_id: int = field(default_factory=lambda: next(_task_counter))
    status: TaskStatus = TaskStatus.CREATED
    round_idx: int = 0
    created_at: float = field(default_factory=time.time)
    history: list = field(default_factory=list)   # RoundInfo-like dicts
    stop_reason: Optional[str] = None       # why the task COMPLETED:
                                            # n_rounds | target_metric |
                                            # epsilon_budget

    def can_manage(self, user: str) -> bool:
        return user == self.config.owner or user in self.config.permissions
