"""Shared device directory: ONE source of truth for the physical fleet.

Before the control-plane refactor every task's ``SelectionService`` state
was fully independent, so two concurrent tasks could "select" the same
physical phone into overlapping sync cohorts — impossible on real devices
(the SDK runs one training session at a time) and unsound for secure
aggregation (a device's compute budget and availability window are
physical, not per-task). The :class:`DeviceDirectory` fixes the model:

- **registration is physical**: a device registers once, with its
  ``device_info`` and (optionally) its ``population.DeviceProfile``;
  per-task *enrollment* (selection-criteria matching, attestation) stays in
  ``SelectionService``, which is now a per-task VIEW over this directory;
- **leases**: a sync cohort selection ACQUIRES a lease per member and the
  round lifecycle releases it (``reset_round`` / ``release`` / ``drop``) —
  while leased, the device is invisible to every other task's selectable
  pool, so no device can ever train in two overlapping sync cohorts.
  Async tasks do not lease (their clients train opportunistically and the
  trusted-boundary buffer has no cohort barrier to protect);
- **availability in one place**: :meth:`available_at` answers "is this
  physical device inside its window at virtual time t" from the profile
  the device registered with, instead of each task re-deriving it;
- **fairness accounting**: released leases accumulate per-task
  *lease-seconds* (``now`` is the virtual clock, maintained by the caller
  — the scheduler/simulator), the currency the ``ControlPlane``'s
  deficit-weighted round-robin schedules against.

The lease log (on by default) records every ``(client_id, task_id, t0,
t1)`` interval so tests and audits can prove the no-overlap invariant via
:meth:`overlap_violations`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


class LeaseConflict(RuntimeError):
    """A task tried to lease a device already leased by another task."""


@dataclass
class DeviceEntry:
    client_id: str
    device_info: dict = field(default_factory=dict)
    profile: object = None          # optional population.DeviceProfile
    tasks: set = field(default_factory=set)   # task_ids enrolled with


@dataclass
class _Lease:
    task_id: int
    t_start: float


class DeviceDirectory:
    def __init__(self, log_leases: bool = True):
        self._devices: dict[str, DeviceEntry] = {}
        self._leases: dict[str, _Lease] = {}
        # task_id -> accumulated lease-seconds over released leases (the
        # fairness currency; active leases charge on release)
        self.lease_seconds: dict[int, float] = {}
        self.lease_log: list = []   # (client_id, task_id, t_start, t_end)
        self.log_leases = log_leases
        # virtual clock; the scheduler / simulator advances it so lease
        # intervals are measured in the same time base as round walls
        self.now: float = 0.0

    # -- fleet ------------------------------------------------------------
    def register(self, client_id: str, device_info: dict | None = None,
                 profile=None, task_id: int | None = None) -> DeviceEntry:
        """Physical registration (idempotent). ``task_id`` additionally
        records per-task enrollment; a later call may attach the profile a
        first registration omitted."""
        entry = self._devices.get(client_id)
        if entry is None:
            entry = DeviceEntry(client_id, dict(device_info or {}), profile)
            self._devices[client_id] = entry
        else:
            if device_info:
                entry.device_info.update(device_info)
            if profile is not None:
                entry.profile = profile
        if task_id is not None:
            entry.tasks.add(task_id)
        return entry

    def __contains__(self, client_id: str) -> bool:
        return client_id in self._devices

    def __len__(self) -> int:
        return len(self._devices)

    def devices(self) -> list:
        return sorted(self._devices)

    def profile_of(self, client_id: str):
        entry = self._devices.get(client_id)
        return entry.profile if entry else None

    def available_at(self, client_id: str, t: float | None = None) -> bool:
        """Availability-window check at virtual time ``t`` (default: the
        directory clock). Devices without a profile are always inside
        their window — the profile-less simulator contract."""
        p = self.profile_of(client_id)
        return p is None or p.available_at(self.now if t is None else t)

    def enrolled(self, task_id: int) -> list:
        return sorted(cid for cid, e in self._devices.items()
                      if task_id in e.tasks)

    # -- leases -----------------------------------------------------------
    def leased_by(self, client_id: str) -> Optional[int]:
        lease = self._leases.get(client_id)
        return lease.task_id if lease else None

    def leasable(self, client_id: str, task_id: int) -> bool:
        """Free, or already held by the SAME task (re-acquire is a no-op
        so a task's own cohort never blocks its backfill)."""
        lease = self._leases.get(client_id)
        return lease is None or lease.task_id == task_id

    def acquire(self, task_id: int, client_ids) -> None:
        """Lease every id for ``task_id`` (atomic: conflict leaves no
        partial acquisition). Selection filters on :meth:`leasable`, so a
        conflict here means two tasks raced the same device — a scheduler
        bug worth failing loudly on."""
        ids = list(client_ids)
        for cid in ids:
            if not self.leasable(cid, task_id):
                raise LeaseConflict(
                    f"device {cid!r} is leased by task "
                    f"{self._leases[cid].task_id}, wanted by {task_id}")
        for cid in ids:
            if cid not in self._leases:          # re-acquire keeps t_start
                self._leases[cid] = _Lease(task_id, self.now)

    def release(self, task_id: int, client_ids) -> float:
        """Release this task's leases on ``client_ids`` (ids it does not
        hold are ignored). Returns the lease-seconds charged."""
        charged = 0.0
        for cid in client_ids:
            lease = self._leases.get(cid)
            if lease is None or lease.task_id != task_id:
                continue
            del self._leases[cid]
            held = max(0.0, self.now - lease.t_start)
            charged += held
            self.lease_seconds[task_id] = \
                self.lease_seconds.get(task_id, 0.0) + held
            if self.log_leases:
                self.lease_log.append((cid, task_id, lease.t_start,
                                       self.now))
        return charged

    def release_all(self, task_id: int) -> float:
        return self.release(task_id,
                            [cid for cid, lease in self._leases.items()
                             if lease.task_id == task_id])

    def leased(self, task_id: int | None = None) -> list:
        """Currently-leased device ids (optionally for one task)."""
        return sorted(cid for cid, lease in self._leases.items()
                      if task_id is None or lease.task_id == task_id)

    # -- audit / telemetry ------------------------------------------------
    def overlap_violations(self) -> list:
        """Every pair of lease intervals on the SAME device that overlap
        in time — the multi-task acceptance invariant is that this is
        empty. Active (unreleased) leases are checked as open intervals
        ending at ``now``."""
        by_dev: dict[str, list] = {}
        for cid, tid, t0, t1 in self.lease_log:
            by_dev.setdefault(cid, []).append((t0, t1, tid))
        for cid, lease in self._leases.items():
            by_dev.setdefault(cid, []).append(
                (lease.t_start, self.now, lease.task_id))
        bad = []
        for cid, spans in by_dev.items():
            spans.sort()
            for (a0, a1, ta), (b0, b1, tb) in zip(spans, spans[1:]):
                if b0 < a1:            # half-open [t0, t1) intervals
                    bad.append((cid, (a0, a1, ta), (b0, b1, tb)))
        return bad

    def fleet_summary(self) -> dict:
        """Cross-task fleet view numbers for the dashboard/telemetry."""
        return {
            "devices": len(self._devices),
            "leased_now": len(self._leases),
            "lease_seconds": dict(sorted(self.lease_seconds.items())),
            "tasks_enrolled": len({t for e in self._devices.values()
                                   for t in e.tasks}),
        }
