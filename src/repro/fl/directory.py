"""Shared device directory: ONE source of truth for the physical fleet.

Before the control-plane refactor every task's ``SelectionService`` state
was fully independent, so two concurrent tasks could "select" the same
physical phone into overlapping sync cohorts — impossible on real devices
(the SDK runs one training session at a time) and unsound for secure
aggregation (a device's compute budget and availability window are
physical, not per-task). The :class:`DeviceDirectory` fixes the model:

- **registration is physical**: a device registers once, with its
  ``device_info`` and (optionally) its ``population.DeviceProfile``;
  per-task *enrollment* (selection-criteria matching, attestation) stays in
  ``SelectionService``, which is now a per-task VIEW over this directory;
- **leases**: a sync cohort selection ACQUIRES a lease per member and the
  round lifecycle releases it (``reset_round`` / ``release`` / ``drop``) —
  while leased, the device is invisible to every other task's selectable
  pool, so no device can ever train in two overlapping sync cohorts.
  Async tasks do not lease (their clients train opportunistically and the
  trusted-boundary buffer has no cohort barrier to protect);
- **availability in one place**: :meth:`available_at` answers "is this
  physical device inside its window at virtual time t" from the profile
  the device registered with, instead of each task re-deriving it;
- **fairness accounting**: released leases accumulate per-task
  *lease-seconds* (``now`` is the virtual clock, maintained by the caller
  — the scheduler/simulator), the currency the ``ControlPlane``'s
  deficit-weighted round-robin schedules against.

Array-backed since the fleet-scale refactor: device state is
struct-of-arrays (index-based membership, lease bitmaps, vectorized
availability windows), so a 10^6-device fleet registers in one bulk call
(:meth:`register_fleet`) and pool/lease queries are O(fleet) numpy ops
instead of O(fleet) python dict scans. The per-device object surface is
preserved as a lazy VIEW: ``directory._devices[cid]`` still materializes a
:class:`DeviceEntry`, ``register``/``acquire``/``release`` keep their
semantics bit-for-bit, and ``lease_seconds``/``lease_log`` remain the
plain dict/list the scheduler and audits consume.

The lease log (on by default) records every ``(client_id, task_id, t0,
t1)`` interval so tests and audits can prove the no-overlap invariant via
:meth:`overlap_violations`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.fl.population import DeviceProfile


class LeaseConflict(RuntimeError):
    """A task tried to lease a device already leased by another task."""


@dataclass
class DeviceEntry:
    """Materialized per-device view (``device_info`` is the LIVE dict —
    mutations through the entry are mutations of the directory state;
    ``tasks`` is a snapshot of the enrollment masks)."""
    client_id: str
    device_info: dict = field(default_factory=dict)
    profile: object = None          # optional population.DeviceProfile
    tasks: set = field(default_factory=set)   # task_ids enrolled with


_FREE = -1          # lease sentinel: no task holds the device


class _DeviceView:
    """Lazy mapping over the array-backed registry: the ``_devices`` dict
    the pre-refactor directory exposed, without 10^6 live objects."""

    def __init__(self, directory: "DeviceDirectory"):
        self._d = directory

    def __getitem__(self, client_id: str) -> DeviceEntry:
        d = self._d
        idx = d._index[client_id]
        return DeviceEntry(client_id, d._info_dict(idx),
                           d.profile_of(client_id), d._task_set(idx))

    def __contains__(self, client_id) -> bool:
        return client_id in self._d._index

    def __len__(self) -> int:
        return len(self._d._index)

    def __iter__(self):
        return iter(self._d._index)

    def get(self, client_id, default=None):
        return self[client_id] if client_id in self else default

    def keys(self):
        return self._d._index.keys()

    def values(self):
        return (self[c] for c in self._d._index)

    def items(self):
        return ((c, self[c]) for c in self._d._index)


_DEFAULT_INFO = {"os": "linux", "n_samples": 100, "battery": 1.0}


class DeviceDirectory:
    def __init__(self, log_leases: bool = True):
        # identity: row index <-> client id (rows never move or vanish)
        self._index: dict[str, int] = {}
        self._ids: list[str] = []
        # per-device object state (python lists, lazily filled)
        self._info: list = []           # dict | None (None: materialize
        self._info_base: list = []      #   from the bulk template + tier)
        self._profiles: list = []       # DeviceProfile | None (lazy for bulk)
        # struct-of-arrays numeric state, capacity >= n (geometric growth)
        self._cap = 0
        self._n = 0
        self._lease_task = np.full(0, _FREE, np.int64)
        self._lease_t0 = np.zeros(0)
        self._speed = np.ones(0)
        self._base_train_s = np.ones(0)
        self._hazard = np.zeros(0)
        self._offset = np.zeros(0)
        self._period = np.ones(0)
        self._duty = np.ones(0)
        self._windowed = np.zeros(0, bool)   # has availability-window data
        self._tier_code = np.full(0, -1, np.int16)
        self._tier_names: list[str] = []
        # task_id -> capacity-sized enrollment bitmap
        self._task_members: dict[int, np.ndarray] = {}
        # cached lexicographic argsort of _ids (invalidated on register)
        self._perm: Optional[np.ndarray] = None
        # task_id -> accumulated lease-seconds over released leases (the
        # fairness currency; active leases charge on release)
        self.lease_seconds: dict[int, float] = {}
        self.lease_log: list = []   # (client_id, task_id, t_start, t_end)
        self.log_leases = log_leases
        # virtual clock; the scheduler / simulator advances it so lease
        # intervals are measured in the same time base as round walls
        self.now: float = 0.0

    # -- storage ----------------------------------------------------------
    def _grow(self, need: int):
        if need <= self._cap:
            return
        cap = max(need, 2 * self._cap, 256)

        def g(a, fill, dtype=None):
            new = np.full(cap, fill, dtype or a.dtype)
            new[:self._n] = a[:self._n]
            return new

        self._lease_task = g(self._lease_task, _FREE)
        self._lease_t0 = g(self._lease_t0, 0.0)
        self._speed = g(self._speed, 1.0)
        self._base_train_s = g(self._base_train_s, 1.0)
        self._hazard = g(self._hazard, 0.0)
        self._offset = g(self._offset, 0.0)
        self._period = g(self._period, 1.0)
        self._duty = g(self._duty, 1.0)
        self._windowed = g(self._windowed, False)
        self._tier_code = g(self._tier_code, -1)
        for tid in self._task_members:
            self._task_members[tid] = g(self._task_members[tid], False)
        self._cap = cap

    def _add(self, client_id: str) -> int:
        idx = self._n
        self._grow(idx + 1)
        self._n = idx + 1
        self._index[client_id] = idx
        self._ids.append(client_id)
        self._info.append(None)
        self._info_base.append(None)
        self._profiles.append(None)
        self._perm = None
        return idx

    def _tier_of(self, name: str) -> int:
        try:
            return self._tier_names.index(name)
        except ValueError:
            self._tier_names.append(name)
            return len(self._tier_names) - 1

    def _info_dict(self, idx: int) -> dict:
        info = self._info[idx]
        if info is None:            # bulk-registered: materialize + cache
            info = dict(self._info_base[idx] or {})
            code = self._tier_code[idx]
            if code >= 0 and "tier" not in info:
                info["tier"] = self._tier_names[code]
            self._info[idx] = info
        return info

    def _task_set(self, idx: int) -> set:
        return {tid for tid, m in self._task_members.items() if m[idx]}

    def _enroll_mask(self, task_id: int) -> np.ndarray:
        m = self._task_members.get(task_id)
        if m is None:
            m = np.zeros(max(self._cap, 1), bool)
            self._task_members[task_id] = m
        return m

    def _set_profile(self, idx: int, p):
        self._profiles[idx] = p
        self._speed[idx] = p.speed
        self._base_train_s[idx] = p.base_train_s
        self._hazard[idx] = p.dropout_hazard
        self._offset[idx] = p.avail_offset
        self._period[idx] = p.avail_period
        self._duty[idx] = p.avail_duty
        self._windowed[idx] = True
        self._tier_code[idx] = self._tier_of(p.tier)

    @property
    def _devices(self) -> _DeviceView:
        return _DeviceView(self)

    def index_of(self, client_id: str) -> int:
        """Stable row index of a registered device (KeyError if unknown)."""
        return self._index[client_id]

    def sorted_perm(self) -> np.ndarray:
        """Cached argsort of the id axis: ``ids[perm]`` is the fleet in
        lexicographic order (numpy '<U' compare == python str compare), so
        every sorted-pool query is one O(fleet) fancy-index instead of an
        O(pool log pool) python sort."""
        if self._perm is None or len(self._perm) != self._n:
            self._perm = np.argsort(np.array(self._ids)) if self._n \
                else np.zeros(0, np.int64)
        return self._perm

    # -- fleet ------------------------------------------------------------
    def register(self, client_id: str, device_info: dict | None = None,
                 profile=None, task_id: int | None = None) -> DeviceEntry:
        """Physical registration (idempotent). ``task_id`` additionally
        records per-task enrollment; a later call may attach the profile a
        first registration omitted."""
        idx = self._index.get(client_id)
        if idx is None:
            idx = self._add(client_id)
            self._info[idx] = dict(device_info or {})
        elif device_info:
            self._info_dict(idx).update(device_info)
        if profile is not None:
            self._set_profile(idx, profile)
        if task_id is not None:
            self._enroll_mask(task_id)[idx] = True
        return self._devices[client_id]

    def register_fleet(self, population, device_info: dict | None = None,
                       task_id: int | None = None) -> np.ndarray:
        """Bulk physical registration of a :class:`~repro.fl.population.
        PopulationArrays` fleet — one array copy per field instead of n
        ``register`` calls. ``device_info`` is the shared info template
        (per-device dicts materialize lazily, with the device's tier).
        Idempotent per fleet: if every id is already registered, the call
        only adds the ``task_id`` enrollment. Returns the fleet's row
        indices (population order)."""
        ids = list(population.ids)
        n_new = len(ids)
        if self._n and all(c in self._index for c in ids):
            idx = np.fromiter((self._index[c] for c in ids), np.int64,
                              count=n_new)
        elif self._n and any(c in self._index for c in ids):
            # mixed old/new: correctness fallback through the scalar path
            idx = np.empty(n_new, np.int64)
            for j in range(n_new):
                self.register(ids[j], device_info,
                              profile=population.profile(j))
                idx[j] = self._index[ids[j]]
        else:
            start = self._n
            self._grow(start + n_new)
            self._index.update(zip(ids, range(start, start + n_new)))
            self._ids.extend(ids)
            self._info.extend([None] * n_new)
            base = dict(device_info if device_info is not None
                        else _DEFAULT_INFO)
            self._info_base.extend([base] * n_new)
            self._profiles.extend([None] * n_new)
            sl = slice(start, start + n_new)
            self._speed[sl] = population.speed
            self._base_train_s[sl] = population.base_train_s
            self._hazard[sl] = population.dropout_hazard
            self._offset[sl] = population.avail_offset
            self._period[sl] = population.avail_period
            self._duty[sl] = population.avail_duty
            self._windowed[sl] = True
            remap = np.asarray([self._tier_of(t)
                                for t in population.tier_names], np.int16)
            self._tier_code[sl] = remap[population.tier_code]
            self._n = start + n_new
            self._perm = None
            idx = np.arange(start, start + n_new, dtype=np.int64)
        if task_id is not None:
            self._enroll_mask(task_id)[idx] = True
        return idx

    def __contains__(self, client_id: str) -> bool:
        return client_id in self._index

    def __len__(self) -> int:
        return self._n

    def devices(self) -> list:
        perm = self.sorted_perm()
        return [self._ids[i] for i in perm]

    def profile_of(self, client_id: str):
        idx = self._index.get(client_id)
        if idx is None:
            return None
        p = self._profiles[idx]
        if p is None and self._windowed[idx]:
            # bulk-registered: materialize (and cache) the frozen view
            code = self._tier_code[idx]
            p = DeviceProfile(
                client_id=client_id,
                tier=self._tier_names[code] if code >= 0 else "",
                speed=float(self._speed[idx]),
                base_train_s=float(self._base_train_s[idx]),
                dropout_hazard=float(self._hazard[idx]),
                avail_offset=float(self._offset[idx]),
                avail_period=float(self._period[idx]),
                avail_duty=float(self._duty[idx]))
            self._profiles[idx] = p
        return p

    def available_at(self, client_id: str, t: float | None = None) -> bool:
        """Availability-window check at virtual time ``t`` (default: the
        directory clock). Devices without a profile are always inside
        their window — the profile-less simulator contract."""
        idx = self._index.get(client_id)
        if idx is None or not self._windowed[idx]:
            return True
        t = self.now if t is None else t
        duty = float(self._duty[idx])
        if duty >= 1.0:
            return True
        period = float(self._period[idx])
        return math.fmod(t + float(self._offset[idx]), period) < duty * period

    def available_mask(self, t: float | None = None) -> np.ndarray:
        """Whole-fleet availability at ``t`` as one (n,) bool array —
        elementwise identical to :meth:`available_at` (np.fmod == math.fmod
        on finite doubles)."""
        t = self.now if t is None else t
        n = self._n
        duty = self._duty[:n]
        period = self._period[:n]
        phase = np.fmod(t + self._offset[:n], np.where(period > 0,
                                                       period, 1.0))
        return ~self._windowed[:n] | (duty >= 1.0) | (phase < duty * period)

    def enrolled(self, task_id: int) -> list:
        m = self._task_members.get(task_id)
        if m is None:
            return []
        perm = self.sorted_perm()
        return [self._ids[i] for i in perm[m[:self._n][perm]]]

    def enrolled_mask(self, task_id: int) -> np.ndarray:
        """(n,) bool enrollment bitmap (a copy-free view; do not mutate)."""
        m = self._task_members.get(task_id)
        if m is None:
            return np.zeros(self._n, bool)
        return m[:self._n]

    # -- leases -----------------------------------------------------------
    def leased_by(self, client_id: str) -> Optional[int]:
        idx = self._index.get(client_id)
        if idx is None:
            return None
        t = self._lease_task[idx]
        return int(t) if t != _FREE else None

    def leasable(self, client_id: str, task_id: int) -> bool:
        """Free, or already held by the SAME task (re-acquire is a no-op
        so a task's own cohort never blocks its backfill)."""
        idx = self._index.get(client_id)
        if idx is None:
            return True
        t = self._lease_task[idx]
        return t == _FREE or t == task_id

    def leasable_mask(self, task_id: int) -> np.ndarray:
        """(n,) bool: free-or-held-by-this-task, the vectorized pool
        filter array-backed selection uses."""
        lt = self._lease_task[:self._n]
        return (lt == _FREE) | (lt == task_id)

    def _idx_of(self, client_ids) -> np.ndarray:
        # acquire may see ids never registered (legacy leases were a
        # side dict); auto-register keeps the semantics total
        out = np.empty(len(client_ids), np.int64)
        for j, cid in enumerate(client_ids):
            idx = self._index.get(cid)
            out[j] = self._add(cid) if idx is None else idx
        return out

    def acquire(self, task_id: int, client_ids, idx=None) -> None:
        """Lease every id for ``task_id`` (atomic: conflict leaves no
        partial acquisition). Selection filters on :meth:`leasable`, so a
        conflict here means two tasks raced the same device — a scheduler
        bug worth failing loudly on. ``idx``: the ids' directory rows when
        the caller already holds them (array-backed selection), skipping
        the per-id index lookups."""
        ids = list(client_ids)
        if not ids:
            return
        idx = self._idx_of(ids) if idx is None else np.asarray(idx, np.int64)
        held = self._lease_task[idx]
        conflict = (held != _FREE) & (held != task_id)
        if conflict.any():
            j = int(np.argmax(conflict))
            raise LeaseConflict(
                f"device {ids[j]!r} is leased by task "
                f"{int(held[j])}, wanted by {task_id}")
        fresh = idx[held == _FREE]           # re-acquire keeps t_start
        self._lease_task[fresh] = task_id
        self._lease_t0[fresh] = self.now

    def release(self, task_id: int, client_ids) -> float:
        """Release this task's leases on ``client_ids`` (ids it does not
        hold are ignored). Returns the lease-seconds charged."""
        ids = [cid for cid in client_ids if cid in self._index]
        if not ids:
            return 0.0
        idx = np.fromiter((self._index[c] for c in ids), np.int64,
                          count=len(ids))
        _, first = np.unique(idx, return_index=True)   # dedupe, keep order
        idx = idx[np.sort(first)]
        mine = self._lease_task[idx] == task_id
        idx = idx[mine]
        if not idx.size:
            return 0.0
        t0 = self._lease_t0[idx]
        held = np.maximum(0.0, self.now - t0)
        charged = float(held.sum())
        self.lease_seconds[task_id] = \
            self.lease_seconds.get(task_id, 0.0) + charged
        if self.log_leases:
            self.lease_log.extend(
                (self._ids[i], task_id, float(s), self.now)
                for i, s in zip(idx, t0))
        self._lease_task[idx] = _FREE
        return charged

    def release_all(self, task_id: int) -> float:
        idx = np.nonzero(self._lease_task[:self._n] == task_id)[0]
        return self.release(task_id, [self._ids[i] for i in idx])

    def leased(self, task_id: int | None = None) -> list:
        """Currently-leased device ids (optionally for one task)."""
        lt = self._lease_task[:self._n]
        m = lt != _FREE if task_id is None else lt == task_id
        return sorted(self._ids[i] for i in np.nonzero(m)[0])

    # -- audit / telemetry ------------------------------------------------
    def overlap_violations(self) -> list:
        """Every pair of lease intervals on the SAME device that overlap
        in time — the multi-task acceptance invariant is that this is
        empty. Active (unreleased) leases are checked as open intervals
        ending at ``now``."""
        by_dev: dict[str, list] = {}
        for cid, tid, t0, t1 in self.lease_log:
            by_dev.setdefault(cid, []).append((t0, t1, tid))
        for i in np.nonzero(self._lease_task[:self._n] != _FREE)[0]:
            by_dev.setdefault(self._ids[i], []).append(
                (float(self._lease_t0[i]), self.now,
                 int(self._lease_task[i])))
        bad = []
        for cid, spans in by_dev.items():
            spans.sort()
            for (a0, a1, ta), (b0, b1, tb) in zip(spans, spans[1:]):
                if b0 < a1:            # half-open [t0, t1) intervals
                    bad.append((cid, (a0, a1, ta), (b0, b1, tb)))
        return bad

    def fleet_summary(self) -> dict:
        """Cross-task fleet view numbers for the dashboard/telemetry."""
        return {
            "devices": self._n,
            "leased_now": int((self._lease_task[:self._n] != _FREE).sum()),
            "lease_seconds": dict(sorted(self.lease_seconds.items())),
            "tasks_enrolled": len([t for t, m in self._task_members.items()
                                   if m.any()]),
        }
