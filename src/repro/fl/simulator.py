"""Multi-client simulation harness (the paper's AzureML simulator analogue,
§5/Fig. 10): runs many SDK clients against an in-process ManagementService
under a *virtual clock* with heterogeneous client speeds, producing the
per-iteration duration measurements of Fig. 11 (center/right).

Sync mode: round duration = slowest selected client (barrier) + server agg.
Async mode: an event queue; the server steps whenever the FedBuff buffer
fills, so stragglers never block a round — the paper's measured speedup.

Both modes take an optional ``engine`` (``repro.core.cohort_engine
.CohortEngine``): when given, local training for a whole cohort (sync) or
for every client whose finish event lands before the next server step
(async) runs as ONE compiled vmap-over-clients computation instead of a
serial python loop — same protocol traffic through the service, orders of
magnitude fewer dispatches. The async fast path stacks each client's
*served-version* params along the client axis (the engine's personalized
path), so mixed-staleness groups batch too.

BOTH fast paths are FUSED end to end. Sync: ``run_cohort_stacked`` keeps
the cohort's updates stacked on device and ``ManagementService
.submit_cohort`` feeds them straight into the vectorized privacy pipeline
(``repro.core.privacy_engine``) — local training AND the §4 privacy chain
(DP -> quantize -> mask -> VG sums -> master combine) each run as one
compiled call per round, with no unstack-to-host in between. Async:
``run_cohort_personalized_stacked`` + ``ManagementService
.submit_updates_async`` feed each event group's stacked mixed-version
updates through the batched local-DP rows into the device-resident FedBuff
buffer (one write, one-dispatch drain on fill) — bit-identical to the
serial per-client submit loop.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro import tracing
from repro.fl.client import FederatedLearningClient, WorkflowDetails, \
    _normalize_trainer_output
from repro.fl.server import ManagementService
from repro.fl.task import TaskConfig


@dataclass
class SimClient:
    client_id: str
    trainer: Callable                   # trainer(model_bytes, round) -> update
    speed: float = 1.0                  # relative compute speed
    base_train_s: float = 1.0           # nominal seconds per local update
    device_info: dict = field(default_factory=lambda: {
        "os": "linux", "n_samples": 100, "battery": 1.0})
    profile: object = None              # optional population.DeviceProfile —
                                        # availability windows + dropout
                                        # hazard (enables the churn path)

    def duration(self, rng) -> float:
        # log-normal jitter around base/speed: heterogeneous device model
        return float(self.base_train_s / self.speed *
                     rng.lognormal(mean=0.0, sigma=0.25))

    def available_at(self, t: float) -> bool:
        return self.profile is None or self.profile.available_at(t)

    def drops_during(self, duration: float, rng) -> bool:
        return self.profile is not None and \
            self.profile.drops_during(duration, rng)


@dataclass
class SimResult:
    round_durations: list
    metrics_history: list
    total_time: float
    n_server_steps: int
    n_dropped_total: int = 0      # churn runs: mid-round dropouts, all rounds


def _register_all(service, task_id, clients):
    for cid, sc in clients.items():
        sdk = FederatedLearningClient.get_instance(cid,
                                                   device_info=sc.device_info)
        cert = sdk._authority.issue(cid, os=sc.device_info.get("os", "linux"))
        assert service.register_client(task_id, cid, sc.device_info, cert), cid


def run_sync_simulation(service: ManagementService, task_id: int,
                        clients: dict[str, SimClient],
                        server_agg_s: float = 0.05, seed: int = 0,
                        eval_fn: Callable | None = None,
                        engine=None, churn: bool | None = None) -> SimResult:
    """Drive a sync task to completion under the virtual clock.

    ``engine``: optional CohortEngine — executes each round's whole cohort
    in one vmapped call (engine.batch_fn supplies client data; SimClient
    trainers are bypassed). Virtual-clock timing is unchanged: wall time
    still models per-client device speed, not host compute.

    ``churn``: run rounds under realistic device churn — availability
    windows filter + backfill the cohort before training, per-client
    dropout hazards and the ``round_timeout_s`` deadline drop members
    mid-round, and aggregation proceeds over the survivors with mask
    recovery (``repro.core.dropout``). Defaults to auto: on iff any client
    carries a ``population.DeviceProfile`` or the task over-provisions.
    """
    rng = np.random.RandomState(seed)
    task = service.get_task(task_id)
    _register_all(service, task_id, clients)
    if churn is None:
        churn = any(sc.profile is not None for sc in clients.values()) \
            or task.config.overprovision > 1.0
    if churn:
        return _run_sync_churn(service, task_id, clients, rng,
                               server_agg_s, eval_fn, engine)

    durations, history, clock = [], [], 0.0
    while task.status.value == "running":
        with tracing.span("round", task=task_id) as round_sp:
            round_idx, cohort = service.begin_round(task_id)
            if not cohort:
                break
            round_sp.set(round=round_idx, n_cohort=len(cohort))
            blob = service.model_snapshot(task_id)
            round_wall = 0.0
            if engine is not None:
                from repro.checkpoint import deserialize_pytree
                if engine.template is None:
                    raise ValueError(
                        "CohortEngine.template must be the model pytree "
                        "structure to use the simulator fast path")
                params = deserialize_pytree(blob, like=engine.template)
                # fused path: the stacked cohort output feeds the
                # vectorized privacy pipeline directly — no
                # unstack-to-host, no per-client submit round-trips
                stacked, losses, n_samples = engine.run_cohort_stacked(
                    params, list(cohort), round_idx)
                losses = np.asarray(losses)
                if not service.submit_cohort(
                        task_id, list(cohort), stacked, n_samples,
                        [{"loss": float(l)} for l in losses]):
                    raise RuntimeError(
                        f"bulk submission rejected for round {round_idx} "
                        f"(cohort {cohort})")
                for cid in cohort:
                    round_wall = max(round_wall,
                                     clients[cid].duration(rng))
            else:
                for cid in cohort:
                    sc = clients[cid]
                    with tracing.span("local_train", client=cid,
                                      round=round_idx):
                        out = sc.trainer(blob, round_idx)
                    update, n_samples, metrics = \
                        _normalize_trainer_output(out)
                    service.submit_update(task_id, cid, update, n_samples,
                                          metrics)
                    round_wall = max(round_wall,
                                     sc.duration(rng))  # barrier
        round_wall += server_agg_s
        clock += round_wall
        durations.append(round_wall)
        service.meters.histogram("round_duration_s", task=task_id) \
            .observe(round_wall)
        row = dict(task.history[-1]) if task.history else {}
        if eval_fn is not None:
            row["eval_accuracy"] = float(eval_fn(task.model))
            service.metrics.log(task_id, round_idx + 1,
                                eval_accuracy=row["eval_accuracy"],
                                round_duration_s=round_wall)
        history.append(row)
    return SimResult(durations, history, clock, len(durations))


def _run_sync_churn(service, task_id, clients, rng, server_agg_s,
                    eval_fn, engine) -> SimResult:
    """Sync rounds under device churn (the paper's cross-device reality):

    1. over-provisioned selection from the STALE registry
       (``TaskConfig.overprovision``; the Selection Service cannot know
       live device state);
    2. availability windows are probed when the round starts — members
       outside theirs are released and backfilled (pre-protocol, no masks
       involved); an instant with nobody reachable idles one deadline and
       re-selects;
    3. every member draws a train duration; members past the
       ``round_timeout_s`` deadline or hit by their dropout hazard are
       reported dropped — the server declares dropouts AT the deadline,
       so any dropout costs the round the full deadline wall time;
    4. the survivors' aggregate runs with mask recovery — no abort.
    """
    from repro.checkpoint import deserialize_pytree
    task = service.get_task(task_id)
    deadline = task.config.round_timeout_s
    durations, history, clock, dropped_total = [], [], 0.0, 0
    voided, steps, idle = 0, 0, 0
    if engine is not None and engine.template is None:
        raise ValueError("CohortEngine.template must be the model pytree "
                         "structure to use the simulator fast path")
    while task.status.value == "running":
        # selection sees the (stale) registry, not live device state —
        # availability is probed when the round actually starts, and
        # members found outside their window are released + backfilled
        round_idx, cohort = service.begin_round(task_id)
        if cohort:
            unavailable = [c for c in cohort
                           if not clients[c].available_at(clock)]
            if unavailable:
                cohort = service.backfill_round(
                    task_id, unavailable,
                    available=lambda cid: clients[cid].available_at(clock))
        if not cohort:
            # nobody reachable at this instant: idle one deadline and try
            # again when availability windows have moved (bounded — a
            # fleet that is NEVER available ends the run)
            clock += deadline
            idle += 1
            if idle >= 64:
                break
            continue
        idle = 0
        dur = {cid: clients[cid].duration(rng) for cid in cohort}
        dropped = {cid for cid in cohort
                   if dur[cid] > deadline
                   or clients[cid].drops_during(min(dur[cid], deadline),
                                                rng)}
        survivors = [cid for cid in cohort if cid not in dropped]
        dropped_total += len(dropped)
        for cid in sorted(dropped):
            service.report_dropout(task_id, cid)
        if not survivors:
            # round voided server-side: the deadline wall time still burns
            # but NO aggregation step ran (no server_agg_s, no step count)
            clock += deadline
            durations.append(deadline)
            history.append({"round_voided": 1})
            voided += 1
            if voided >= 64:      # hazard so high no round can complete
                break
            continue
        voided = 0
        blob = service.model_snapshot(task_id)
        with tracing.span("round", task=task_id, round=round_idx,
                          n_cohort=len(cohort),
                          n_dropped=len(dropped)):
            if engine is not None:
                params = deserialize_pytree(blob, like=engine.template)
                stacked, losses, n_samples = engine.run_cohort_stacked(
                    params, survivors, round_idx)
                losses = np.asarray(losses)
                if not service.submit_cohort(
                        task_id, survivors, stacked, n_samples,
                        [{"loss": float(l)} for l in losses]):
                    raise RuntimeError(
                        f"bulk survivor submission rejected for round "
                        f"{round_idx} (survivors {survivors})")
            else:
                for cid in survivors:
                    sc = clients[cid]
                    with tracing.span("local_train", client=cid,
                                      round=round_idx):
                        out = sc.trainer(blob, round_idx)
                    update, n_samples, metrics = \
                        _normalize_trainer_output(out)
                    service.submit_update(task_id, cid, update, n_samples,
                                          metrics)
        round_wall = (deadline if dropped
                      else max(dur[cid] for cid in survivors))
        round_wall += server_agg_s
        clock += round_wall
        durations.append(round_wall)
        service.meters.histogram("round_duration_s", task=task_id) \
            .observe(round_wall)
        steps += 1
        row = dict(task.history[-1]) if task.history else {}
        if eval_fn is not None:
            row["eval_accuracy"] = float(eval_fn(task.model))
            service.metrics.log(task_id, round_idx + 1,
                                eval_accuracy=row["eval_accuracy"],
                                round_duration_s=round_wall)
        history.append(row)
    # n_server_steps counts ROUNDS THAT AGGREGATED — voided rounds appear
    # in durations/history (their wall time is real) but not here
    return SimResult(durations, history, clock, steps,
                     n_dropped_total=dropped_total)


class _SnapshotStore:
    """Versioned snapshots with in-flight refcounts.

    The pre-fix simulator kept only the latest snapshot; a straggler whose
    start version had been evicted silently retrained on the *current*
    snapshot while the server still discounted it as stale — corrupting
    FedBuff's staleness weights. Retaining every version that an in-flight
    event references makes staleness real; ``serve`` also returns the
    version actually served so the submit path records truth even if a
    version is somehow missing.
    """

    def __init__(self):
        self._blobs: dict[int, bytes] = {}
        self._refs: dict[int, int] = {}

    def put(self, version: int, blob: bytes):
        self._blobs.setdefault(version, blob)

    def ref(self, version: int):
        self._refs[version] = self._refs.get(version, 0) + 1

    def serve(self, version: int, current_version: int,
              fetch_current: Callable):
        """-> (blob, version_actually_served)."""
        self._refs[version] = self._refs.get(version, 1) - 1
        blob = self._blobs.get(version)
        if blob is not None:
            self._gc(current_version)
            return blob, version
        blob = self._blobs.get(current_version)
        if blob is None:
            blob = fetch_current()
            self._blobs[current_version] = blob
        self._gc(current_version)
        return blob, current_version

    def _gc(self, current_version: int):
        for v in [v for v, r in self._refs.items() if r <= 0]:
            del self._refs[v]
        # evict every unreferenced non-current blob — including versions
        # whose last ref dropped while they were still current (keeping
        # the refs entry and the blob coupled leaked those forever)
        for v in [v for v in self._blobs
                  if v != current_version and self._refs.get(v, 0) <= 0]:
            del self._blobs[v]


def run_async_simulation(service: ManagementService, task_id: int,
                         clients: dict[str, SimClient],
                         server_agg_s: float = 0.05, seed: int = 0,
                         eval_fn: Callable | None = None,
                         engine=None) -> SimResult:
    """Event-driven async run: each client trains continuously; the server
    steps whenever the buffer fills (no barrier — stragglers contribute
    stale updates, discounted by FedBuff).

    ``engine``: optional CohortEngine. All events landing before the next
    server step (the buffer's remaining room, in virtual-time order) batch
    into one vmapped call with per-client served-version params stacked
    along the client axis.
    """
    rng = np.random.RandomState(seed)
    task = service.get_task(task_id)
    _register_all(service, task_id, clients)

    # event queue: (finish_time, seq, cid, model_version_at_start)
    q: list = []
    seq = 0
    store = _SnapshotStore()
    store.put(0, service.model_snapshot(task_id))
    for cid, sc in clients.items():
        heapq.heappush(q, (sc.duration(rng), seq, cid, 0))
        store.ref(0)
        seq += 1
    durations, history = [], []
    last_step_t = 0.0
    clock = 0.0

    def handle_submission(clock, cid, served_version, update, n_samples,
                          metrics, reenqueue=True):
        nonlocal last_step_t, seq
        stepped = service.submit_update(task_id, cid, update, n_samples,
                                        metrics,
                                        update_version=served_version)
        if stepped:
            clock += server_agg_s
            durations.append(clock - last_step_t)
            last_step_t = clock
            store.put(task.round_idx, service.model_snapshot(task_id))
            row = {}
            if eval_fn is not None:
                row["eval_accuracy"] = float(eval_fn(task.model))
                service.metrics.log(task_id, task.round_idx,
                                    eval_accuracy=row["eval_accuracy"],
                                    round_duration_s=durations[-1])
            history.append(row)
        if reenqueue and task.status.value == "running":
            sc = clients[cid]
            heapq.heappush(q, (clock + sc.duration(rng), seq, cid,
                               task.round_idx))
            store.ref(task.round_idx)
            seq += 1
        return clock

    if engine is None:
        while q and task.status.value == "running":
            clock, _, cid, version = heapq.heappop(q)
            blob, served = store.serve(
                version, task.round_idx,
                lambda: service.model_snapshot(task_id))
            out = clients[cid].trainer(blob, served)
            update, n_samples, metrics = _normalize_trainer_output(out)
            clock = handle_submission(clock, cid, served, update, n_samples,
                                      metrics)
        return SimResult(durations, history, clock, len(durations))

    from repro.checkpoint import deserialize_pytree
    if engine.template is None:
        raise ValueError("CohortEngine.template must be the model pytree "
                         "structure to use the simulator fast path")
    while q and task.status.value == "running":
        # Timing pre-pass: the server only steps on the submission that
        # fills the buffer, so the next `room` submissions IN VIRTUAL-TIME
        # ORDER all train against pre-step snapshots and batch together.
        # Non-final group members re-enqueue their next event immediately
        # (their submission cannot trigger a step), so a fast client's
        # re-submissions compete in time order exactly as in the serial
        # reference — the same client may appear in a group twice.
        room = service.async_buffer_room(task_id)
        group = []
        while q and len(group) < room:
            t, _, cid, version = heapq.heappop(q)
            blob, served = store.serve(
                version, task.round_idx,
                lambda: service.model_snapshot(task_id))
            is_final = len(group) == room - 1 or not q
            group.append((t, cid, served, blob, is_final))
            if not is_final:
                heapq.heappush(q, (t + clients[cid].duration(rng), seq, cid,
                                   task.round_idx))
                store.ref(task.round_idx)
                seq += 1
        params_cache = {}
        for _, _, served, blob, _ in group:
            if served not in params_cache:
                params_cache[served] = deserialize_pytree(
                    blob, like=engine.template)
        # fused path: the stacked mixed-version group output feeds the
        # batched-DP FedBuff buffer in one bulk route — no unstack-to-host,
        # no per-client submit round trips. A group is at most the buffer's
        # remaining room, so at most ONE server step can occur (on the row
        # that fills the buffer) — the post-batch model/round_idx the
        # bookkeeping below reads is exactly the post-step state.
        stacked, _, n_samples = engine.run_cohort_personalized_stacked(
            [params_cache[served] for _, _, served, _, _ in group],
            [cid for _, cid, _, _, _ in group],
            [served for _, _, served, _, _ in group])
        step_rows = set(service.submit_updates_async(
            task_id, [cid for _, cid, _, _, _ in group], stacked,
            n_samples, [served for _, _, served, _, _ in group]))
        for j, (t, cid, served, _, is_final) in enumerate(group):
            clock = t
            if j in step_rows:
                clock += server_agg_s
                durations.append(clock - last_step_t)
                last_step_t = clock
                store.put(task.round_idx, service.model_snapshot(task_id))
                row = {}
                if eval_fn is not None:
                    row["eval_accuracy"] = float(eval_fn(task.model))
                    service.metrics.log(task_id, task.round_idx,
                                        eval_accuracy=row["eval_accuracy"],
                                        round_duration_s=durations[-1])
                history.append(row)
            if is_final and task.status.value == "running":
                heapq.heappush(q, (clock + clients[cid].duration(rng), seq,
                                   cid, task.round_idx))
                store.ref(task.round_idx)
                seq += 1
    return SimResult(durations, history, clock, len(durations))


@dataclass
class MultiTaskResult:
    """Outcome of :func:`run_multi_task_simulation`."""
    per_task: dict                # task_id -> SimResult
    total_time: float             # global virtual clock at exit
    lease_seconds: dict           # task_id -> device-seconds consumed
    rounds_granted: dict          # task_id -> scheduler grants
    fairness: dict                # ControlPlane.fairness() snapshot
    lease_overlaps: list          # DeviceDirectory.overlap_violations()


@dataclass
class _TaskRun:
    """Per-task simulator state (the fields every single-task driver kept
    as locals, one bundle per concurrent task)."""
    rng: object                   # np.random.RandomState — durations/hazard
    churn: bool = False
    registered: list = field(default_factory=list)
    durations: list = field(default_factory=list)
    history: list = field(default_factory=list)
    clock: float = 0.0            # this task's own end-of-activity time
    steps: int = 0                # sync rounds that aggregated
    dropped_total: int = 0
    voided: int = 0               # consecutive voided rounds (stall guard)
    idle: int = 0                 # consecutive empty-cohort probes
    stalled: bool = False
    store: object = None          # async: _SnapshotStore
    last_step_t: float = 0.0      # async: previous server-step time


def run_multi_task_simulation(plane, clients: dict[str, SimClient],
                              server_agg_s: float = 0.05, seed: int = 0,
                              eval_fns: dict | None = None,
                              engines: dict | None = None,
                              trainers: dict | None = None,
                              churn: dict | None = None,
                              on_round: Callable | None = None,
                              max_virtual_s: float = 1e9
                              ) -> MultiTaskResult:
    """Drive EVERY deployed task of a :class:`~repro.fl.scheduler
    .ControlPlane` concurrently over ONE shared client fleet under a
    single virtual clock — the paper's FLaaS scenario.

    Sync rounds are scheduler-granted (``plane.grant_round`` picks the
    next task by priority + weighted lease-seconds fairness; selection
    leases the cohort's devices so concurrent sync cohorts never share a
    device) and complete as events on the global clock; async tasks step
    whenever their FedBuff buffer fills, event-driven exactly like
    ``run_async_simulation``, without leasing. Per-task knobs are dicts
    keyed by task_id: ``eval_fns`` (model -> metric), ``engines``
    (CohortEngine sync fast path), ``trainers`` (``fn(cid, blob, round)``
    overriding ``SimClient.trainer`` — for tasks whose model structure
    differs from the fleet default), ``churn`` (force the churn posture;
    default auto per task, like ``run_sync_simulation``).

    Parity contract (tested): with exactly ONE task, per-round durations,
    history, metrics and the final model are bit-identical to
    ``run_sync_simulation`` / ``run_async_simulation`` on a plain service
    — per-task duration RNGs are seeded ``seed + task position`` so the
    first task draws the same stream a single-task run would.
    """
    from repro.checkpoint import deserialize_pytree
    service = plane.service
    eval_fns = eval_fns or {}
    engines = engines or {}
    trainers = trainers or {}
    churn = churn or {}

    task_ids = sorted(t.task_id for t in service.list_tasks()
                      if t.status.value in ("running", "paused"))
    runs: dict[int, _TaskRun] = {}
    certs: dict[str, dict] = {}
    for pos, tid in enumerate(task_ids):
        rec = service.get_task(tid)
        tr = _TaskRun(rng=np.random.RandomState(seed + pos))
        auto_churn = any(sc.profile is not None
                         for sc in clients.values()) \
            or rec.config.overprovision > 1.0
        tr.churn = bool(churn.get(tid, auto_churn))
        for cid, sc in clients.items():
            if cid not in certs:
                sdk = FederatedLearningClient.get_instance(
                    cid, device_info=sc.device_info)
                certs[cid] = sdk._authority.issue(
                    cid, os=sc.device_info.get("os", "linux"))
            if service.register_client(tid, cid, sc.device_info,
                                       certs[cid], profile=sc.profile):
                tr.registered.append(cid)
        runs[tid] = tr

    def _train(tid, cid, blob, round_idx):
        fn = trainers.get(tid)
        out = fn(cid, blob, round_idx) if fn is not None \
            else clients[cid].trainer(blob, round_idx)
        return _normalize_trainer_output(out)

    q: list = []      # (time, seq, payload) — seq breaks time ties FIFO
    seq = 0

    # async tasks: every registered client trains continuously from t=0
    for tid in task_ids:
        rec, tr = service.get_task(tid), runs[tid]
        if rec.config.mode != "async":
            continue
        tr.store = _SnapshotStore()
        tr.store.put(0, service.model_snapshot(tid))
        for cid in tr.registered:
            heapq.heappush(q, (clients[cid].duration(tr.rng), seq,
                               ("async", tid, cid, 0)))
            tr.store.ref(0)
            seq += 1

    def _stall(tid, tr):
        tr.stalled = True
        plane.defer(tid, float("inf"))   # never grant again

    def _schedule_sync(grant, clock):
        """A freshly granted round: probe/backfill (churn), draw member
        durations + dropouts NOW (the physical timeline is decided at
        round start) and push the round-end event."""
        nonlocal seq
        tid = grant.task_id
        rec, tr = service.get_task(tid), runs[tid]
        deadline = rec.config.round_timeout_s
        cohort = list(grant.cohort)
        if tr.churn:
            unavailable = [c for c in cohort
                           if not clients[c].available_at(clock)]
            if unavailable:
                cohort = service.backfill_round(
                    tid, unavailable,
                    available=lambda cid: clients[cid].available_at(clock))
            if not cohort:
                # nobody reachable at this instant: release and retry one
                # deadline later (bounded — a fleet that is NEVER inside
                # its windows stalls the task, mirroring the single-task
                # driver's idle cap)
                plane.complete_round(tid, now=clock)
                tr.idle += 1
                tr.clock = clock + deadline
                if tr.idle >= 64:
                    _stall(tid, tr)
                else:
                    plane.defer(tid, clock + deadline)
                return
        tr.idle = 0
        dur = {cid: clients[cid].duration(tr.rng) for cid in cohort}
        if tr.churn:
            dropped = {cid for cid in cohort
                       if dur[cid] > deadline
                       or clients[cid].drops_during(
                           min(dur[cid], deadline), tr.rng)}
        else:
            dropped = set()
        survivors = [c for c in cohort if c not in dropped]
        if survivors:
            round_wall = (deadline if dropped
                          else max(dur[c] for c in survivors))
            round_wall += server_agg_s
        else:
            round_wall = deadline     # voided: no aggregation wall time
        heapq.heappush(q, (clock + round_wall, seq,
                           ("sync", tid, grant, cohort, survivors,
                            sorted(dropped), round_wall)))
        seq += 1

    def _finish_sync(t_end, tid, grant, cohort, survivors, dropped,
                     round_wall):
        rec, tr = service.get_task(tid), runs[tid]
        if plane.active_grant(tid) is not grant:
            return   # round was aborted (pause/cancel) mid-flight
        round_idx = grant.round_idx
        tr.dropped_total += len(dropped)
        for cid in dropped:
            service.report_dropout(tid, cid)
        if not survivors:
            tr.voided += 1
            tr.durations.append(round_wall)
            tr.history.append({"round_voided": 1})
            tr.clock = t_end
            plane.complete_round(tid, now=t_end)
            if tr.voided >= 64:
                _stall(tid, tr)
            return
        tr.voided = 0
        blob = service.model_snapshot(tid)
        engine = engines.get(tid)
        with tracing.span("round", task=tid, round=round_idx,
                          n_cohort=len(cohort),
                          n_dropped=len(dropped)):
            if engine is not None:
                if engine.template is None:
                    raise ValueError(
                        "CohortEngine.template must be the model pytree "
                        "structure to use the simulator fast path")
                params = deserialize_pytree(blob, like=engine.template)
                stacked, losses, n_samples = engine.run_cohort_stacked(
                    params, survivors, round_idx)
                losses = np.asarray(losses)
                if not service.submit_cohort(
                        tid, survivors, stacked, n_samples,
                        [{"loss": float(l)} for l in losses]):
                    raise RuntimeError(
                        f"bulk submission rejected for task {tid} round "
                        f"{round_idx} (survivors {survivors})")
            else:
                for cid in survivors:
                    with tracing.span("local_train", client=cid,
                                      round=round_idx):
                        update, n_samples, metrics = _train(
                            tid, cid, blob, round_idx)
                    service.submit_update(tid, cid, update, n_samples,
                                          metrics)
        aggregated = rec.round_idx > round_idx   # False: privacy refusal
        plane.complete_round(tid, now=t_end)
        tr.steps += int(aggregated)
        tr.durations.append(round_wall)
        service.meters.histogram("round_duration_s", task=tid) \
            .observe(round_wall)
        tr.clock = t_end
        row = dict(rec.history[-1]) if rec.history else {}
        eval_fn = eval_fns.get(tid)
        if eval_fn is not None:
            row["eval_accuracy"] = float(eval_fn(rec.model))
            service.metrics.log(tid, round_idx + 1,
                                eval_accuracy=row["eval_accuracy"],
                                round_duration_s=round_wall)
            service.check_stop(tid)   # target_metric may be eval-time
        tr.history.append(row)
        if on_round is not None:
            on_round(tid, round_idx, t_end)

    def _handle_async(t, tid, cid, version):
        nonlocal seq
        rec, tr = service.get_task(tid), runs[tid]
        if rec.status.value != "running":
            return
        blob, served = tr.store.serve(
            version, rec.round_idx,
            lambda: service.model_snapshot(tid))
        update, n_samples, metrics = _train(tid, cid, blob, served)
        stepped = service.submit_update(tid, cid, update, n_samples,
                                        metrics, update_version=served)
        t_eff = t
        if stepped:
            t_eff = t + server_agg_s
            tr.durations.append(t_eff - tr.last_step_t)
            tr.last_step_t = t_eff
            tr.store.put(rec.round_idx, service.model_snapshot(tid))
            row = {}
            eval_fn = eval_fns.get(tid)
            if eval_fn is not None:
                row["eval_accuracy"] = float(eval_fn(rec.model))
                service.metrics.log(tid, rec.round_idx,
                                    eval_accuracy=row["eval_accuracy"],
                                    round_duration_s=tr.durations[-1])
                service.check_stop(tid)
            tr.history.append(row)
        tr.clock = t_eff
        if rec.status.value == "running":
            heapq.heappush(q, (t_eff + clients[cid].duration(tr.rng), seq,
                               ("async", tid, cid, rec.round_idx)))
            tr.store.ref(rec.round_idx)
            seq += 1

    clock = 0.0
    while clock <= max_virtual_s:
        plane.directory.now = clock
        while True:
            grant = plane.grant_round(now=clock)
            if grant is None:
                break
            _schedule_sync(grant, clock)
        if not q:
            nxt = plane.next_deferred(clock)
            if nxt is None:
                break                 # nothing pending, nothing deferred
            clock = nxt
            continue
        t, _, payload = heapq.heappop(q)
        clock = max(clock, t)
        plane.directory.now = clock
        if payload[0] == "sync":
            _finish_sync(clock, *payload[1:])
        else:
            _handle_async(clock, *payload[1:])

    per_task = {}
    for tid in task_ids:
        rec, tr = service.get_task(tid), runs[tid]
        steps = (len(tr.durations) if rec.config.mode == "async"
                 else tr.steps)
        per_task[tid] = SimResult(tr.durations, tr.history, tr.clock,
                                  steps, n_dropped_total=tr.dropped_total)
    return MultiTaskResult(
        per_task=per_task, total_time=clock,
        lease_seconds=dict(plane.directory.lease_seconds),
        rounds_granted=dict(plane.rounds_granted),
        fairness=plane.fairness(),
        lease_overlaps=plane.directory.overlap_violations())


def make_heterogeneous_clients(n: int, trainer_factory, seed: int = 0,
                               base_train_s: float = 1.0,
                               straggler_frac: float = 0.1):
    """n clients with log-normal speeds; ``straggler_frac`` get 4x slower."""
    from repro.fl.population import client_id
    rng = np.random.RandomState(seed)
    clients = {}
    for i in range(n):
        speed = float(rng.lognormal(0.0, 0.3))
        if rng.rand() < straggler_frac:
            speed /= 4.0
        cid = client_id(i, n)
        clients[cid] = SimClient(cid, trainer_factory(i), speed=speed,
                                 base_train_s=base_train_s)
    return clients
