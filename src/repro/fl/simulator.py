"""Multi-client simulation harness (the paper's AzureML simulator analogue,
§5/Fig. 10): runs many SDK clients against an in-process ManagementService
under a *virtual clock* with heterogeneous client speeds, producing the
per-iteration duration measurements of Fig. 11 (center/right).

Sync mode: round duration = slowest selected client (barrier) + server agg.
Async mode: an event queue; the server steps whenever the FedBuff buffer
fills, so stragglers never block a round — the paper's measured speedup.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.fl.client import FederatedLearningClient, WorkflowDetails, \
    _normalize_trainer_output
from repro.fl.server import ManagementService
from repro.fl.task import TaskConfig


@dataclass
class SimClient:
    client_id: str
    trainer: Callable                   # trainer(model_bytes, round) -> update
    speed: float = 1.0                  # relative compute speed
    base_train_s: float = 1.0           # nominal seconds per local update
    device_info: dict = field(default_factory=lambda: {
        "os": "linux", "n_samples": 100, "battery": 1.0})

    def duration(self, rng) -> float:
        # log-normal jitter around base/speed: heterogeneous device model
        return float(self.base_train_s / self.speed *
                     rng.lognormal(mean=0.0, sigma=0.25))


@dataclass
class SimResult:
    round_durations: list
    metrics_history: list
    total_time: float
    n_server_steps: int


def run_sync_simulation(service: ManagementService, task_id: int,
                        clients: dict[str, SimClient],
                        server_agg_s: float = 0.05, seed: int = 0,
                        eval_fn: Callable | None = None) -> SimResult:
    """Drive a sync task to completion under the virtual clock."""
    rng = np.random.RandomState(seed)
    task = service.get_task(task_id)
    wf_by_cid = {}
    for cid, sc in clients.items():
        sdk = FederatedLearningClient.get_instance(cid,
                                                   device_info=sc.device_info)
        cert = sdk._authority.issue(cid, os=sc.device_info.get("os", "linux"))
        assert service.register_client(task_id, cid, sc.device_info, cert), cid
        wf_by_cid[cid] = (sdk, WorkflowDetails(task.config.app_name,
                                               task.config.workflow_name,
                                               sc.trainer))

    durations, history, clock = [], [], 0.0
    while task.status.value == "running":
        round_idx, cohort = service.begin_round(task_id)
        if not cohort:
            break
        blob = service.model_snapshot(task_id)
        round_wall = 0.0
        for cid in cohort:
            sc = clients[cid]
            out = sc.trainer(blob, round_idx)
            update, n_samples, metrics = _normalize_trainer_output(out)
            service.submit_update(task_id, cid, update, n_samples, metrics)
            round_wall = max(round_wall, sc.duration(rng))  # barrier
        round_wall += server_agg_s
        clock += round_wall
        durations.append(round_wall)
        row = dict(task.history[-1]) if task.history else {}
        if eval_fn is not None:
            row["eval_accuracy"] = float(eval_fn(task.model))
            service.metrics.log(task_id, round_idx + 1,
                                eval_accuracy=row["eval_accuracy"],
                                round_duration_s=round_wall)
        history.append(row)
    return SimResult(durations, history, clock, len(durations))


def run_async_simulation(service: ManagementService, task_id: int,
                         clients: dict[str, SimClient],
                         server_agg_s: float = 0.05, seed: int = 0,
                         eval_fn: Callable | None = None) -> SimResult:
    """Event-driven async run: each client trains continuously; the server
    steps whenever the buffer fills (no barrier — stragglers contribute
    stale updates, discounted by FedBuff)."""
    rng = np.random.RandomState(seed)
    task = service.get_task(task_id)
    for cid, sc in clients.items():
        sdk = FederatedLearningClient.get_instance(cid,
                                                   device_info=sc.device_info)
        cert = sdk._authority.issue(cid, os=sc.device_info.get("os", "linux"))
        assert service.register_client(task_id, cid, sc.device_info, cert)

    # event queue: (finish_time, seq, cid, model_version_at_start)
    q: list = []
    seq = 0
    for cid, sc in clients.items():
        heapq.heappush(q, (sc.duration(rng), seq, cid, 0))
        seq += 1
    snapshots = {0: service.model_snapshot(task_id)}
    durations, history = [], []
    last_step_t = 0.0
    clock = 0.0
    while q and task.status.value == "running":
        clock, _, cid, version = heapq.heappop(q)
        sc = clients[cid]
        blob = snapshots.get(version) or service.model_snapshot(task_id)
        out = sc.trainer(blob, version)
        update, n_samples, metrics = _normalize_trainer_output(out)
        stepped = service.submit_update(task_id, cid, update, n_samples,
                                        metrics)
        if stepped:
            clock += server_agg_s
            durations.append(clock - last_step_t)
            last_step_t = clock
            snapshots = {task.round_idx: service.model_snapshot(task_id)}
            row = {}
            if eval_fn is not None:
                row["eval_accuracy"] = float(eval_fn(task.model))
                service.metrics.log(task_id, task.round_idx,
                                    eval_accuracy=row["eval_accuracy"],
                                    round_duration_s=durations[-1])
            history.append(row)
        if task.status.value == "running":
            heapq.heappush(q, (clock + sc.duration(rng), seq, cid,
                               task.round_idx))
            seq += 1
    return SimResult(durations, history, clock, len(durations))


def make_heterogeneous_clients(n: int, trainer_factory, seed: int = 0,
                               base_train_s: float = 1.0,
                               straggler_frac: float = 0.1):
    """n clients with log-normal speeds; ``straggler_frac`` get 4x slower."""
    rng = np.random.RandomState(seed)
    clients = {}
    for i in range(n):
        speed = float(rng.lognormal(0.0, 0.3))
        if rng.rand() < straggler_frac:
            speed /= 4.0
        cid = f"client-{i:04d}"
        clients[cid] = SimClient(cid, trainer_factory(i), speed=speed,
                                 base_train_s=base_train_s)
    return clients
