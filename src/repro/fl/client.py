"""Client SDK mirroring the paper's Fig. 3 Python API:

    def trainer(model: bytes, iteration_id: int):
        ...train locally, return the pseudo-gradient...

    work = WorkflowDetails(app_name=..., workflow_name=..., trainer=trainer)
    client = FederatedLearningClient.get_instance()
    client.execute(endpoint=service, workflows=[work], logger=ConsoleLogger())

``endpoint`` is the in-process ManagementService (production: gRPC/REST URL —
the ``isEndpointHttp1`` flag is accepted for interface fidelity and ignored).
The trainer receives the *serialized* model snapshot (bytes), exactly as in
the paper, and returns an update pytree or flat float list.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.checkpoint import deserialize_pytree
from repro.fl.auth import AttestationAuthority


class ConsoleLogger:
    def log(self, msg):
        print(f"[florida-client] {msg}")


class NullLogger:
    def log(self, msg):
        pass


@dataclass
class WorkflowDetails:
    app_name: str
    workflow_name: str
    trainer: Callable           # trainer(model_bytes, iteration_id) -> update
    selector: Optional[Callable] = None   # optional local eligibility gate


@dataclass
class FederatedLearningClient:
    client_id: str = "client-0"
    device_info: dict = field(default_factory=lambda: {
        "os": "linux", "n_samples": 100, "battery": 1.0})
    _authority: AttestationAuthority = field(
        default_factory=AttestationAuthority)

    _instance = None

    @classmethod
    def get_instance(cls, client_id: str = "client-0", **kw):
        # paper API is a singleton accessor; we key by client id so the
        # simulator can hold many
        return cls(client_id=client_id, **kw)

    def execute(self, endpoint, workflows, *, cert_path: str | None = None,
                isEndpointHttp1: bool = False, logger=None, event=None,
                max_iterations: int | None = None):
        """Participate in matching tasks until they complete.

        Returns the number of updates contributed.
        """
        logger = logger or NullLogger()
        contributed = 0
        for wf in workflows:
            tasks = endpoint.list_tasks(wf.app_name, wf.workflow_name)
            for task in tasks:
                cert = self._authority.issue(self.client_id,
                                             os=self.device_info.get(
                                                 "os", "linux"))
                ok = endpoint.register_client(task.task_id, self.client_id,
                                              self.device_info, cert)
                if not ok:
                    logger.log(f"registration rejected for {task.task_id}")
                    continue
                contributed += self._participate(endpoint, task, wf, logger,
                                                 max_iterations)
        return contributed

    def _participate(self, endpoint, task, wf, logger, max_iterations):
        n = 0
        while task.status.value == "running":
            if max_iterations is not None and n >= max_iterations:
                break
            round_idx, cohort = endpoint.begin_round(task.task_id)
            if self.client_id not in cohort:
                break
            n += self.run_assignment(endpoint, task.task_id, wf,
                                     round_idx, logger)
        return n

    def run_assignment(self, endpoint, task_id, wf, iteration_id, logger=None):
        """Fetch snapshot, run the user trainer, submit the update."""
        if wf.selector is not None and not wf.selector(self.device_info):
            return 0
        blob = endpoint.model_snapshot(task_id)
        t0 = time.perf_counter()
        out = wf.trainer(blob, iteration_id)
        duration = time.perf_counter() - t0
        update, n_samples, metrics = _normalize_trainer_output(out)
        metrics.setdefault("client_train_s", duration)
        endpoint.submit_update(task_id, self.client_id, update, n_samples,
                               metrics)
        if logger:
            logger.log(f"{self.client_id} round {iteration_id}: "
                       f"{n_samples} samples in {duration:.3f}s")
        return 1


def _normalize_trainer_output(out):
    """Trainer may return update | (update, n) | (update, n, metrics);
    update may be a pytree or a flat float list (paper Fig. 3 returns a
    list of floats)."""
    n_samples, metrics = 1, {}
    if isinstance(out, tuple):
        if len(out) == 3:
            update, n_samples, metrics = out
        elif len(out) == 2:
            update, n_samples = out
        else:
            update = out[0]
    else:
        update = out
    if isinstance(update, (list,)):
        update = np.asarray(update, np.float32)
    return update, int(n_samples), dict(metrics)


def load_model_snapshot(blob: bytes):
    """Helper for trainers: deserialize the model bytes into a pytree."""
    return deserialize_pytree(blob)
