"""Heterogeneous device-population model (paper §1/§3.1.4, §5).

Project Florida targets "heterogeneous device types ... exhibiting a wide
variety of performance characteristics": phones that train at very
different speeds, come and go with charger/wifi availability windows, and
disconnect mid-round. The simulator's original log-normal speed jitter
(``SimClient.duration``) models only the first axis; this module is the
full population model that drives the churn subsystem:

- **compute tiers** — a seeded categorical mix of device classes (flagship
  / mid-range / budget by default), each a speed multiplier band;
- **availability windows** — a per-device periodic duty cycle (phase,
  period, duty fraction) standing in for charging/idle/unmetered-network
  eligibility (the §3.1.4 selection criteria a device can only meet part
  of the day);
- **dropout hazard** — a per-device Poisson disconnect rate: the chance a
  client that STARTED a round vanishes before uploading is
  ``1 - exp(-hazard * train_time)``.

Everything is derived deterministically from ``(seed, client index)``, so
two simulations with the same config sample the same population.
``sample_population`` + ``make_population_clients`` plug straight into the
simulator; ``fl/selection.py`` consumes availability at selection time and
the dropout machinery (``repro.core.dropout``) absorbs mid-round losses.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

# -- client-id padding ------------------------------------------------------
# The original ids were f"client-{i:04d}", which breaks lexicographic-sort
# determinism past i=9999: "client-10000" < "client-2000" as strings, so
# every sorted pool (selection, VG protocol order) silently reorders. The
# fix must be a UNIFORM pad width per population — mixing 4- and 7-digit ids
# in one fleet would itself break the order ("client-0010000" < "client-2000")
# — so the width is a function of the population size: the legacy 4 while
# every index fits it (existing <= 10^4-device seeds keep their ids
# bit-for-bit), else a fixed 7 (numeric == lexicographic up to 10^7 devices).
ID_PAD_LEGACY = 4
ID_PAD_WIDE = 7


def client_id_width(n: int) -> int:
    """Zero-pad width for a population of ``n`` devices (uniform per
    population — see the compat note above)."""
    return ID_PAD_LEGACY if n <= 10 ** ID_PAD_LEGACY else ID_PAD_WIDE


def client_id(i: int, n: int) -> str:
    """The canonical id of device ``i`` in a population of ``n``."""
    return f"client-{i:0{client_id_width(n)}d}"


def client_ids(n: int) -> list:
    """All ``n`` canonical ids, index order (== lexicographic order)."""
    w = client_id_width(n)
    return [f"client-{i:0{w}d}" for i in range(n)]


@dataclass(frozen=True)
class DeviceTier:
    """One device class: sampled with probability ``weight`` (normalized
    across the mix); speed drawn log-normally around ``speed``."""
    name: str
    speed: float          # median relative compute speed (1.0 = nominal)
    weight: float         # unnormalized mix probability
    speed_sigma: float = 0.2   # log-normal spread within the tier


# A phone-fleet-flavoured default mix: a few fast flagships, a mid-range
# bulk, and a long budget tail (the paper's Fig. 11 heterogeneity shape).
DEFAULT_TIERS = (
    DeviceTier("flagship", speed=2.0, weight=0.2),
    DeviceTier("midrange", speed=1.0, weight=0.5),
    DeviceTier("budget", speed=0.4, weight=0.3),
)


@dataclass(frozen=True)
class DeviceProfile:
    """One sampled device. All randomness routed through caller RNGs so
    profiles stay immutable / hashable."""
    client_id: str
    tier: str
    speed: float                 # relative compute speed (higher = faster)
    base_train_s: float          # nominal seconds per local update
    dropout_hazard: float        # disconnects per second of training
    avail_offset: float          # availability window phase (seconds)
    avail_period: float          # window period (seconds)
    avail_duty: float            # fraction of the period the device is up

    def available_at(self, t: float) -> bool:
        """Is the device eligible (charging/idle/unmetered) at clock t?"""
        if self.avail_duty >= 1.0:
            return True
        phase = math.fmod(t + self.avail_offset, self.avail_period)
        return phase < self.avail_duty * self.avail_period

    def drop_probability(self, duration: float) -> float:
        """P(disconnect before uploading | trains for ``duration`` s)."""
        if self.dropout_hazard <= 0.0:
            return 0.0
        return 1.0 - math.exp(-self.dropout_hazard * duration)

    def drops_during(self, duration: float, rng) -> bool:
        return bool(rng.rand() < self.drop_probability(duration))


@dataclass(frozen=True)
class PopulationConfig:
    """Knobs of :func:`sample_population`. ``mean_hazard`` is the fleet
    mean disconnect rate (exponential across devices — most are stable,
    a few are flaky); ``avail_duty``/``avail_period`` shape the windows
    (duty 1.0 = always available)."""
    tiers: tuple = DEFAULT_TIERS
    base_train_s: float = 1.0
    mean_hazard: float = 0.0          # 1/s; 0 = nobody disconnects
    avail_period: float = 24.0        # "a day" in virtual seconds
    avail_duty: float = 1.0           # fraction of the period online
    duty_jitter: float = 0.0          # +- uniform jitter on the duty


def sample_population(n: int, seed: int = 0,
                      cfg: PopulationConfig = PopulationConfig()
                      ) -> list[DeviceProfile]:
    """Sample ``n`` device profiles, deterministically from ``seed``."""
    rng = np.random.RandomState(seed)
    weights = np.asarray([t.weight for t in cfg.tiers], np.float64)
    weights = weights / weights.sum()
    profiles = []
    for i in range(n):
        tier = cfg.tiers[int(rng.choice(len(cfg.tiers), p=weights))]
        speed = float(tier.speed *
                      rng.lognormal(mean=0.0, sigma=tier.speed_sigma))
        hazard = float(rng.exponential(cfg.mean_hazard)) \
            if cfg.mean_hazard > 0 else 0.0
        duty = float(np.clip(
            cfg.avail_duty + rng.uniform(-cfg.duty_jitter, cfg.duty_jitter),
            0.05, 1.0))
        profiles.append(DeviceProfile(
            client_id=client_id(i, n),
            tier=tier.name,
            speed=speed,
            base_train_s=cfg.base_train_s,
            dropout_hazard=hazard,
            avail_offset=float(rng.uniform(0.0, cfg.avail_period)),
            avail_period=cfg.avail_period,
            avail_duty=duty,
        ))
    return profiles


@dataclass
class PopulationArrays:
    """Struct-of-arrays population: the fleet-scale twin of
    ``sample_population``'s profile list.

    One vectorized RNG pass draws every device's tier / speed / hazard /
    availability window at once (a 10^6-device fleet samples in ~100 ms
    instead of the per-device loop's minutes), and the whole-fleet
    :meth:`available_mask` answers "who is inside their window at t" as one
    boolean array — what array-backed selection filters on. ``sample`` is
    its own deterministic stream (vectorized draw ORDER differs from the
    legacy loop, so it is NOT value-identical to ``sample_population`` at
    the same seed); :meth:`from_profiles` converts a legacy-sampled
    population losslessly when bit-compat with old seeds matters.

    ``ids`` follow :func:`client_id` (uniform pad width — the > 10^4
    populations that were previously lex-sort-broken get 7-digit ids)."""
    ids: list                      # n python strs, index == lex order
    tier_names: tuple              # tier code -> name
    tier_code: np.ndarray          # (n,) int16
    speed: np.ndarray              # (n,) f64
    base_train_s: np.ndarray       # (n,) f64
    dropout_hazard: np.ndarray     # (n,) f64
    avail_offset: np.ndarray       # (n,) f64
    avail_period: np.ndarray       # (n,) f64
    avail_duty: np.ndarray         # (n,) f64

    def __len__(self) -> int:
        return len(self.ids)

    @classmethod
    def sample(cls, n: int, seed: int = 0,
               cfg: PopulationConfig = PopulationConfig()
               ) -> "PopulationArrays":
        """One vectorized RNG pass over the same distributions as
        :func:`sample_population` (same marginals, distinct stream)."""
        rng = np.random.RandomState(seed)
        weights = np.asarray([t.weight for t in cfg.tiers], np.float64)
        weights = weights / weights.sum()
        code = rng.choice(len(cfg.tiers), size=n, p=weights).astype(np.int16)
        med = np.asarray([t.speed for t in cfg.tiers])[code]
        sig = np.asarray([t.speed_sigma for t in cfg.tiers])[code]
        speed = med * np.exp(sig * rng.standard_normal(n))
        hazard = rng.exponential(cfg.mean_hazard, size=n) \
            if cfg.mean_hazard > 0 else np.zeros(n)
        duty = np.clip(
            cfg.avail_duty + rng.uniform(-cfg.duty_jitter, cfg.duty_jitter, n),
            0.05, 1.0)
        return cls(
            ids=client_ids(n),
            tier_names=tuple(t.name for t in cfg.tiers),
            tier_code=code,
            speed=speed,
            base_train_s=np.full(n, cfg.base_train_s),
            dropout_hazard=hazard,
            avail_offset=rng.uniform(0.0, cfg.avail_period, n),
            avail_period=np.full(n, cfg.avail_period),
            avail_duty=duty,
        )

    @classmethod
    def from_profiles(cls, profiles) -> "PopulationArrays":
        """Lossless conversion of a legacy profile list (ids and every
        sampled value preserved bit-for-bit)."""
        names = []
        for p in profiles:
            if p.tier not in names:
                names.append(p.tier)
        code = {t: i for i, t in enumerate(names)}
        return cls(
            ids=[p.client_id for p in profiles],
            tier_names=tuple(names),
            tier_code=np.asarray([code[p.tier] for p in profiles], np.int16),
            speed=np.asarray([p.speed for p in profiles]),
            base_train_s=np.asarray([p.base_train_s for p in profiles]),
            dropout_hazard=np.asarray([p.dropout_hazard for p in profiles]),
            avail_offset=np.asarray([p.avail_offset for p in profiles]),
            avail_period=np.asarray([p.avail_period for p in profiles]),
            avail_duty=np.asarray([p.avail_duty for p in profiles]),
        )

    def available_mask(self, t: float) -> np.ndarray:
        """(n,) bool — ``DeviceProfile.available_at(t)`` for the whole
        fleet in one pass (np.fmod == math.fmod on finite doubles, so each
        element matches the scalar check exactly)."""
        period = np.where(self.avail_period > 0, self.avail_period, 1.0)
        phase = np.fmod(t + self.avail_offset, period)
        return (self.avail_duty >= 1.0) | \
            (phase < self.avail_duty * self.avail_period)

    def profile(self, i: int) -> DeviceProfile:
        """Materialize one device's frozen profile view."""
        return DeviceProfile(
            client_id=self.ids[i],
            tier=self.tier_names[self.tier_code[i]],
            speed=float(self.speed[i]),
            base_train_s=float(self.base_train_s[i]),
            dropout_hazard=float(self.dropout_hazard[i]),
            avail_offset=float(self.avail_offset[i]),
            avail_period=float(self.avail_period[i]),
            avail_duty=float(self.avail_duty[i]),
        )

    def profiles(self) -> list:
        """Materialize the full profile list (small-n convenience; at
        fleet scale keep the arrays and use the bulk directory path)."""
        return [self.profile(i) for i in range(len(self.ids))]

    def summary(self) -> dict:
        tiers = {self.tier_names[c]: int(k) for c, k in
                 zip(*np.unique(self.tier_code, return_counts=True))}
        return {
            "n": len(self.ids),
            "tiers": tiers,
            "speed_min": float(self.speed.min()),
            "speed_max": float(self.speed.max()),
            "mean_hazard": float(self.dropout_hazard.mean()),
        }


def make_population_clients(profiles, trainer_factory=None):
    """Profiles -> ``{client_id: SimClient}`` for the simulator.

    ``trainer_factory(i)``: per-client trainer callables (may be None when
    a CohortEngine supplies client data — the fused simulator path)."""
    from repro.fl.simulator import SimClient
    clients = {}
    for i, p in enumerate(profiles):
        trainer = trainer_factory(i) if trainer_factory is not None else None
        clients[p.client_id] = SimClient(
            p.client_id, trainer, speed=p.speed,
            base_train_s=p.base_train_s, profile=p,
            device_info={"os": "linux", "n_samples": 100, "battery": 1.0,
                         "tier": p.tier})
    return clients


def enroll_fleet(directory, profiles, task_id=None):
    """Register a sampled population straight into a shared
    :class:`~repro.fl.directory.DeviceDirectory` (the multi-tenant fleet
    view), without going through any one task's SDK registration. Devices
    enrolled here carry their availability profile, so every tenant's
    selection sees the same windows. Returns the directory."""
    for p in profiles:
        directory.register(
            p.client_id,
            {"os": "linux", "n_samples": 100, "battery": 1.0,
             "tier": p.tier},
            profile=p, task_id=task_id)
    return directory


def population_summary(profiles) -> dict:
    """Aggregate stats for logs/docs: tier mix, speed range, hazard mean."""
    tiers: dict = {}
    for p in profiles:
        tiers[p.tier] = tiers.get(p.tier, 0) + 1
    speeds = [p.speed for p in profiles]
    return {
        "n": len(profiles),
        "tiers": tiers,
        "speed_min": min(speeds),
        "speed_max": max(speeds),
        "mean_hazard": sum(p.dropout_hazard for p in profiles)
        / max(1, len(profiles)),
    }
