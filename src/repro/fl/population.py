"""Heterogeneous device-population model (paper §1/§3.1.4, §5).

Project Florida targets "heterogeneous device types ... exhibiting a wide
variety of performance characteristics": phones that train at very
different speeds, come and go with charger/wifi availability windows, and
disconnect mid-round. The simulator's original log-normal speed jitter
(``SimClient.duration``) models only the first axis; this module is the
full population model that drives the churn subsystem:

- **compute tiers** — a seeded categorical mix of device classes (flagship
  / mid-range / budget by default), each a speed multiplier band;
- **availability windows** — a per-device periodic duty cycle (phase,
  period, duty fraction) standing in for charging/idle/unmetered-network
  eligibility (the §3.1.4 selection criteria a device can only meet part
  of the day);
- **dropout hazard** — a per-device Poisson disconnect rate: the chance a
  client that STARTED a round vanishes before uploading is
  ``1 - exp(-hazard * train_time)``.

Everything is derived deterministically from ``(seed, client index)``, so
two simulations with the same config sample the same population.
``sample_population`` + ``make_population_clients`` plug straight into the
simulator; ``fl/selection.py`` consumes availability at selection time and
the dropout machinery (``repro.core.dropout``) absorbs mid-round losses.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class DeviceTier:
    """One device class: sampled with probability ``weight`` (normalized
    across the mix); speed drawn log-normally around ``speed``."""
    name: str
    speed: float          # median relative compute speed (1.0 = nominal)
    weight: float         # unnormalized mix probability
    speed_sigma: float = 0.2   # log-normal spread within the tier


# A phone-fleet-flavoured default mix: a few fast flagships, a mid-range
# bulk, and a long budget tail (the paper's Fig. 11 heterogeneity shape).
DEFAULT_TIERS = (
    DeviceTier("flagship", speed=2.0, weight=0.2),
    DeviceTier("midrange", speed=1.0, weight=0.5),
    DeviceTier("budget", speed=0.4, weight=0.3),
)


@dataclass(frozen=True)
class DeviceProfile:
    """One sampled device. All randomness routed through caller RNGs so
    profiles stay immutable / hashable."""
    client_id: str
    tier: str
    speed: float                 # relative compute speed (higher = faster)
    base_train_s: float          # nominal seconds per local update
    dropout_hazard: float        # disconnects per second of training
    avail_offset: float          # availability window phase (seconds)
    avail_period: float          # window period (seconds)
    avail_duty: float            # fraction of the period the device is up

    def available_at(self, t: float) -> bool:
        """Is the device eligible (charging/idle/unmetered) at clock t?"""
        if self.avail_duty >= 1.0:
            return True
        phase = math.fmod(t + self.avail_offset, self.avail_period)
        return phase < self.avail_duty * self.avail_period

    def drop_probability(self, duration: float) -> float:
        """P(disconnect before uploading | trains for ``duration`` s)."""
        if self.dropout_hazard <= 0.0:
            return 0.0
        return 1.0 - math.exp(-self.dropout_hazard * duration)

    def drops_during(self, duration: float, rng) -> bool:
        return bool(rng.rand() < self.drop_probability(duration))


@dataclass(frozen=True)
class PopulationConfig:
    """Knobs of :func:`sample_population`. ``mean_hazard`` is the fleet
    mean disconnect rate (exponential across devices — most are stable,
    a few are flaky); ``avail_duty``/``avail_period`` shape the windows
    (duty 1.0 = always available)."""
    tiers: tuple = DEFAULT_TIERS
    base_train_s: float = 1.0
    mean_hazard: float = 0.0          # 1/s; 0 = nobody disconnects
    avail_period: float = 24.0        # "a day" in virtual seconds
    avail_duty: float = 1.0           # fraction of the period online
    duty_jitter: float = 0.0          # +- uniform jitter on the duty


def sample_population(n: int, seed: int = 0,
                      cfg: PopulationConfig = PopulationConfig()
                      ) -> list[DeviceProfile]:
    """Sample ``n`` device profiles, deterministically from ``seed``."""
    rng = np.random.RandomState(seed)
    weights = np.asarray([t.weight for t in cfg.tiers], np.float64)
    weights = weights / weights.sum()
    profiles = []
    for i in range(n):
        tier = cfg.tiers[int(rng.choice(len(cfg.tiers), p=weights))]
        speed = float(tier.speed *
                      rng.lognormal(mean=0.0, sigma=tier.speed_sigma))
        hazard = float(rng.exponential(cfg.mean_hazard)) \
            if cfg.mean_hazard > 0 else 0.0
        duty = float(np.clip(
            cfg.avail_duty + rng.uniform(-cfg.duty_jitter, cfg.duty_jitter),
            0.05, 1.0))
        profiles.append(DeviceProfile(
            client_id=f"client-{i:04d}",
            tier=tier.name,
            speed=speed,
            base_train_s=cfg.base_train_s,
            dropout_hazard=hazard,
            avail_offset=float(rng.uniform(0.0, cfg.avail_period)),
            avail_period=cfg.avail_period,
            avail_duty=duty,
        ))
    return profiles


def make_population_clients(profiles, trainer_factory=None):
    """Profiles -> ``{client_id: SimClient}`` for the simulator.

    ``trainer_factory(i)``: per-client trainer callables (may be None when
    a CohortEngine supplies client data — the fused simulator path)."""
    from repro.fl.simulator import SimClient
    clients = {}
    for i, p in enumerate(profiles):
        trainer = trainer_factory(i) if trainer_factory is not None else None
        clients[p.client_id] = SimClient(
            p.client_id, trainer, speed=p.speed,
            base_train_s=p.base_train_s, profile=p,
            device_info={"os": "linux", "n_samples": 100, "battery": 1.0,
                         "tier": p.tier})
    return clients


def enroll_fleet(directory, profiles, task_id=None):
    """Register a sampled population straight into a shared
    :class:`~repro.fl.directory.DeviceDirectory` (the multi-tenant fleet
    view), without going through any one task's SDK registration. Devices
    enrolled here carry their availability profile, so every tenant's
    selection sees the same windows. Returns the directory."""
    for p in profiles:
        directory.register(
            p.client_id,
            {"os": "linux", "n_samples": 100, "battery": 1.0,
             "tier": p.tier},
            profile=p, task_id=task_id)
    return directory


def population_summary(profiles) -> dict:
    """Aggregate stats for logs/docs: tier mix, speed range, hazard mean."""
    tiers: dict = {}
    for p in profiles:
        tiers[p.tier] = tiers.get(p.tier, 0) + 1
    speeds = [p.speed for p in profiles]
    return {
        "n": len(profiles),
        "tiers": tiers,
        "speed_min": min(speeds),
        "speed_max": max(speeds),
        "mean_hazard": sum(p.dropout_hazard for p in profiles)
        / max(1, len(profiles)),
    }
