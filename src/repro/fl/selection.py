"""Selection Service (paper §3.1.4): advertises tasks, registers clients
that meet the criteria, randomly selects the round cohort, and tracks
per-participant training status.

Churn-aware since the dropout subsystem: cohorts can be OVER-PROVISIONED
(select more than ``clients_per_round`` so the survivor set still hits the
target under expected dropout), carry a round DEADLINE (stragglers past it
are dropped, not waited for), and BACKFILL replacements for members found
unavailable before training starts. Lifecycle: ``registered -> selected ->
training -> done | dropped``, and ``reset_round`` releases selected/done
AND dropped members back to the registered pool — a device that
disconnected mid-round re-registers next round, exactly like a device that
finished (the pre-fix code kept ``dropped`` sticky forever, so churned
devices leaked out of the pool and ``ready()`` over-counted them).

Multi-tenant since the control-plane refactor: the service is a per-task
VIEW over a shared :class:`~repro.fl.directory.DeviceDirectory`. Per-task
state (criteria matching, round status) stays here; physical state
(device identity, profile, leases) lives in the directory. Selecting a
cohort ACQUIRES a per-device lease and the round lifecycle releases it
(``reset_round`` / ``release`` / ``drop``), so with many tasks sharing one
fleet no device can sit in two overlapping sync cohorts — ``available``
filters leased-elsewhere devices out of the pool. With a single task the
pool and the RNG draw sequence are bit-identical to the pre-directory
service."""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from repro.fl.auth import AuthenticationService
from repro.fl.directory import DeviceDirectory
from repro.fl.task import TaskRecord


@dataclass
class Registration:
    client_id: str
    device_info: dict
    status: str = "registered"   # registered | selected | training | done | dropped


class SelectionService:
    def __init__(self, auth: AuthenticationService | None = None, seed=0,
                 directory: DeviceDirectory | None = None):
        self.auth = auth or AuthenticationService()
        self._rng = random.Random(seed)
        # the shared physical-fleet view; standalone services get a
        # private one so single-task behaviour needs no wiring
        self.directory = directory if directory is not None \
            else DeviceDirectory()
        # task_id -> {client_id -> Registration}
        self._registrations: dict = {}
        # task_id -> deadline (seconds) of the current round, if any
        self._deadlines: dict = {}

    # -- client side -------------------------------------------------------
    def advertise(self, tasks: list[TaskRecord], app_name: str,
                  workflow_name: str) -> list[TaskRecord]:
        """Which running tasks match this app/workflow?"""
        return [t for t in tasks
                if t.config.app_name == app_name
                and t.config.workflow_name == workflow_name
                and t.status.value in ("created", "running")]

    def register(self, task: TaskRecord, client_id: str, device_info: dict,
                 certificate: dict | None = None, profile=None) -> bool:
        crit = task.config.selection
        if crit.require_attestation:
            if certificate is None or not self.auth.verify(certificate):
                return False
        if not crit.matches(device_info):
            return False
        self._registrations.setdefault(task.task_id, {})[client_id] = \
            Registration(client_id, device_info)
        # per-task enrollment above; physical registration (identity,
        # availability profile, leases) in the shared directory
        self.directory.register(client_id, device_info, profile=profile,
                                task_id=task.task_id)
        return True

    # -- server side -------------------------------------------------------
    def registered(self, task: TaskRecord) -> list[str]:
        """Every client the task knows about, regardless of round status."""
        return sorted(self._registrations.get(task.task_id, {}))

    def available(self, task: TaskRecord) -> list[str]:
        """The selectable pool: clients currently in status 'registered'
        (not mid-round, not dropped-this-round) whose device is not leased
        to ANOTHER task (with one task this filter is a no-op, keeping the
        pool — and hence the RNG sequence — bit-identical)."""
        return sorted(cid for cid, reg in
                      self._registrations.get(task.task_id, {}).items()
                      if reg.status == "registered"
                      and self.directory.leasable(cid, task.task_id))

    def ready(self, task: TaskRecord) -> bool:
        return len(self.available(task)) >= task.config.clients_per_round

    def select_cohort(self, task: TaskRecord, overprovision: float = 1.0,
                      deadline: float | None = None,
                      available=None) -> list[str]:
        """Random cohort from the selectable pool, evenly spreading load.

        ``overprovision``: select ``ceil(clients_per_round *
        overprovision)`` members (>= 1.0) so the round still reaches its
        target cohort under expected dropout — the deadline-based churn
        posture. ``deadline``: recorded for the round (stragglers past it
        get dropped by the caller; see :meth:`round_deadline`).
        ``available``: optional ``cid -> bool`` predicate (device
        availability windows at selection time)."""
        pool = self.available(task)
        if available is not None:
            pool = [cid for cid in pool if available(cid)]
        target = max(1, math.ceil(task.config.clients_per_round
                                  * max(1.0, overprovision)))
        k = min(target, len(pool))
        cohort = self._rng.sample(pool, k)
        regs = self._registrations[task.task_id]
        for cid in cohort:
            regs[cid].status = "selected"
        self.directory.acquire(task.task_id, cohort)
        self._deadlines[task.task_id] = deadline
        return sorted(cohort)

    def backfill(self, task: TaskRecord, n: int, available=None) -> list:
        """Draw up to ``n`` replacement members from the selectable pool
        (mid-lifecycle top-up for cohort members found unavailable before
        training started). Marks them 'selected'; returns the new ids."""
        pool = self.available(task)
        if available is not None:
            pool = [cid for cid in pool if available(cid)]
        picks = self._rng.sample(pool, min(n, len(pool)))
        regs = self._registrations[task.task_id]
        for cid in picks:
            regs[cid].status = "selected"
        self.directory.acquire(task.task_id, picks)
        return sorted(picks)

    def round_deadline(self, task: TaskRecord):
        """Deadline recorded by the current round's ``select_cohort``."""
        return self._deadlines.get(task.task_id)

    def mark(self, task: TaskRecord, client_id: str, status: str):
        self._registrations[task.task_id][client_id].status = status

    def release(self, task: TaskRecord, client_id: str):
        """Return a member to the selectable pool without it counting as a
        round dropout (selection-time unavailability, pre-training)."""
        self.mark(task, client_id, "registered")
        self.directory.release(task.task_id, [client_id])

    def reset_round(self, task: TaskRecord):
        """Start-of-round lifecycle reset: participants still 'selected',
        'done' — or 'dropped', the churn fix — from the previous round
        return to the registered pool. (Without this, cohort members
        stayed 'selected' forever and dropped devices could never
        re-register for later rounds.)"""
        for reg in self._registrations.get(task.task_id, {}).values():
            if reg.status in ("selected", "done", "dropped"):
                reg.status = "registered"
        self.directory.release_all(task.task_id)
        self._deadlines.pop(task.task_id, None)

    def statuses(self, task: TaskRecord) -> dict:
        return {cid: reg.status for cid, reg in
                self._registrations.get(task.task_id, {}).items()}

    def drop(self, task: TaskRecord, client_id: str):
        """Mid-round dropout: the member leaves the round (its group's
        masks get recovered server-side) but re-enters the pool at the
        next ``reset_round``. Its lease is released immediately — a
        disconnected device is physically free for other tasks even
        though THIS task keeps it out of its own pool until reset."""
        self.mark(task, client_id, "dropped")
        self.directory.release(task.task_id, [client_id])
