"""Selection Service (paper §3.1.4): advertises tasks, registers clients
that meet the criteria, randomly selects the round cohort, and tracks
per-participant training status.

Churn-aware since the dropout subsystem: cohorts can be OVER-PROVISIONED
(select more than ``clients_per_round`` so the survivor set still hits the
target under expected dropout), carry a round DEADLINE (stragglers past it
are dropped, not waited for), and BACKFILL replacements for members found
unavailable before training starts. Lifecycle: ``registered -> selected ->
training -> done | dropped``, and ``reset_round`` releases selected/done
AND dropped members back to the registered pool — a device that
disconnected mid-round re-registers next round, exactly like a device that
finished (the pre-fix code kept ``dropped`` sticky forever, so churned
devices leaked out of the pool and ``ready()`` over-counted them).

Multi-tenant since the control-plane refactor: the service is a per-task
VIEW over a shared :class:`~repro.fl.directory.DeviceDirectory`. Per-task
state (criteria matching, round status) stays here; physical state
(device identity, profile, leases) lives in the directory. Selecting a
cohort ACQUIRES a per-device lease and the round lifecycle releases it
(``reset_round`` / ``release`` / ``drop``), so with many tasks sharing one
fleet no device can sit in two overlapping sync cohorts — ``available``
filters leased-elsewhere devices out of the pool.

Array-backed since the fleet-scale refactor: per-task enrollment and round
status are int8/bool arrays indexed by the directory's device rows, and
the selectable pool is the directory's cached lexicographic permutation
fancy-indexed by one boolean mask — O(fleet) numpy work per selection
instead of an O(pool log pool) python sorted-dict comprehension. The RNG
DRAW SEQUENCE IS BIT-IDENTICAL to the dict-based service:
``random.Random.sample`` consumes randomness as a function of ``(len(pool),
k)`` only and reads members by index, so feeding it a lazy sequence view
over the pool's index array reproduces the legacy cohorts element for
element (property-tested in tests/test_fleet_scale.py)."""
from __future__ import annotations

import math
import random
from collections.abc import Sequence
from dataclasses import dataclass

import numpy as np

from repro import tracing
from repro.fl.auth import AuthenticationService
from repro.fl.directory import DeviceDirectory
from repro.fl.task import TaskRecord


@dataclass
class Registration:
    """Compat record shape (the array-backed service no longer stores
    these per client; ``statuses`` reconstructs the same mapping)."""
    client_id: str
    device_info: dict
    status: str = "registered"   # registered | selected | training | done | dropped

STATUS_CODES = ("registered", "selected", "training", "done", "dropped")
_CODE = {s: i for i, s in enumerate(STATUS_CODES)}
_REGISTERED = _CODE["registered"]
_SELECTED = _CODE["selected"]
_DONE = _CODE["done"]
_DROPPED = _CODE["dropped"]


class _PoolView(Sequence):
    """Lazy sorted-pool view: ``pool[j]`` materializes only the drawn
    member's id. ``random.Random.sample`` over this view consumes the RNG
    exactly like the legacy list-of-str pool of the same length."""
    __slots__ = ("_ids", "_idx")

    def __init__(self, ids: list, idx: np.ndarray):
        self._ids = ids
        self._idx = idx

    def __len__(self) -> int:
        return len(self._idx)

    def __getitem__(self, j):
        return self._ids[self._idx[j]]


class _IdxView(Sequence):
    """Index twin of :class:`_PoolView`: ``pool[j]`` is the drawn member's
    DIRECTORY ROW. ``random.Random.sample`` consumes randomness purely as
    a function of ``(len, k)`` and touches members only by position, so
    sampling rows here and mapping to ids after is bit-identical to
    sampling the id view — while leaving the draw's status/lease writes
    fully vectorized."""
    __slots__ = ("_idx",)

    def __init__(self, idx: np.ndarray):
        self._idx = idx

    def __len__(self) -> int:
        return len(self._idx)

    def __getitem__(self, j):
        return self._idx[j]


class SelectionService:
    def __init__(self, auth: AuthenticationService | None = None, seed=0,
                 directory: DeviceDirectory | None = None):
        self.auth = auth or AuthenticationService()
        self._rng = random.Random(seed)
        # the shared physical-fleet view; standalone services get a
        # private one so single-task behaviour needs no wiring
        self.directory = directory if directory is not None \
            else DeviceDirectory()
        # task_id -> (n,)-capacity int8 round-status codes (meaningful
        # where the directory's enrollment bitmap is set)
        self._status: dict[int, np.ndarray] = {}
        # task_id -> deadline (seconds) of the current round, if any
        self._deadlines: dict = {}

    # -- per-task arrays ---------------------------------------------------
    def _status_arr(self, task_id: int) -> np.ndarray:
        n = len(self.directory)
        arr = self._status.get(task_id)
        if arr is None or len(arr) < n:
            new = np.full(max(n, 256), _REGISTERED, np.int8)
            if arr is not None:
                new[:len(arr)] = arr
            self._status[task_id] = arr = new
        return arr

    def _pool_mask(self, task: TaskRecord) -> np.ndarray:
        """(n,) bool — enrolled, status 'registered', lease-free (or held
        by this task): the selectable pool as one vectorized filter."""
        d = self.directory
        n = len(d)
        enrolled = d.enrolled_mask(task.task_id)
        status = self._status_arr(task.task_id)[:n]
        return enrolled & (status == _REGISTERED) \
            & d.leasable_mask(task.task_id)

    def _sorted_ids(self, mask: np.ndarray) -> list:
        perm = self.directory.sorted_perm()
        ids = self.directory._ids
        return [ids[i] for i in perm[mask[perm]]]

    # -- client side -------------------------------------------------------
    def advertise(self, tasks: list[TaskRecord], app_name: str,
                  workflow_name: str) -> list[TaskRecord]:
        """Which running tasks match this app/workflow?"""
        return [t for t in tasks
                if t.config.app_name == app_name
                and t.config.workflow_name == workflow_name
                and t.status.value in ("created", "running")]

    def register(self, task: TaskRecord, client_id: str, device_info: dict,
                 certificate: dict | None = None, profile=None) -> bool:
        crit = task.config.selection
        if crit.require_attestation:
            if certificate is None or not self.auth.verify(certificate):
                return False
        if not crit.matches(device_info):
            return False
        # physical registration (identity, availability profile, leases)
        # in the shared directory; per-task round status here
        self.directory.register(client_id, device_info, profile=profile,
                                task_id=task.task_id)
        idx = self.directory.index_of(client_id)
        self._status_arr(task.task_id)[idx] = _REGISTERED
        return True

    def register_fleet(self, task: TaskRecord, population,
                       device_info: dict | None = None) -> int:
        """Bulk enrollment of a :class:`~repro.fl.population.
        PopulationArrays` fleet into one task — the 10^6-device path (one
        array pass instead of n ``register`` calls). The selection
        criteria are evaluated ONCE against the shared ``device_info``
        template (a uniform fleet; attestation is not supported on the
        bulk path — enroll per-device when it is required). Returns the
        number of devices enrolled."""
        crit = task.config.selection
        if crit.require_attestation:
            raise ValueError("register_fleet cannot attest devices; "
                             "use per-device register() or a criteria "
                             "config with require_attestation=False")
        info = dict(device_info
                    or {"os": "linux", "n_samples": 100, "battery": 1.0})
        if not crit.matches(info):
            return 0
        idx = self.directory.register_fleet(population, device_info=info,
                                            task_id=task.task_id)
        self._status_arr(task.task_id)[idx] = _REGISTERED
        return len(idx)

    # -- server side -------------------------------------------------------
    def registered(self, task: TaskRecord) -> list[str]:
        """Every client the task knows about, regardless of round status."""
        return self._sorted_ids(self.directory.enrolled_mask(task.task_id))

    def n_registered(self, task: TaskRecord) -> int:
        return int(self.directory.enrolled_mask(task.task_id).sum())

    def available(self, task: TaskRecord) -> list[str]:
        """The selectable pool: clients currently in status 'registered'
        (not mid-round, not dropped-this-round) whose device is not leased
        to ANOTHER task (with one task this filter is a no-op, keeping the
        pool — and hence the RNG sequence — bit-identical)."""
        return self._sorted_ids(self._pool_mask(task))

    def n_available(self, task: TaskRecord) -> int:
        """``len(available(task))`` without materializing the id list —
        what fleet-scale readiness checks (scheduler ``_ready``) poll."""
        return int(self._pool_mask(task).sum())

    def ready(self, task: TaskRecord) -> bool:
        return self.n_available(task) >= task.config.clients_per_round

    def _draw(self, task: TaskRecord, k_target: int, available) -> list:
        """Sorted-pool draw shared by select_cohort/backfill. ``available``
        is None, a ``cid -> bool`` predicate (legacy; applied to the
        sorted pool in order), or an (n,)-indexed bool array (the
        vectorized fast path — same pool, no python per-id calls)."""
        mask = self._pool_mask(task)
        if isinstance(available, np.ndarray):
            mask = mask & available[:len(mask)]
            available = None
        perm = self.directory.sorted_perm()
        pool_idx = perm[mask[perm]]
        ids = self.directory._ids
        status = self._status_arr(task.task_id)
        if available is not None:
            pool = [cid for cid in _PoolView(ids, pool_idx)
                    if available(cid)]
            picks = self._rng.sample(pool, min(k_target, len(pool)))
            idx = np.fromiter((self.directory.index_of(c) for c in picks),
                              np.int64, count=len(picks))
        else:
            pool = _IdxView(pool_idx)
            idx = np.asarray(
                self._rng.sample(pool, min(k_target, len(pool))), np.int64)
            picks = [ids[i] for i in idx]
        with tracing.span("lease_acquire", task=task.task_id,
                          k=k_target, n=len(picks)):
            status[idx] = _SELECTED
            self.directory.acquire(task.task_id, picks, idx=idx)
        return picks

    def select_cohort(self, task: TaskRecord, overprovision: float = 1.0,
                      deadline: float | None = None,
                      available=None) -> list[str]:
        """Random cohort from the selectable pool, evenly spreading load.

        ``overprovision``: select ``ceil(clients_per_round *
        overprovision)`` members (>= 1.0) so the round still reaches its
        target cohort under expected dropout — the deadline-based churn
        posture. ``deadline``: recorded for the round (stragglers past it
        get dropped by the caller; see :meth:`round_deadline`).
        ``available``: optional ``cid -> bool`` predicate, or an
        (n,)-indexed bool array (``DeviceDirectory.available_mask``) for
        the vectorized filter (device availability windows at selection
        time)."""
        target = max(1, math.ceil(task.config.clients_per_round
                                  * max(1.0, overprovision)))
        cohort = self._draw(task, target, available)
        self._deadlines[task.task_id] = deadline
        return sorted(cohort)

    def backfill(self, task: TaskRecord, n: int, available=None) -> list:
        """Draw up to ``n`` replacement members from the selectable pool
        (mid-lifecycle top-up for cohort members found unavailable before
        training started). Marks them 'selected'; returns the new ids."""
        return sorted(self._draw(task, n, available))

    def round_deadline(self, task: TaskRecord):
        """Deadline recorded by the current round's ``select_cohort``."""
        return self._deadlines.get(task.task_id)

    def mark(self, task: TaskRecord, client_id: str, status: str):
        if not self.directory.enrolled_mask(task.task_id)[
                self.directory.index_of(client_id)]:
            raise KeyError(client_id)
        self._status_arr(task.task_id)[
            self.directory.index_of(client_id)] = _CODE[status]

    def release(self, task: TaskRecord, client_id: str):
        """Return a member to the selectable pool without it counting as a
        round dropout (selection-time unavailability, pre-training)."""
        self.mark(task, client_id, "registered")
        self.directory.release(task.task_id, [client_id])

    def reset_round(self, task: TaskRecord):
        """Start-of-round lifecycle reset: participants still 'selected',
        'done' — or 'dropped', the churn fix — from the previous round
        return to the registered pool. (Without this, cohort members
        stayed 'selected' forever and dropped devices could never
        re-register for later rounds.)"""
        n = len(self.directory)
        enrolled = self.directory.enrolled_mask(task.task_id)
        status = self._status_arr(task.task_id)
        s = status[:n]
        done = enrolled & ((s == _SELECTED) | (s == _DONE) | (s == _DROPPED))
        s[done] = _REGISTERED
        self.directory.release_all(task.task_id)
        self._deadlines.pop(task.task_id, None)

    def statuses(self, task: TaskRecord) -> dict:
        n = len(self.directory)
        enrolled = self.directory.enrolled_mask(task.task_id)
        status = self._status_arr(task.task_id)[:n]
        ids = self.directory._ids
        return {ids[i]: STATUS_CODES[status[i]]
                for i in np.nonzero(enrolled)[0]}

    def drop(self, task: TaskRecord, client_id: str):
        """Mid-round dropout: the member leaves the round (its group's
        masks get recovered server-side) but re-enters the pool at the
        next ``reset_round``. Its lease is released immediately — a
        disconnected device is physically free for other tasks even
        though THIS task keeps it out of its own pool until reset."""
        self.mark(task, client_id, "dropped")
        self.directory.release(task.task_id, [client_id])
