"""Selection Service (paper §3.1.4): advertises tasks, registers clients
that meet the criteria, randomly selects the round cohort, and tracks
per-participant training status."""
from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.fl.auth import AuthenticationService
from repro.fl.task import TaskRecord


@dataclass
class Registration:
    client_id: str
    device_info: dict
    status: str = "registered"   # registered | selected | training | done | dropped


class SelectionService:
    def __init__(self, auth: AuthenticationService | None = None, seed=0):
        self.auth = auth or AuthenticationService()
        self._rng = random.Random(seed)
        # task_id -> {client_id -> Registration}
        self._registrations: dict = {}

    # -- client side -------------------------------------------------------
    def advertise(self, tasks: list[TaskRecord], app_name: str,
                  workflow_name: str) -> list[TaskRecord]:
        """Which running tasks match this app/workflow?"""
        return [t for t in tasks
                if t.config.app_name == app_name
                and t.config.workflow_name == workflow_name
                and t.status.value in ("created", "running")]

    def register(self, task: TaskRecord, client_id: str, device_info: dict,
                 certificate: dict | None = None) -> bool:
        crit = task.config.selection
        if crit.require_attestation:
            if certificate is None or not self.auth.verify(certificate):
                return False
        if not crit.matches(device_info):
            return False
        self._registrations.setdefault(task.task_id, {})[client_id] = \
            Registration(client_id, device_info)
        return True

    # -- server side -------------------------------------------------------
    def registered(self, task: TaskRecord) -> list[str]:
        return sorted(self._registrations.get(task.task_id, {}))

    def ready(self, task: TaskRecord) -> bool:
        return len(self.registered(task)) >= task.config.clients_per_round

    def select_cohort(self, task: TaskRecord) -> list[str]:
        """Random subset of registered participants, evenly spreading load."""
        pool = self.registered(task)
        k = min(task.config.clients_per_round, len(pool))
        cohort = self._rng.sample(pool, k)
        regs = self._registrations[task.task_id]
        for cid in cohort:
            regs[cid].status = "selected"
        return sorted(cohort)

    def mark(self, task: TaskRecord, client_id: str, status: str):
        self._registrations[task.task_id][client_id].status = status

    def reset_round(self, task: TaskRecord):
        """Start-of-round lifecycle reset: participants still 'selected'
        or 'done' from the previous round return to the registered pool
        (without this, cohort members stayed 'selected' forever)."""
        for reg in self._registrations.get(task.task_id, {}).values():
            if reg.status in ("selected", "done"):
                reg.status = "registered"

    def statuses(self, task: TaskRecord) -> dict:
        return {cid: reg.status for cid, reg in
                self._registrations.get(task.task_id, {}).items()}

    def drop(self, task: TaskRecord, client_id: str):
        self.mark(task, client_id, "dropped")
