"""Metrics store backing the Florida dashboard / task view (paper §3.3):
per-round training metrics, evaluation metrics, and run-time performance."""
from __future__ import annotations

import json
from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class MetricsStore:
    # task_id -> list of {"round": i, "metric": name, "value": v, ...}
    _rows: dict = field(default_factory=lambda: defaultdict(list))

    def log(self, task_id: int, round_idx: int, **metrics):
        for k, v in metrics.items():
            self._rows[task_id].append(
                {"round": round_idx, "metric": k, "value": float(v)})

    def series(self, task_id: int, metric: str):
        """-> (rounds, values) for dashboard plots."""
        rows = [r for r in self._rows[task_id] if r["metric"] == metric]
        rows.sort(key=lambda r: r["round"])
        return ([r["round"] for r in rows], [r["value"] for r in rows])

    def latest(self, task_id: int, metric: str, default=None):
        _, vals = self.series(task_id, metric)
        return vals[-1] if vals else default

    def churn_summary(self, task_id: int) -> dict:
        """Aggregate the per-round churn telemetry the sync server logs
        (``n_selected`` / ``n_survived`` / ``n_dropped`` / ``recovery_s``
        plus ``round_voided`` for all-dropped rounds) into fleet-health
        numbers for the dashboard: totals, the realized dropout rate, and
        the cumulative mask-recovery time."""
        _, selected = self.series(task_id, "n_selected")
        _, survived = self.series(task_id, "n_survived")
        _, dropped = self.series(task_id, "n_dropped")
        _, recovery = self.series(task_id, "recovery_s")
        _, voided = self.series(task_id, "round_voided")
        total_sel = int(sum(selected))
        return {
            "rounds": len(selected),
            "selected": total_sel,
            "survived": int(sum(survived)),
            "dropped": int(sum(dropped)),
            "dropout_rate": (float(sum(dropped)) / total_sel
                             if total_sel else 0.0),
            "recovery_s": float(sum(recovery)),
            "rounds_voided": int(sum(voided)),
        }

    def fleet_summary(self, task_ids=None) -> dict:
        """Cross-task fleet view: per-task round/churn totals plus the
        fleet-wide aggregate — what a FLaaS operator watches across every
        tenant, not one task's series."""
        ids = sorted(self._rows) if task_ids is None else list(task_ids)
        per_task = {tid: self.churn_summary(tid) for tid in ids}
        total = {k: 0 for k in ("rounds", "selected", "survived", "dropped",
                                "rounds_voided")}
        recovery = 0.0
        for s in per_task.values():
            for k in total:
                total[k] += s[k]
            recovery += s["recovery_s"]
        total["recovery_s"] = recovery
        total["dropout_rate"] = (total["dropped"] / total["selected"]
                                 if total["selected"] else 0.0)
        return {"tasks": len(ids), "per_task": per_task, "fleet": total}

    def to_json(self, task_id: int) -> str:
        return json.dumps(self._rows[task_id])
