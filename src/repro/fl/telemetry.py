"""Metrics backing the Florida dashboard / task view (paper §3.3):
per-round training metrics, evaluation metrics, and run-time performance.

Two layers:

:class:`MetricsStore`
    The per-task round-series store the dashboard plots. Rows keep their
    RAW values — numerics are floated for the series math, but string /
    structured context (``stage2_route``, void reasons) survives instead
    of crashing ``float()`` — and the whole store round-trips through
    :meth:`save`/:meth:`load` (JSON with a wall-clock +
    ``benchmarks.common.host_info()`` header) byte-identically.

:class:`MetricsRegistry`
    Typed operational meters replacing free-form dict rows: counters
    (monotonic — ``jit_cache_misses``, ``rounds_completed``), gauges
    (last-value — ``epsilon_spent``), histograms with FIXED bucket edges
    (``round_duration_s``, ``upload_bytes_per_client``, ``lease_seconds``)
    so cross-run snapshots are mergeable and dashboards never re-bucket.
    Labels are kwargs (``registry.counter("rounds_voided", task=3)``);
    one (name, labels) pair is one meter, and re-declaring a name with a
    different type raises.
"""
from __future__ import annotations

import json
import time
from collections import defaultdict
from dataclasses import dataclass, field

# fixed histogram bucket edges per well-known metric (upper bounds of the
# first len(edges) buckets; one overflow bucket past the last edge)
FIXED_BUCKETS = {
    "round_duration_s": (0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0,
                         60.0, 120.0),
    "upload_bytes_per_client": (1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9),
    "lease_seconds": (1.0, 5.0, 15.0, 60.0, 300.0, 1800.0, 3600.0),
    "recovery_s": (1e-3, 1e-2, 0.1, 1.0, 10.0),
}
DEFAULT_BUCKETS = (1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0, 1000.0)


@dataclass
class Counter:
    """Monotonic count. ``inc`` rejects negative deltas — a decreasing
    'counter' is a bug the registry should surface, not smooth over."""
    value: float = 0.0

    def inc(self, v: float = 1.0):
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float):
        self.value = float(v)


@dataclass
class Histogram:
    """Fixed-edge histogram: ``counts[i]`` counts observations <=
    ``edges[i]`` (cumulative-free, per-bucket), ``counts[-1]`` the
    overflow past the last edge."""
    edges: tuple = DEFAULT_BUCKETS
    counts: list = None
    total: float = 0.0
    count: int = 0

    def __post_init__(self):
        self.edges = tuple(float(e) for e in self.edges)
        if list(self.edges) != sorted(self.edges):
            raise ValueError("histogram edges must be sorted")
        if self.counts is None:
            self.counts = [0] * (len(self.edges) + 1)

    def observe(self, v: float):
        v = float(v)
        for i, e in enumerate(self.edges):
            if v <= e:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.total += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Typed meter registry. Meters are plain dataclasses, so the whole
    registry pickles with the CLI session file."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        # (name, (("label", value), ...)) -> (kind, meter)
        self._meters: dict = {}

    def _get(self, kind: str, name: str, labels: dict, **kw):
        key = (name, tuple(sorted(labels.items())))
        hit = self._meters.get(key)
        if hit is not None:
            if hit[0] != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {hit[0]}, "
                    f"requested as {kind}")
            return hit[1]
        meter = self._KINDS[kind](**kw)
        self._meters[key] = (kind, meter)
        return meter

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, edges=None, **labels) -> Histogram:
        if edges is None:
            edges = FIXED_BUCKETS.get(name, DEFAULT_BUCKETS)
        return self._get("histogram", name, labels, edges=tuple(edges))

    def value(self, name: str, default=None, **labels):
        """Scalar read: counter/gauge value, histogram mean."""
        hit = self._meters.get((name, tuple(sorted(labels.items()))))
        if hit is None:
            return default
        kind, meter = hit
        return meter.mean if kind == "histogram" else meter.value

    def snapshot(self) -> list:
        """Sorted, JSON-ready rows — the ``florida status`` payload."""
        rows = []
        for (name, labels), (kind, meter) in sorted(self._meters.items()):
            row = {"name": name, "labels": dict(labels), "kind": kind}
            if kind == "histogram":
                row.update(count=meter.count, sum=meter.total,
                           mean=meter.mean, edges=list(meter.edges),
                           buckets=list(meter.counts))
            else:
                row["value"] = meter.value
            rows.append(row)
        return rows


def _host_info() -> dict:
    """``benchmarks.common.host_info()`` when the benchmarks package is
    importable (it lives outside ``src``), else a stdlib-only subset —
    the save header must never make the service layer depend on the
    bench tree."""
    try:
        from benchmarks.common import host_info
        return host_info()
    except Exception:
        import os
        import platform
        return {"platform": platform.platform(),
                "machine": platform.machine(),
                "python": platform.python_version(),
                "cpu_count": os.cpu_count()}


@dataclass
class MetricsStore:
    # task_id -> list of {"round": i, "metric": name, "value": v, ...}
    _rows: dict = field(default_factory=lambda: defaultdict(list))

    def log(self, task_id: int, round_idx: int, **metrics):
        for k, v in metrics.items():
            # numerics are floated (series math); anything else is kept
            # RAW — the old unconditional float() silently dropped string
            # context like stage2_route at the caller (or crashed)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                try:
                    v = float(v)
                except (TypeError, ValueError):
                    pass
            else:
                v = float(v)
            self._rows[task_id].append(
                {"round": round_idx, "metric": k, "value": v})

    def series(self, task_id: int, metric: str):
        """-> (rounds, values) for dashboard plots (numeric rows only)."""
        rows = [r for r in self._rows[task_id] if r["metric"] == metric
                and isinstance(r["value"], (int, float))]
        rows.sort(key=lambda r: r["round"])
        return ([r["round"] for r in rows], [r["value"] for r in rows])

    def latest(self, task_id: int, metric: str, default=None):
        _, vals = self.series(task_id, metric)
        return vals[-1] if vals else default

    def churn_summary(self, task_id: int) -> dict:
        """Aggregate the per-round churn telemetry the sync server logs
        (``n_selected`` / ``n_survived`` / ``n_dropped`` / ``recovery_s``
        plus ``round_voided`` for all-dropped rounds) into fleet-health
        numbers for the dashboard: totals, the realized dropout rate, and
        the cumulative mask-recovery time."""
        _, selected = self.series(task_id, "n_selected")
        _, survived = self.series(task_id, "n_survived")
        _, dropped = self.series(task_id, "n_dropped")
        _, recovery = self.series(task_id, "recovery_s")
        _, voided = self.series(task_id, "round_voided")
        total_sel = int(sum(selected))
        return {
            "rounds": len(selected),
            "selected": total_sel,
            "survived": int(sum(survived)),
            "dropped": int(sum(dropped)),
            "dropout_rate": (float(sum(dropped)) / total_sel
                             if total_sel else 0.0),
            "recovery_s": float(sum(recovery)),
            "rounds_voided": int(sum(voided)),
        }

    def fleet_summary(self, task_ids=None) -> dict:
        """Cross-task fleet view: per-task round/churn totals plus the
        fleet-wide aggregate — what a FLaaS operator watches across every
        tenant, not one task's series."""
        ids = sorted(self._rows) if task_ids is None else list(task_ids)
        per_task = {tid: self.churn_summary(tid) for tid in ids}
        total = {k: 0 for k in ("rounds", "selected", "survived", "dropped",
                                "rounds_voided")}
        recovery = 0.0
        for s in per_task.values():
            for k in total:
                total[k] += s[k]
            recovery += s["recovery_s"]
        total["recovery_s"] = recovery
        total["dropout_rate"] = (total["dropped"] / total["selected"]
                                 if total["selected"] else 0.0)
        return {"tasks": len(ids), "per_task": per_task, "fleet": total}

    def to_json(self, task_id: int) -> str:
        return json.dumps(self._rows[task_id])

    # -- whole-store persistence ------------------------------------------

    def save(self, path: str, *, now: float | None = None,
             host: dict | None = None) -> str:
        """Persist EVERY task's rows (the old ``to_json`` exported one
        task and nothing else). Header: wall clock + host metadata so a
        saved store is attributable. ``now``/``host`` are injectable for
        reproducible bytes (the round-trip test)."""
        now = time.time() if now is None else float(now)
        payload = {
            "version": 1,
            "saved_at_unix": round(now, 3),
            "saved_at": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                      time.gmtime(now)),
            "host": _host_info() if host is None else host,
            "tasks": {str(tid): self._rows[tid]
                      for tid in sorted(self._rows)},
        }
        with open(path, "w") as f:
            json.dump(payload, f, sort_keys=True, separators=(",", ":"))
        return path

    @classmethod
    def load(cls, path: str) -> "MetricsStore":
        """Inverse of :meth:`save`; the parsed header lands on
        ``store.header``. ``load(p).save(q)`` with the header's
        ``saved_at_unix``/``host`` re-injected is byte-identical to the
        original file."""
        with open(path) as f:
            payload = json.load(f)
        store = cls()
        for tid, rows in payload.get("tasks", {}).items():
            store._rows[int(tid)] = rows
        store.header = {k: payload[k] for k in
                        ("version", "saved_at_unix", "saved_at", "host")
                        if k in payload}
        return store
