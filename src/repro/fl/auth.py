"""Authentication Service (paper §3.1.5): device attestation.

Production Florida validates Google Play Integrity verdicts and Huawei
SysIntegrity responses through the vendor services; here the trusted
third-party verdict is an HMAC-SHA256-signed token with the same fields and
the same accept/reject semantics (MEETS_DEVICE_INTEGRITY etc.)."""
from __future__ import annotations

import hashlib
import hmac
import json
import time
from dataclasses import dataclass

VALID_VERDICTS = ("MEETS_DEVICE_INTEGRITY", "MEETS_STRONG_INTEGRITY")
REJECT_VERDICTS = ("MEETS_BASIC_INTEGRITY", "NO_INTEGRITY")


def _sign(payload: bytes, key: bytes) -> str:
    return hmac.new(key, payload, hashlib.sha256).hexdigest()


@dataclass
class AttestationAuthority:
    """Stands in for the vendor integrity service (issues verdicts)."""
    key: bytes = b"play-integrity-root-key"

    def issue(self, device_id: str, verdict: str = "MEETS_DEVICE_INTEGRITY",
              os: str = "android") -> dict:
        body = {"device_id": device_id, "verdict": verdict, "os": os,
                "issued_at": time.time()}
        payload = json.dumps(body, sort_keys=True).encode()
        return {"body": body, "signature": _sign(payload, self.key)}


class AuthenticationService:
    """Validates attestation certificates before task participation."""

    def __init__(self, authority_key: bytes = b"play-integrity-root-key",
                 max_age_s: float = 3600.0):
        self.key = authority_key
        self.max_age_s = max_age_s
        self.rejections = 0

    def verify(self, certificate: dict) -> bool:
        try:
            body = certificate["body"]
            payload = json.dumps(body, sort_keys=True).encode()
            if not hmac.compare_digest(_sign(payload, self.key),
                                       certificate["signature"]):
                self.rejections += 1
                return False
            if body["verdict"] not in VALID_VERDICTS:
                self.rejections += 1
                return False
            if time.time() - body["issued_at"] > self.max_age_s:
                self.rejections += 1
                return False
            return True
        except (KeyError, TypeError):
            self.rejections += 1
            return False
