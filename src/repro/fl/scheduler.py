"""Round scheduler: the FLaaS control plane (paper §3.1.1 at fleet scope).

The paper's pitch is many tenants submitting tasks to one service over one
device fleet. Pre-refactor, ``begin_round`` was caller-driven: whoever
held the service decided when each task's round started, and nothing
arbitrated between tasks competing for the same devices. The
:class:`ControlPlane` owns that decision:

- it holds MANY tasks (all inside one shared ``ManagementService``, whose
  ``SelectionService`` views one shared ``DeviceDirectory``);
- :meth:`grant_round` picks WHICH ready task's round starts next —
  **priority tiers** first (a higher ``TaskConfig.priority`` is always
  granted before a lower one), then **deficit-weighted round-robin**
  inside a tier: the task with the least ``lease_seconds / weight``
  (device-time consumed, normalized by its fair-share weight) goes next,
  so a big-cohort task cannot starve a small one — each round it runs
  charges it lease-seconds, pushing it behind the tasks it crowded out;
- :meth:`complete_round` closes a granted round: releases the cohort's
  device leases (charging the lease-seconds the fairness policy feeds on)
  and evaluates the task's stop criteria (``n_rounds`` / target metric /
  epsilon budget — ``ManagementService.check_stop``), publishing completed
  tasks to the model registry.

Async tasks are not round-granted: FedBuff steps whenever its buffer
fills, driven by client submissions, and async clients hold no leases —
the no-overlap invariant the directory enforces is about SYNC cohorts
(a blocking training session with a cohort barrier).

A single task driven through ``grant_round``/``complete_round`` is
bit-identical to calling ``begin_round``/``submit_cohort`` directly: the
scheduler adds arbitration, not protocol steps.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro import tracing
from repro.fl.server import ManagementService
from repro.fl.task import TaskRecord, TaskStatus


@dataclass
class RoundGrant:
    task_id: int
    round_idx: int
    cohort: list


class ControlPlane:
    def __init__(self, service: ManagementService | None = None,
                 seed: int = 0):
        self.service = service if service is not None \
            else ManagementService(seed=seed)
        self.directory = self.service.directory
        self.registry = self.service.registry
        self._active: dict[int, RoundGrant] = {}   # task_id -> open grant
        self._deferred: dict[int, float] = {}      # task_id -> retry-at t
        self.rounds_granted: dict[int, int] = {}

    # -- task management (thin lifecycle wrappers) ------------------------
    def create_task(self, config, initial_model,
                    user: str = "default-user") -> int:
        """Create WITHOUT deploying — the control-plane lifecycle is
        CREATED -> deploy() -> RUNNING -> stop criteria -> COMPLETED."""
        return self.service.create_task(config, initial_model, user=user,
                                        deploy=False)

    def deploy(self, task_id: int, user: str = "default-user"):
        self.service.deploy_task(task_id, user=user)

    def pause(self, task_id: int, user: str = "default-user"):
        """Pause aborts any in-flight round (the service releases its
        leases) and forgets the grant — the scheduler moves straight on to
        other tasks, never waiting on a paused task's round."""
        self.service.pause_task(task_id, user=user)
        self._active.pop(task_id, None)

    def resume(self, task_id: int, user: str = "default-user"):
        self.service.resume_task(task_id, user=user)

    def cancel(self, task_id: int, user: str = "default-user"):
        self.service.cancel_task(task_id, user=user)
        self._active.pop(task_id, None)

    def tasks(self) -> list:
        return self.service.list_tasks()

    def defer(self, task_id: int, until: float):
        """Back off granting to a task until virtual time ``until`` (e.g.
        the simulator found its whole cohort outside availability windows
        — retry after a deadline instead of spinning at one instant)."""
        self._deferred[task_id] = until

    # -- scheduling policy ------------------------------------------------
    def _policy(self, rec: TaskRecord):
        return (int(getattr(rec.config, "priority", 0)),
                float(getattr(rec.config, "weight", 1.0)) or 1.0)

    def _ready(self, rec: TaskRecord, now: float) -> bool:
        """A task can be granted a round: sync, RUNNING, no round in
        flight, not deferred, and the lease-free selectable pool still
        covers its target cohort (a task whose devices are leased to
        another task's round WAITS — it does not burn a round index on a
        short cohort)."""
        if rec.config.mode != "sync" or rec.status is not TaskStatus.RUNNING:
            return False
        if rec.task_id in self._active:
            return False
        if now < self._deferred.get(rec.task_id, float("-inf")):
            return False
        # counts, not materialized id lists — at fleet scale this readiness
        # probe runs per grant attempt and must stay O(fleet) numpy work
        n_pool = self.service.selection.n_available(rec)
        # under-provisioned tasks (fewer enrolled devices than the cohort
        # target) run short cohorts, exactly like the direct path — the
        # wait is only for devices leased AWAY, never for devices the task
        # never had
        need = min(rec.config.clients_per_round,
                   self.service.selection.n_registered(rec))
        return need > 0 and n_pool >= need

    def next_task(self, now: float | None = None):
        """The task the fairness policy grants next, or None if no sync
        task is ready. Highest priority tier first; within a tier, the
        lowest weighted lease-seconds deficit; task_id breaks ties."""
        now = self.directory.now if now is None else now
        ready = [t for t in self.service.list_tasks() if self._ready(t, now)]
        if not ready:
            return None
        spent = self.directory.lease_seconds

        def rank(rec):
            prio, weight = self._policy(rec)
            return (-prio, spent.get(rec.task_id, 0.0) / weight,
                    rec.task_id)

        return min(ready, key=rank).task_id

    # -- round lifecycle --------------------------------------------------
    def grant_round(self, now: float | None = None,
                    available=None) -> RoundGrant | None:
        """Grant the next round to the fairest ready task: advances the
        directory clock, runs the task's ``begin_round`` (selection
        acquires the cohort's leases at ``now``) and records the grant.
        Returns None when no sync task is ready."""
        if now is not None:
            self.directory.now = now
        with tracing.span("grant_round") as sp:
            tid = self.next_task(self.directory.now)
            if tid is None:
                return None
            round_idx, cohort = self.service.begin_round(
                tid, available=available)
            if not cohort:
                return None
            sp.set(task=tid, round=round_idx, n_cohort=len(cohort))
            grant = RoundGrant(tid, round_idx, list(cohort))
        self._active[tid] = grant
        self.rounds_granted[tid] = self.rounds_granted.get(tid, 0) + 1
        self.service.meters.counter("rounds_granted", task=tid).inc()
        return grant

    def active_grants(self) -> list:
        return [self._active[t] for t in sorted(self._active)]

    def active_grant(self, task_id: int):
        """The task's open grant, or None (e.g. after a pause aborted
        it) — the simulator drops stale round-end events with this."""
        return self._active.get(task_id)

    def next_deferred(self, now: float):
        """Earliest deferral expiry strictly after ``now`` among RUNNING
        sync tasks, or None — the simulator's idle-advance target when no
        events are pending."""
        times = []
        for rec in self.service.list_tasks():
            if rec.config.mode != "sync" \
                    or rec.status is not TaskStatus.RUNNING:
                continue
            t = self._deferred.get(rec.task_id)
            if t is not None and now < t < float("inf"):
                times.append(t)
        return min(times) if times else None

    def complete_round(self, task_id: int, now: float | None = None):
        """Close a granted round AFTER its submissions (or its void): set
        the clock to the round's end, release the cohort's leases —
        charging the task its lease-seconds — and evaluate stop criteria
        (COMPLETED tasks publish to the registry via the service)."""
        if now is not None:
            self.directory.now = now
        self._active.pop(task_id, None)
        rec = self.service.get_task(task_id)
        self.service.selection.reset_round(rec)
        self.service.meters.gauge("lease_seconds", task=task_id).set(
            self.directory.lease_seconds.get(task_id, 0.0))
        return self.service.check_stop(task_id)

    # -- telemetry --------------------------------------------------------
    def fairness(self) -> dict:
        """Per-task scheduling telemetry: priority, weight, raw and
        weight-normalized lease-seconds, rounds granted."""
        out = {}
        spent = self.directory.lease_seconds
        for rec in self.service.list_tasks():
            prio, weight = self._policy(rec)
            s = spent.get(rec.task_id, 0.0)
            out[rec.task_id] = {
                "priority": prio, "weight": weight,
                "lease_seconds": s, "normalized": s / weight,
                "rounds_granted": self.rounds_granted.get(rec.task_id, 0),
                "status": rec.status.value,
            }
        return out
