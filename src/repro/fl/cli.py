"""Florida CLI (paper §3.3: "a command-line interface for scripting service
and workflow management" with the same functionality as the web UI).

Because the container runs everything in-process, the CLI operates on a
*service session file*: commands construct/load a ManagementService whose
task state persists between invocations via the checkpoint module.

    PYTHONPATH=src python -m repro.fl.cli create --task-name spam \\
        --app-name spam-app --workflow train --clients-per-round 8 \\
        --rounds 5 [--dp local --noise 1.0 --clip 0.5] [--mode async]
    PYTHONPATH=src python -m repro.fl.cli list
    PYTHONPATH=src python -m repro.fl.cli deploy <task_id>
    PYTHONPATH=src python -m repro.fl.cli run <task_id> [...] --clients 16
    PYTHONPATH=src python -m repro.fl.cli show <task_id>
    PYTHONPATH=src python -m repro.fl.cli pause|resume|cancel <task_id>
    PYTHONPATH=src python -m repro.fl.cli metrics <task_id>
    PYTHONPATH=src python -m repro.fl.cli fleet
    PYTHONPATH=src python -m repro.fl.cli registry [--save-dir DIR]

``run`` with several task ids drives them CONCURRENTLY through the
:class:`~repro.fl.scheduler.ControlPlane` over one shared client
population (the multi-tenant path); with one id it uses the direct
single-task simulators, which the scheduler path reproduces bit-for-bit.
"""
from __future__ import annotations

import argparse
import os
import pickle
import sys

from repro.core.dp import DPConfig
from repro.fl import tracing
from repro.fl.dashboard import (render_fleet, render_metrics,
                                render_status, render_task_list,
                                render_task_view, render_trace)
from repro.fl.scheduler import ControlPlane
from repro.fl.server import ManagementService
from repro.fl.task import CompressionConfig, TaskConfig

DEFAULT_SESSION = os.environ.get("FLORIDA_SESSION",
                                 os.path.expanduser("~/.florida-session.pkl"))


def load_service(path=DEFAULT_SESSION) -> ManagementService:
    if os.path.exists(path):
        with open(path, "rb") as f:
            svc = pickle.load(f)
        # sessions saved before the observability layer grew these
        if not hasattr(svc, "meters"):
            from repro.fl.telemetry import MetricsRegistry
            svc.meters = MetricsRegistry()
        if not hasattr(svc, "flight"):
            svc.flight = None
        if not hasattr(svc, "_jit_snapshot"):
            svc._jit_snapshot = tracing.jit_cache_total()
        return svc
    return ManagementService()


def save_service(svc, path=DEFAULT_SESSION):
    with open(path, "wb") as f:
        pickle.dump(svc, f)


def cmd_create(svc, args):
    import jax
    from repro.configs import get_config
    from repro.models import classifier_init, init_params
    cfg = get_config("bert-tiny-spam").replace(vocab_size=1024, d_model=64,
                                               d_ff=128)
    key = jax.random.PRNGKey(args.seed)
    model = {"trunk": init_params(cfg, key),
             "head": classifier_init(cfg, jax.random.fold_in(key, 1))}
    dp = DPConfig(mechanism=args.dp, clip_norm=args.clip,
                  noise_multiplier=args.noise) if args.dp != "off" \
        else DPConfig()
    comp = CompressionConfig(kind="topk", frac=args.topk_frac,
                             error_feedback=not args.no_error_feedback) \
        if args.topk_frac > 0 else CompressionConfig()
    tc = TaskConfig(task_name=args.task_name, app_name=args.app_name,
                    workflow_name=args.workflow,
                    clients_per_round=args.clients_per_round,
                    n_rounds=args.rounds, strategy=args.strategy,
                    mode=args.mode, vg_size=args.vg_size, dp=dp,
                    compression=comp,
                    priority=args.priority, weight=args.weight,
                    epsilon_budget=args.epsilon_budget,
                    target_metric=args.target_metric,
                    target_value=args.target_value)
    tid = svc.create_task(tc, model, user=args.user,
                          deploy=not args.no_deploy)
    state = "created" if args.no_deploy else "created + deployed"
    print(f"{state} task {tid} ({args.task_name})")
    return tid


def _spam_world(model0=None):
    sys.path.insert(0, os.getcwd())
    from benchmarks.common import SpamWorld
    world = SpamWorld(vocab=1024, d_model=64, n_train=3000, n_splits=20,
                      frac=0.5)
    if model0 is not None:
        world.model0 = model0  # continue from the task's current snapshot
    return world


def cmd_run(svc, args):
    """Drive task(s) with simulated SDK clients (the CLI's test harness).
    One task id -> the direct single-task simulators; several -> the
    ControlPlane-scheduled multi-task simulator over one shared fleet.

    Tracing is ON by default for CLI runs (``--no-trace`` opts out): a
    collecting tracer records the full round span tree, the service gets
    a flight recorder next to the session file, and the run's Perfetto
    timeline is exported to ``<session>.flight/perfetto_run.json``."""
    if args.no_trace:
        _run_tasks(svc, args)
        return
    svc.flight = tracing.FlightRecorder(args.session + ".flight")
    with tracing.use_tracer(tracing.Tracer()) as tracer:
        try:
            _run_tasks(svc, args)
        finally:
            out = os.path.join(svc.flight.root, "perfetto_run.json")
            tracer.export_perfetto(out)
            print(f"trace: {tracer.n_spans} spans -> {out}")


def _run_tasks(svc, args):
    from repro.fl.simulator import (make_heterogeneous_clients,
                                    run_async_simulation,
                                    run_multi_task_simulation,
                                    run_sync_simulation)
    if len(args.task_id) == 1:
        task = svc.get_task(args.task_id[0])
        world = _spam_world(task.model)
        clients = make_heterogeneous_clients(args.clients, world.make_trainer)
        runner = (run_async_simulation if task.config.mode == "async"
                  else run_sync_simulation)
        res = runner(svc, args.task_id[0], clients,
                     eval_fn=world.test_accuracy)
        accs = [h.get("eval_accuracy") for h in res.metrics_history]
        print(f"task {args.task_id[0]}: {len(res.round_durations)} "
              f"iterations, acc {accs[0]:.3f} -> {accs[-1]:.3f}"
              if accs else "no rounds ran")
        return
    world = _spam_world()
    clients = make_heterogeneous_clients(args.clients, world.make_trainer)
    plane = ControlPlane(svc)
    for tid in args.task_id:
        if svc.get_task(tid).status.value == "created":
            plane.deploy(tid, user=args.user)
    res = run_multi_task_simulation(
        plane, clients,
        eval_fns={tid: world.test_accuracy for tid in args.task_id})
    for tid in args.task_id:
        r = res.per_task[tid]
        rec = svc.get_task(tid)
        print(f"task {tid}: {len(r.round_durations)} iterations, "
              f"status={rec.status.value}"
              + (f" (stop: {rec.stop_reason})" if rec.stop_reason else ""))
    if res.lease_overlaps:
        print(f"WARNING: {len(res.lease_overlaps)} overlapping sync leases")
    print(render_fleet(plane))


def cmd_registry(svc, args):
    reg = svc.registry
    if not len(reg):
        print("registry: no published models")
        return
    for e in reg.entries():
        eps = f" eps={e.epsilon:.2f}" if e.epsilon is not None else ""
        print(f"task {e.task_id} ({e.task_name}): {e.rounds_run} rounds, "
              f"stop={e.stop_reason}{eps}, "
              f"published_at={e.published_at:.1f}")
    if args.save_dir:
        reg.save(args.save_dir)
        print(f"saved {len(reg)} model(s) to {args.save_dir}")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="florida")
    ap.add_argument("--session", default=DEFAULT_SESSION)
    ap.add_argument("--user", default="default-user")
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("create")
    c.add_argument("--task-name", required=True)
    c.add_argument("--app-name", required=True)
    c.add_argument("--workflow", required=True)
    c.add_argument("--clients-per-round", type=int, default=8)
    c.add_argument("--rounds", type=int, default=5)
    c.add_argument("--strategy", default="fedavg",
                   choices=["fedavg", "fedavgm", "fedprox", "dga"])
    c.add_argument("--mode", default="sync", choices=["sync", "async"])
    c.add_argument("--vg-size", type=int, default=4)
    c.add_argument("--dp", default="off", choices=["off", "local", "global"])
    c.add_argument("--clip", type=float, default=0.5)
    c.add_argument("--noise", type=float, default=1.0)
    c.add_argument("--topk-frac", type=float, default=0.0,
                   help="top-k update compression: transmit this fraction "
                        "of the flat update per round (0 = dense)")
    c.add_argument("--no-error-feedback", action="store_true",
                   help="disable the per-client residual carry (plain "
                        "rand-k; diagnostics only)")
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--no-deploy", action="store_true",
                   help="leave the task CREATED (deploy it later)")
    c.add_argument("--priority", type=int, default=0)
    c.add_argument("--weight", type=float, default=1.0)
    c.add_argument("--epsilon-budget", type=float, default=None)
    c.add_argument("--target-metric", default=None)
    c.add_argument("--target-value", type=float, default=None)

    sub.add_parser("list")
    sub.add_parser("fleet")
    for name in ("show", "deploy", "pause", "resume", "cancel", "metrics"):
        p = sub.add_parser(name)
        p.add_argument("task_id", type=int)
    r = sub.add_parser("run")
    r.add_argument("task_id", type=int, nargs="+")
    r.add_argument("--clients", type=int, default=16)
    r.add_argument("--no-trace", action="store_true",
                   help="disable the flight recorder + Perfetto export "
                        "for this run")
    g = sub.add_parser("registry")
    g.add_argument("--save-dir", default=None)
    sub.add_parser("status")
    t = sub.add_parser("trace")
    t.add_argument("task_id", type=int)
    t.add_argument("--perfetto", default=None, metavar="OUT",
                   help="also rebuild a Perfetto trace_events JSON from "
                        "the task's flight records and write it here")

    args = ap.parse_args(argv)
    svc = load_service(args.session)
    if args.cmd == "create":
        cmd_create(svc, args)
    elif args.cmd == "list":
        print(render_task_list(svc))
    elif args.cmd == "fleet":
        print(render_fleet(ControlPlane(svc)))
    elif args.cmd == "deploy":
        svc.deploy_task(args.task_id, user=args.user)
        print(f"task {args.task_id} deployed")
    elif args.cmd == "registry":
        cmd_registry(svc, args)
    elif args.cmd == "show":
        print(render_task_view(svc, args.task_id))
    elif args.cmd == "metrics":
        print(render_metrics(svc, args.task_id))
    elif args.cmd == "pause":
        svc.pause_task(args.task_id, user=args.user)
        print(f"task {args.task_id} paused")
    elif args.cmd == "resume":
        svc.resume_task(args.task_id, user=args.user)
        print(f"task {args.task_id} resumed")
    elif args.cmd == "cancel":
        svc.cancel_task(args.task_id, user=args.user)
        print(f"task {args.task_id} cancelled")
    elif args.cmd == "run":
        cmd_run(svc, args)
    elif args.cmd == "status":
        print(render_status(svc))
    elif args.cmd == "trace":
        print(render_trace(svc, args.task_id))
        if args.perfetto:
            if svc.flight is None:
                print("no flight recorder: nothing to export")
            else:
                import json
                events = svc.flight.read(args.task_id)
                with open(args.perfetto, "w") as f:
                    json.dump(tracing.perfetto_from_flight(
                        events, args.task_id), f)
                print(f"wrote {args.perfetto} ({len(events)} rounds)")
    save_service(svc, args.session)


if __name__ == "__main__":
    main()
