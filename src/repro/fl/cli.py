"""Florida CLI (paper §3.3: "a command-line interface for scripting service
and workflow management" with the same functionality as the web UI).

Because the container runs everything in-process, the CLI operates on a
*service session file*: commands construct/load a ManagementService whose
task state persists between invocations via the checkpoint module.

    PYTHONPATH=src python -m repro.fl.cli create --task-name spam \\
        --app-name spam-app --workflow train --clients-per-round 8 \\
        --rounds 5 [--dp local --noise 1.0 --clip 0.5] [--mode async]
    PYTHONPATH=src python -m repro.fl.cli list
    PYTHONPATH=src python -m repro.fl.cli run <task_id> --clients 16
    PYTHONPATH=src python -m repro.fl.cli show <task_id>
    PYTHONPATH=src python -m repro.fl.cli pause|resume|cancel <task_id>
    PYTHONPATH=src python -m repro.fl.cli metrics <task_id>
"""
from __future__ import annotations

import argparse
import os
import pickle
import sys

from repro.core.dp import DPConfig
from repro.fl.dashboard import render_metrics, render_task_list, render_task_view
from repro.fl.server import ManagementService
from repro.fl.task import TaskConfig

DEFAULT_SESSION = os.environ.get("FLORIDA_SESSION",
                                 os.path.expanduser("~/.florida-session.pkl"))


def load_service(path=DEFAULT_SESSION) -> ManagementService:
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    return ManagementService()


def save_service(svc, path=DEFAULT_SESSION):
    with open(path, "wb") as f:
        pickle.dump(svc, f)


def cmd_create(svc, args):
    import jax
    from repro.configs import get_config
    from repro.models import classifier_init, init_params
    cfg = get_config("bert-tiny-spam").replace(vocab_size=1024, d_model=64,
                                               d_ff=128)
    key = jax.random.PRNGKey(args.seed)
    model = {"trunk": init_params(cfg, key),
             "head": classifier_init(cfg, jax.random.fold_in(key, 1))}
    dp = DPConfig(mechanism=args.dp, clip_norm=args.clip,
                  noise_multiplier=args.noise) if args.dp != "off" \
        else DPConfig()
    tc = TaskConfig(task_name=args.task_name, app_name=args.app_name,
                    workflow_name=args.workflow,
                    clients_per_round=args.clients_per_round,
                    n_rounds=args.rounds, strategy=args.strategy,
                    mode=args.mode, vg_size=args.vg_size, dp=dp)
    tid = svc.create_task(tc, model, user=args.user)
    print(f"created task {tid} ({args.task_name})")
    return tid


def cmd_run(svc, args):
    """Drive a task with simulated SDK clients (the CLI's test harness)."""
    sys.path.insert(0, os.getcwd())
    from benchmarks.common import SpamWorld
    from repro.fl.simulator import (make_heterogeneous_clients,
                                    run_async_simulation, run_sync_simulation)
    task = svc.get_task(args.task_id)
    world = SpamWorld(vocab=1024, d_model=64, n_train=3000, n_splits=20,
                      frac=0.5)
    world.model0 = task.model  # continue from the task's current snapshot
    clients = make_heterogeneous_clients(args.clients, world.make_trainer)
    runner = (run_async_simulation if task.config.mode == "async"
              else run_sync_simulation)
    res = runner(svc, args.task_id, clients, eval_fn=world.test_accuracy)
    accs = [h.get("eval_accuracy") for h in res.metrics_history]
    print(f"task {args.task_id}: {len(res.round_durations)} iterations, "
          f"acc {accs[0]:.3f} -> {accs[-1]:.3f}" if accs else "no rounds ran")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="florida")
    ap.add_argument("--session", default=DEFAULT_SESSION)
    ap.add_argument("--user", default="default-user")
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("create")
    c.add_argument("--task-name", required=True)
    c.add_argument("--app-name", required=True)
    c.add_argument("--workflow", required=True)
    c.add_argument("--clients-per-round", type=int, default=8)
    c.add_argument("--rounds", type=int, default=5)
    c.add_argument("--strategy", default="fedavg",
                   choices=["fedavg", "fedavgm", "fedprox", "dga"])
    c.add_argument("--mode", default="sync", choices=["sync", "async"])
    c.add_argument("--vg-size", type=int, default=4)
    c.add_argument("--dp", default="off", choices=["off", "local", "global"])
    c.add_argument("--clip", type=float, default=0.5)
    c.add_argument("--noise", type=float, default=1.0)
    c.add_argument("--seed", type=int, default=0)

    sub.add_parser("list")
    for name in ("show", "pause", "resume", "cancel", "metrics"):
        p = sub.add_parser(name)
        p.add_argument("task_id", type=int)
    r = sub.add_parser("run")
    r.add_argument("task_id", type=int)
    r.add_argument("--clients", type=int, default=16)

    args = ap.parse_args(argv)
    svc = load_service(args.session)
    if args.cmd == "create":
        cmd_create(svc, args)
    elif args.cmd == "list":
        print(render_task_list(svc))
    elif args.cmd == "show":
        print(render_task_view(svc, args.task_id))
    elif args.cmd == "metrics":
        print(render_metrics(svc, args.task_id))
    elif args.cmd == "pause":
        svc.pause_task(args.task_id, user=args.user)
        print(f"task {args.task_id} paused")
    elif args.cmd == "resume":
        svc.resume_task(args.task_id, user=args.user)
        print(f"task {args.task_id} resumed")
    elif args.cmd == "cancel":
        svc.cancel_task(args.task_id, user=args.user)
        print(f"task {args.task_id} cancelled")
    elif args.cmd == "run":
        cmd_run(svc, args)
    save_service(svc, args.session)


if __name__ == "__main__":
    main()
