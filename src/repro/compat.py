"""JAX version compatibility shims.

Supported range: JAX 0.4.x – 0.5.x. The repo pins 0.4.37 in the container,
but the mesh-introspection helpers below are written against the 0.5 API so
an upgrade is a no-op.

``get_abstract_mesh`` is the load-bearing shim: the §Perf
with-sharding-constraint helpers (models/model.py, models/attention.py,
models/moe.py, launch/fl_step.py) ask "is a mesh ambient, and which axes
does it have?" before pinning activation layouts. On JAX >= 0.5 that is
``jax.sharding.get_abstract_mesh()``; on 0.4.x the equivalent ambient-mesh
state for a ``with mesh:`` context lives at
``jax.interpreters.pxla.thread_resources.env.physical_mesh``. Both are
normalized to *None when unmeshed* so call sites stay a plain
``if mesh is None: return x`` no-op on CPU smoke tests.
"""
from __future__ import annotations

import jax


def get_abstract_mesh():
    """Return the ambient (abstract or physical) mesh, or None if unmeshed.

    The returned object — when not None — has ``axis_names`` and
    ``axis_sizes`` attributes on every supported JAX version; use
    :func:`mesh_axis_sizes` for a name->size dict.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            mesh = get()
        except Exception:
            mesh = None
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
        # fall through: 0.5's AbstractMesh() sentinel for "no mesh" has no
        # axes; a ``with mesh:`` context may still be visible below.
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or getattr(mesh, "empty", True):
        return None
    return mesh


def mesh_axis_sizes(mesh) -> dict:
    """{axis_name: size} for any mesh object returned above."""
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on >= 0.5,
    the mesh's own ``with mesh:`` context (physical_mesh) on 0.4.x. Either
    way :func:`get_abstract_mesh` sees it inside the block."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """Version-portable ``shard_map``: ``jax.shard_map`` where it exists
    (newer releases), else ``jax.experimental.shard_map.shard_map`` (the
    0.4.x–0.5.x home). ``check_rep`` defaults to False because the FL
    combine paths feed uint32 collectives (psum of limb states) whose
    replication rule the checker rejects on some versions."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_rep)
    except TypeError:
        pass
    try:
        # newer API renamed the flag (check_vma on 0.7+); keep the checker
        # OFF there too — dropping the flag would silently re-enable it
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_rep)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them
    (>= 0.5); 0.4.x meshes are implicitly Auto, so omitting is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if devices is None else {"devices": devices}
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names), **kw)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)
