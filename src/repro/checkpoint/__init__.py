from repro.checkpoint.checkpoint import (deserialize_pytree, load_checkpoint,
                                         save_checkpoint, serialize_pytree)

__all__ = ["deserialize_pytree", "load_checkpoint", "save_checkpoint",
           "serialize_pytree"]
