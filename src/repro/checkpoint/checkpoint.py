"""Pytree checkpointing: npz-serialized model snapshots.

Used both for durable checkpoints (train loop) and for the *model snapshot*
blobs the Florida server distributes to clients each round (paper §1: the
orchestrator "distribut[es] a model snapshot to a client ... running client
code to update the model").

Format: npz with flattened leaf arrays keyed "leaf_<i>" plus a json header
encoding the treedef path structure. Handles nested dicts/lists/tuples of
jnp/np arrays (the param structures used throughout this repo).
"""
from __future__ import annotations

import io
import json
import os

import jax
import numpy as np


def _paths_and_leaves(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = [jax.tree_util.keystr(p) for p, _ in leaves_with_paths]
    leaves = [np.asarray(v) for _, v in leaves_with_paths]
    return paths, leaves


def serialize_pytree(tree) -> bytes:
    paths, leaves = _paths_and_leaves(tree)
    buf = io.BytesIO()
    arrays = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    np.savez(buf, __paths__=np.frombuffer(
        json.dumps(paths).encode(), dtype=np.uint8), **arrays)
    return buf.getvalue()


def deserialize_pytree(blob: bytes, like=None):
    """If ``like`` is given, restore into its exact treedef; otherwise
    return {path: array}."""
    with np.load(io.BytesIO(blob)) as z:
        paths = json.loads(bytes(z["__paths__"]).decode())
        leaves = [z[f"leaf_{i}"] for i in range(len(paths))]
    if like is None:
        return dict(zip(paths, leaves))
    like_paths, _ = _paths_and_leaves(like)
    if like_paths != paths:
        raise ValueError("checkpoint structure mismatch")
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(path: str, tree, step: int | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = serialize_pytree(tree if step is None
                            else {"step": np.int64(step), "tree": tree})
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)  # atomic


def load_checkpoint(path: str, like=None, with_step=False):
    with open(path, "rb") as f:
        blob = f.read()
    if with_step:
        restored = deserialize_pytree(
            blob, {"step": np.int64(0), "tree": like} if like is not None
            else None)
        if like is not None:
            return restored["tree"], int(restored["step"])
        return restored
    return deserialize_pytree(blob, like)
