"""LR schedules (multiplicative factors on the base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: 1.0


def cosine_decay(total_steps, final_frac=0.1):
    def f(step):
        t = jnp.minimum(step / total_steps, 1.0)
        return final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return f


def warmup_cosine(warmup_steps, total_steps, final_frac=0.1):
    cos = cosine_decay(max(1, total_steps - warmup_steps), final_frac)
    def f(step):
        w = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup_steps, 0))
    return f
