"""SGD with optional momentum (client-side local steps / server FedAvgM)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def sgd(lr=0.1, momentum=0.0, schedule=None):
    def init(params):
        if momentum:
            return {"m": jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr if schedule is None else lr * schedule(step)
        if momentum:
            m = jax.tree.map(lambda m_, g: momentum * m_
                             + g.astype(jnp.float32), state["m"], grads)
            return (jax.tree.map(lambda m_: -lr_t * m_, m),
                    {"m": m, "step": step})
        return (jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads),
                {"step": step})

    return Optimizer(init, update)
