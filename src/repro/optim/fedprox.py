"""FedProx client optimizer (Li et al. 2018; paper lists FedProx among the
supported aggregation schemes): local SGD with the proximal term
mu/2 ||w - w_global||^2 added to the objective, i.e. gradient += mu (w - w0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def proximal_sgd(lr=0.1, mu=0.01):
    def init(params):
        # anchor = the round's global model
        return {"anchor": jax.tree.map(jnp.copy, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        prox = jax.tree.map(
            lambda p, a: mu * (p.astype(jnp.float32)
                               - a.astype(jnp.float32)),
            params, state["anchor"])
        g = jax.tree.map(lambda g_, x: g_.astype(jnp.float32) + x,
                         grads, prox)
        return (jax.tree.map(lambda g_: -lr * g_, g),
                {"anchor": state["anchor"], "step": state["step"] + 1})

    return Optimizer(init, update)
