from repro.optim.adamw import adamw
from repro.optim.fedprox import proximal_sgd
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
from repro.optim.sgd import sgd

__all__ = ["adamw", "proximal_sgd", "constant", "cosine_decay",
           "warmup_cosine", "sgd"]
