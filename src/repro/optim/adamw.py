"""AdamW (the paper's client optimizer in §5.1: transformers' default).

Functional optax-like interface:
    opt = adamw(lr=5e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          schedule=None, moment_dtype=None):
    """moment_dtype: jnp.bfloat16 halves optimizer-state memory (§Perf);
    update math still runs in f32."""
    def init(params):
        mdt = moment_dtype or jnp.float32
        zeros = jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=mdt), params)
        return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr if schedule is None else lr * schedule(step)
        mdt = moment_dtype or jnp.float32
        m = jax.tree.map(lambda m_, g: (b1 * m_.astype(jnp.float32)
                         + (1 - b1) * g.astype(jnp.float32)).astype(mdt),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: (b2 * v_.astype(jnp.float32)
                         + (1 - b2) * jnp.square(g.astype(jnp.float32)))
                         .astype(mdt), state["v"], grads)
        mh = jax.tree.map(lambda m_: m_.astype(jnp.float32)
                          / (1 - b1 ** step), m)
        vh = jax.tree.map(lambda v_: v_.astype(jnp.float32)
                          / (1 - b2 ** step), v)
        updates = jax.tree.map(
            lambda mh_, vh_: -lr_t * mh_ / (jnp.sqrt(vh_) + eps), mh, vh)
        if weight_decay and params is not None:
            updates = jax.tree.map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
                updates, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)
