"""Sharding rules (MaxText-style logical rules, shape-driven).

Parameter rule, given a leaf's shape (ignoring the stacked n_blocks leading
dim for scanned layers):

  - rank-3 expert weights (E, D, F): E -> "model" (expert parallelism:
    dispatch einsum becomes the all-to-all), D==d_model -> FSDP axis.
  - rank-2: the first dim equal to d_model -> FSDP axis; one remaining
    large divisible dim -> "model" (tensor parallelism).
  - rank-1 / small: replicated.

FSDP axis by FL scheme (DESIGN.md §6):
  per_silo: params replicated over data (each silo owns a full replica of
            its model-shard; pseudo-grads stay per-silo) -> FSDP axis = None,
            but OPTIMIZER state still shards over "data" (ZeRO-1).
  per_pod : params shard over "data" within a pod, replicate over "pod"
            (each pod is one silo running FSDP+TP internally).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _divisible(dim, size):
    return size > 1 and dim % size == 0 and dim >= size


def leaf_pspec(shape, cfg, mesh, *, fsdp_axis, stacked: bool):
    d_model = cfg.d_model
    model_ok = "model" in mesh.shape
    model_size = mesh.shape.get("model", 1)
    fsdp_size = mesh.shape.get(fsdp_axis, 1) if fsdp_axis else 1

    body = list(shape[1:]) if stacked else list(shape)
    spec = [None] * len(body)
    if len(body) >= 2:
        model_used = False
        fsdp_used = False
        # expert weights: dim0 == num_experts -> model axis
        if (len(body) == 3 and cfg.num_experts
                and body[0] == cfg.num_experts
                and _divisible(body[0], model_size)):
            spec[0] = "model"
            model_used = True
        for i, s in enumerate(body):
            if spec[i] is not None:
                continue
            if (not fsdp_used and fsdp_axis and s == d_model
                    and _divisible(s, fsdp_size)):
                spec[i] = fsdp_axis
                fsdp_used = True
        # one remaining largest divisible dim -> model
        if model_ok and not model_used:
            cands = [(s, i) for i, s in enumerate(body)
                     if spec[i] is None and _divisible(s, model_size)
                     and s >= 128]
            if cands:
                _, i = max(cands)
                spec[i] = "model"
    if stacked:
        spec = [None] + spec
    return P(*spec)


def _is_stacked(path) -> bool:
    s = jax.tree_util.keystr(path)
    return ("'blocks'" in s) or ("'encoder'" in s) or ("'decoder'" in s)


def params_pspecs(cfg, params_struct, mesh, *, scheme=None):
    """PartitionSpec pytree for the parameter pytree (or its eval_shape)."""
    scheme = scheme or cfg.fl_scheme
    fsdp_axis = "data" if scheme == "per_pod" else None

    def rule(path, leaf):
        return leaf_pspec(leaf.shape, cfg, mesh, fsdp_axis=fsdp_axis,
                          stacked=_is_stacked(path))

    return jax.tree_util.tree_map_with_path(rule, params_struct)


def opt_pspecs(cfg, params_struct, mesh):
    """Optimizer moments always FSDP over 'data' (ZeRO-1), both schemes."""
    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        return leaf_pspec(leaf.shape, cfg, mesh, fsdp_axis="data",
                          stacked=_is_stacked(path))
    return jax.tree_util.tree_map_with_path(rule, params_struct)


def batch_pspecs(cfg, batch_struct, mesh, *, silo_blocked: bool):
    """Batch arrays: leading dim over the data axes when divisible (small
    batches — e.g. long_500k's global_batch=1 — replicate instead)."""
    d_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    d_size = 1
    for a in d_axes:
        d_size *= mesh.shape[a]

    def rule(leaf):
        lead = d_axes if leaf.shape[0] % d_size == 0 and \
            leaf.shape[0] >= d_size else None
        spec = [lead] + [None] * (leaf.ndim - 1)
        return P(*spec)

    return jax.tree.map(rule, batch_struct)


def silo_batch_pspecs(cfg, batch_struct, mesh, scheme):
    """Training batches blocked (n_silos, per_silo_B, S, ...).

    per_silo: silo dim over (pod, data); inner batch unsharded.
    per_pod : silo dim over (pod,); inner batch over data (FSDP grouping).
    """
    if scheme == "per_silo":
        lead = tuple(a for a in ("pod", "data") if a in mesh.shape)
        inner = None
    else:
        lead = ("pod",) if "pod" in mesh.shape else None
        inner = "data"

    def rule(leaf):
        spec = [lead, inner] + [None] * (leaf.ndim - 2)
        return P(*spec)

    return jax.tree.map(rule, batch_struct)


def cache_pspecs(cfg, cache_struct, mesh, batch_size: int):
    """Decode caches: batch over (pod, data) when divisible; the KV-cache
    sequence dim over 'model' (flash-decode style: partial softmax + small
    cross-shard reductions); SSM state heads/d_inner over 'model'."""
    d_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    d_size = 1
    for a in d_axes:
        d_size *= mesh.shape[a]
    model_size = mesh.shape.get("model", 1)
    batch_axes = d_axes if batch_size % max(d_size, 1) == 0 and \
        batch_size >= d_size else None

    def rule(path, leaf):
        s = jax.tree_util.keystr(path)
        if leaf.ndim == 0:   # index scalar
            return P()
        if "'k'" in s or "'v'" in s:
            # (n_blocks, B, S, KV, hd). Preference order:
            #   1. KV heads over 'model' when divisible (classic TP decode:
            #      attention fully local, no softmax psum)
            #   2. else sequence over 'model' (flash-decode partials)
            #   3. B=1 long-context: sequence over (data, model)
            spec = [None, batch_axes, None, None, None]
            if leaf.ndim >= 4 and _divisible(leaf.shape[3], model_size):
                spec[3] = "model"
                if not batch_axes and _divisible(leaf.shape[2], d_size):
                    spec[2] = "data"
                return P(*spec[:leaf.ndim])
            seq_axes = ("model",) if batch_axes else ("data", "model")
            seq_size = model_size
            if not batch_axes:
                seq_size = model_size * d_size
            if leaf.shape[2] % seq_size == 0 and leaf.shape[2] >= seq_size:
                spec[2] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            elif leaf.shape[2] % model_size == 0 \
                    and leaf.shape[2] >= model_size:
                spec[2] = "model"
            return P(*spec[:leaf.ndim])
        if "'S'" in s:
            # rwkv state (n_blocks, B, H, hd, hd)
            spec = [None, batch_axes, None, None, None]
            if leaf.shape[2] % model_size == 0:
                spec[2] = "model"
            return P(*spec[:leaf.ndim])
        if "'h'" in s or "'conv'" in s:
            # mamba (n_blocks, B, d_in, n) / (n_blocks, B, c, d_in)
            spec = [None, batch_axes] + [None] * (leaf.ndim - 2)
            for i in range(2, leaf.ndim):
                if leaf.shape[i] % model_size == 0 and leaf.shape[i] >= 1024:
                    spec[i] = "model"
                    break
            return P(*spec)
        # last_tm/last_cm (n_blocks, B, d)
        spec = [None, batch_axes] + [None] * (leaf.ndim - 2)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_struct)


def to_shardings(mesh, pspecs):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
