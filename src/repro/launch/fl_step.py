"""The production FL round — ONE jitted function lowered in the dry-run.

    per-silo local step (grad of the LM loss on the silo's batch)
      -> [bf16 pseudo-gradient]
      -> quantize (uint32 fixed point)                     [paper §4.1]
      -> + net pairwise mask within the silo's VG          [paper §4.1]
      -> stage-1: modular uint32 sum over each VG          [paper §3.1.2]
      -> stage-2: hierarchical master combine over VGs     [paper §3.1.3]
         (per-pod limb-state accumulators + exact cross-pod merge — the
         SAME combine implementation as the cross-device master in
         ``repro.core.quantize``; under the per_pod scheme it runs as a
         ``compat.shard_map`` over the mesh's "pod" axis with the merge
         lowered to one uint32 psum)
      -> server AdamW update (FedOpt-style master logic)

The whole protocol runs PER LEAF of the gradient pytree (never raveled:
concatenating differently-sharded leaves would force an all-gather of the
full model). Counter-mode KDF masks make this exact: each leaf gets a
disjoint stream-offset range, and each element's mask word is addressed by
its global flat index — so masks agree across silos regardless of how the
leaf is sharded. The silo axis is the leading batch dim, sharded over the
mesh's data axes, so the stage-1/stage-2 sums lower to grouped integer
collectives — the paper's communication pattern, visible in the compiled
HLO and counted by the roofline's collective term.

Schemes (DESIGN.md §6):
  per_silo: n_silos = pod*data axis size; params replicated across silos
            (sharded over "model" only); optimizer state ZeRO-1 over data.
  per_pod : a silo = one pod running FSDP+TP internally; n_silos = pod
            axis size; masks apply to the silo's *sharded* pseudo-gradient.
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.kdf import U32, mask_stream, pair_seed
from repro.core.quantize import (MAX_MASTER_GROUPS, carry_normalize,
                                 check_headroom, check_master_headroom,
                                 check_shard_headroom, dequantize_limb_state,
                                 interim_limb_state, merge_limb_states,
                                 min_master_shards, quantize,
                                 shard_limb_states)
from repro.models import loss_fn
from repro.optim import adamw
from repro.optim.adamw import apply_updates

_log = logging.getLogger(__name__)


def n_silos_for(cfg, mesh) -> int:
    if cfg.fl_scheme == "per_pod":
        return mesh.shape.get("pod", 1)
    return mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)


# --------------------------------------------------------------------------
# per-leaf masking with global flat indices
# --------------------------------------------------------------------------

def _flat_index(shape):
    """uint32 global flat index array of ``shape`` (row-major)."""
    idx = jnp.zeros(shape, U32)
    for k in range(len(shape)):
        idx = idx * U32(shape[k]) + jax.lax.broadcasted_iota(U32, shape, k)
    return idx


def leaf_net_mask(i, vg_id, vg_size: int, round_seed, shape, offset: int):
    """Net pairwise mask for one leaf, shaped like the leaf (not flat)."""
    from repro.core.kdf import kdf_u32
    peers = jnp.asarray(vg_id, U32) * U32(vg_size) + jnp.arange(
        vg_size, dtype=U32)
    i = jnp.asarray(i, U32)
    # counters wrap mod 2^32 — cancellation only needs both pair members to
    # agree on each element's counter, which wrapping preserves. (Production
    # note: >4.3B-param models reuse counter values across the stream; a
    # 64-bit counter KDF removes that — recorded in DESIGN.md.)
    ctr = _flat_index(shape) + U32(offset & 0xFFFFFFFF)

    def one(peer):
        lo = jnp.minimum(i, peer)
        hi = jnp.maximum(i, peer)
        seed = pair_seed(round_seed, lo, hi)
        m = kdf_u32(seed[0], seed[1], ctr)
        signed = jnp.where(i < peer, m, jnp.zeros((), U32) - m)
        return jnp.where(peer == i, jnp.zeros((), U32), signed)

    # NOTE §Perf hillclimb 3: a fori_loop variant (one live mask buffer)
    # was tried and REFUTED — it blocks elementwise fusion of the
    # quantize+mask chain and grew device memory 64.8 -> 70.9 GiB.
    acc = jnp.zeros(shape, U32)
    for j in range(vg_size):
        acc = acc + one(peers[j])
    return acc


def leaf_offsets(params_struct):
    """Disjoint stream-offset per leaf (static ints, row-major order)."""
    import math
    leaves = jax.tree.leaves(params_struct)
    offsets, acc = [], 0
    for leaf in leaves:
        offsets.append(acc)
        acc += math.prod(leaf.shape) if leaf.shape else 1
    treedef = jax.tree.structure(params_struct)
    return jax.tree.unflatten(treedef, offsets)


def hierarchical_master_combine(interim, n_total: int, clip: float,
                                bits: int, *, n_shards: int = 1,
                                pod_axis: str | None = None, mesh=None):
    """Stage 2, shared with the cross-device master (``repro.core.quantize``):
    fold disjoint VG shards into per-pod limb states (tier 1, exact for
    < 2^16 VGs per shard), merge exactly across shards (tier 2, < 2^16
    shards), dequantize the cohort total ONCE.

    ``interim``: (n_vgs, *leaf_shape) uint32 exact per-VG sums;
    ``n_total``: total silo count (the mean's denominator). With
    ``pod_axis`` set (per_pod scheme under a mesh whose pod axis divides
    n_vgs) the tier-1 fold runs per pod under ``compat.shard_map`` and the
    tier-2 merge is one uint32 ``psum`` over the pod axis — the paper's
    tree-combine, visible as a single integer collective in the HLO.
    Every sharding (including n_shards=1) is bit-identical: canonical
    limb digits don't depend on how the VG axis is partitioned. A
    ``n_shards`` that does not divide n_vgs zero-pads the VG axis (an
    exact no-op in the integer sums); the shard_map route does require
    the pod axis to divide n_vgs (its input spec blocks the leading
    axis)."""
    n_vgs = interim.shape[0]
    if pod_axis is not None and mesh is not None:
        from jax.sharding import PartitionSpec as P
        p = mesh.shape[pod_axis]
        check_shard_headroom(p)
        check_master_headroom(n_vgs // p)

        def local(ishard):                 # (n_vgs/p, *leaf_shape) per pod
            state = interim_limb_state(ishard)
            merged = carry_normalize(jax.lax.psum(state, pod_axis))
            return dequantize_limb_state(merged, n_total, clip, bits)

        pad = [None] * (interim.ndim - 1)
        return compat.shard_map(local, mesh=mesh,
                                in_specs=P(pod_axis, *pad),
                                out_specs=P(*pad))(interim)
    check_shard_headroom(n_shards)
    check_master_headroom(-(-n_vgs // n_shards))
    states = shard_limb_states(interim, n_shards)
    return dequantize_limb_state(merge_limb_states(states), n_total, clip,
                                 bits)


def _build_pack_axes(cfg, mesh):
    """Per-leaf axis for packed aggregation: an even-sized axis the param
    pspec leaves UNSHARDED (local pairing; -1 = leaf not packable)."""
    from repro.launch import input_specs as ispec
    from repro.launch import sharding as shd
    aparams = ispec.abstract_params(cfg)
    pspecs = shd.params_pspecs(cfg, aparams, mesh)
    from jax.sharding import PartitionSpec as P

    def axis_for(leaf, spec):
        shape = leaf.shape
        for ax in range(len(shape) - 1, -1, -1):
            entry = spec[ax] if ax < len(spec) else None
            if entry is None and shape[ax] % 2 == 0 and shape[ax] >= 2:
                return ax
        return -1

    flat_specs = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_leaves = jax.tree.leaves(aparams)
    axes = [axis_for(l, s) for l, s in zip(flat_leaves, flat_specs)]
    return jax.tree.unflatten(jax.tree.structure(aparams), axes)


# --------------------------------------------------------------------------
# the round
# --------------------------------------------------------------------------

def _mb_constraint(cfg):
    """Keep the per-microbatch batch dim sharded over 'data' after the
    (B,) -> (mb, B/mb) reshape — GSPMD otherwise replicates the activations
    (measured: jamba train went 64x batch-replicated, 324 GiB/device).
    Only the per_pod scheme shards the inner batch dim."""
    if cfg.fl_scheme != "per_pod":
        return lambda x: x
    mesh = compat.get_abstract_mesh()
    if mesh is None or "data" not in getattr(mesh, "axis_names", ()):
        return lambda x: x

    def f(x):
        spec = jax.sharding.PartitionSpec(
            None, "data", *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)

    return f


def _silo_grad(cfg, params, silo_batch, microbatches: int):
    """Mean loss+grad over one silo's batch with grad-accumulation scan."""

    def mb_loss(p, b):
        return loss_fn(cfg, p, b)

    if microbatches <= 1:
        loss, g = jax.value_and_grad(mb_loss)(params, silo_batch)
        return loss, jax.tree.map(lambda a: a.astype(jnp.bfloat16), g)

    constrain = _mb_constraint(cfg)

    def split(x):
        b = x.shape[0]
        return constrain(
            x.reshape(microbatches, b // microbatches, *x.shape[1:]))

    mbs = jax.tree.map(split, silo_batch)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(mb_loss)(params, mb)
        g_acc = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
        return (loss_acc + loss, g_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mbs)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(
        lambda a: (a * inv).astype(jnp.bfloat16), g)


def make_fl_train_step(cfg, mesh, *, vg_size: int | None = None,
                       bits: int = 18, clip: float = 0.05,
                       microbatches: int | None = None,
                       server_lr: float = 1e-3,
                       secure: bool = True,
                       packed: bool = False,
                       local_steps: int = 1,
                       client_lr: float = 1e-2):
    """Build fl_round(params, opt_state, batch, round_seed) for this mesh.

    Batch arrays are silo-blocked: (n_silos, per_silo_B, ...).
    ``secure=False`` is the ablation baseline: plain f32 mean, no
    quantize/mask (what a non-FL data-parallel step would do).
    ``packed=True``: beyond-paper packed modular aggregation — two 13-bit
    codes per uint32 carrier; masks apply to packed words; HALVES
    secure-agg traffic, exact for vg_size <= 8 (paper §7 names compression
    under secure aggregation as an open problem).
    ``local_steps > 1``: FedAvg-style multi-step local training per silo —
    the silo batch splits into ``local_steps`` SGD steps at ``client_lr``
    (via ``repro.core.cohort_engine.make_local_update``) and the uploaded
    pseudo-gradient is the negated param delta; supersedes ``microbatches``
    (the local-step scan already bounds live activations the same way).
    """
    from repro.core.quantize import (PACK_FIELD_BITS, check_pack_headroom)
    n_silos = n_silos_for(cfg, mesh)
    vg_size = vg_size or min(8, n_silos)
    if n_silos % vg_size:
        vg_size = n_silos  # degenerate: one VG
    n_vgs = n_silos // vg_size
    if packed:
        bits = min(bits, 13)
        check_pack_headroom(bits, vg_size)
    check_headroom(bits, vg_size)
    # stage-2 sharding over the mesh's pod axis: per_pod consumes the same
    # hierarchical merge as the cross-device master. The shard_map route
    # needs the pod axis to divide the VG axis AND the per-pod shard to
    # fit the tier-1 bound; otherwise fall back to the bit-identical
    # zero-padded form (GSPMD lowers the tree), keeping enough shards for
    # headroom even when the pod count doesn't divide n_vgs.
    n_pods = mesh.shape.get("pod", 1)
    divisible = n_vgs % n_pods == 0
    pod_axis = ("pod" if cfg.fl_scheme == "per_pod" and "pod" in mesh.shape
                and divisible and n_vgs // n_pods < MAX_MASTER_GROUPS
                else None)
    stage2_shards = max(n_pods if divisible else 1, min_master_shards(n_vgs))
    # which stage-2 lowering actually won: the explicit shard_map over the
    # pod axis, or the bit-identical zero-padded form GSPMD lowers. Launch
    # scripts read meta["stage2_route"]; the log line is the operator's
    # one-glance check that a topology change didn't silently demote the
    # route (e.g. a pod count that stops dividing n_vgs).
    stage2_route = ("shard_map_pod" if pod_axis is not None
                    else "zero_padded_shards")
    _log.info("fl_step stage-2 route: %s (n_vgs=%d, n_pods=%d, "
              "divisible=%s, shards=%d)", stage2_route, n_vgs, n_pods,
              divisible, stage2_shards)
    check_master_headroom(-(-n_vgs // stage2_shards))
    check_shard_headroom(stage2_shards)
    microbatches = microbatches or cfg.train_microbatches
    pack_axes = _build_pack_axes(cfg, mesh) if packed else None
    if cfg.fl_scheme == "per_pod" and cfg.activation_batch_axes is None:
        cfg = cfg.replace(activation_batch_axes=("data",))
    if cfg.fl_scheme == "per_silo" and cfg.shard_attn_heads is None:
        cfg = cfg.replace(shard_attn_heads=True)

    def fl_round(params, opt_state, batch, round_seed):
        round_seed = round_seed.astype(U32)
        offsets = leaf_offsets(params)
        nonlocal pack_axes
        if pack_axes is None:
            pack_axes = jax.tree.map(lambda _: -1, offsets)

        if local_steps > 1:
            from repro.core.cohort_engine import (LocalTrainSpec,
                                                  make_local_update)
            from repro.optim import sgd
            local_up = make_local_update(LocalTrainSpec(
                loss_fn=lambda p, b: loss_fn(cfg, p, b),
                optimizer=sgd(client_lr), local_steps=local_steps))
            constrain = _mb_constraint(cfg)

            def one_silo(silo_batch):
                def split(x):
                    b = x.shape[0]
                    if b % local_steps:
                        raise ValueError(
                            f"per-silo batch {b} not divisible by "
                            f"local_steps={local_steps}")
                    return constrain(x.reshape(local_steps, b // local_steps,
                                               *x.shape[1:]))

                delta, mloss = local_up(params,
                                        jax.tree.map(split, silo_batch))
                return mloss, jax.tree.map(
                    lambda d: (-d).astype(jnp.bfloat16), delta)
        else:
            def one_silo(silo_batch):
                return _silo_grad(cfg, params, silo_batch, microbatches)

        losses, grads = jax.vmap(one_silo)(batch)  # leaves: (n_silos, ...)

        silo_ids = jnp.arange(n_silos, dtype=U32)
        vg_ids = silo_ids // U32(vg_size)

        def aggregate_leaf(g, offset, pack_ax):
            # g: (n_silos, *leaf_shape) bf16 pseudo-gradients
            leaf_shape = g.shape[1:]
            if not secure:
                return jnp.mean(g.astype(jnp.float32), axis=0)
            # Packing requires a SHARDING-LOCAL pairing: flatten-pack and
            # stride-2 on a sharded dim both trigger GSPMD resharding
            # (measured 24.7 -> 107.7 / 128.1 GiB on gemma2). Pack adjacent
            # pairs along an axis the param pspec leaves UNSHARDED.
            do_pack = packed and pack_ax >= 0
            if do_pack:
                q = quantize(g, clip, bits)
                ax = pack_ax + 1  # + silo dim
                lo = jax.lax.slice_in_dim(q, 0, None, 2, axis=ax)
                hi = jax.lax.slice_in_dim(q, 1, None, 2, axis=ax)
                q = lo | (hi << U32(PACK_FIELD_BITS))
                mask_shape = q.shape[1:]
            else:
                q = quantize(g, clip, bits)           # (n_silos, ...)
                mask_shape = leaf_shape

            def protect(i, vg, qi):
                return qi + leaf_net_mask(i, vg, vg_size, round_seed,
                                          mask_shape, offset)

            payloads = jax.vmap(protect)(silo_ids, vg_ids, q)
            grouped = payloads.reshape(n_vgs, vg_size, *mask_shape)
            interim = jnp.sum(grouped, axis=1, dtype=U32)   # stage 1
            if do_pack:
                lo = interim & U32(0xFFFF)
                hi = interim >> U32(PACK_FIELD_BITS)
                interim = jnp.stack([lo, hi], axis=pack_ax + 2).reshape(
                    n_vgs, *leaf_shape)
            return hierarchical_master_combine(         # stage 2 (tree)
                interim, n_silos, clip, bits, n_shards=stage2_shards,
                pod_axis=pod_axis, mesh=mesh)

        agg_grad = jax.tree.map(aggregate_leaf, grads, offsets, pack_axes)

        opt = adamw(lr=server_lr,
                    moment_dtype=jnp.bfloat16 if cfg.opt_moments_bf16
                    else None)
        updates, opt_state_new = opt.update(agg_grad, opt_state, params)
        new_params = apply_updates(params, updates)
        return new_params, opt_state_new, jnp.mean(losses)

    return fl_round, dict(n_silos=n_silos, vg_size=vg_size, n_vgs=n_vgs,
                          bits=bits, clip=clip, microbatches=microbatches,
                          local_steps=local_steps,
                          stage2_shards=stage2_shards,
                          stage2_pod_axis=pod_axis,
                          stage2_route=stage2_route)
