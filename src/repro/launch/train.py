"""End-to-end FL training driver (deliverable b's e2e example backend).

Runs REAL steps (not a dry-run) of the production fl_round on whatever
devices exist — on this CPU container use a reduced arch + host mesh:

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
        --steps 30 --global-batch 16 --seq-len 64

On a TPU slice the same entry point takes the full config + production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import save_checkpoint
from repro.configs import get_config, get_reduced_config
from repro.configs.shapes import InputShape
from repro.data import lm_batches, lm_dataset
from repro.launch import input_specs as ispec
from repro.launch import sharding as shd
from repro.launch.fl_step import make_fl_train_step, n_silos_for
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.optim import adamw


def make_silo_batches(cfg, n_silos, per_silo, seq_len, seed=0):
    stream = lm_dataset(n_tokens=max(200_000, 4 * n_silos * per_silo
                                     * (seq_len + 1)),
                        vocab_size=cfg.vocab_size, seed=seed)
    it = lm_batches(stream, n_silos * per_silo, seq_len, seed=seed)
    if cfg.frontend == "vision_stub" or cfg.encoder_decoder:
        raise SystemExit("use a text arch for the LM training driver")
    while True:
        b = next(it)
        yield {k: v.reshape(n_silos, per_silo, *v.shape[1:])
               for k, v in b.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--vg-size", type=int, default=None)
    ap.add_argument("--server-lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--insecure", action="store_true")
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    n_silos = n_silos_for(cfg, mesh)
    assert args.global_batch % n_silos == 0
    per_silo = args.global_batch // n_silos

    with compat.set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw().init(params)
        fl_round, meta = make_fl_train_step(
            cfg, mesh, vg_size=args.vg_size, server_lr=args.server_lr,
            secure=not args.insecure, microbatches=1)
        shape = InputShape("train", args.seq_len, args.global_batch, "train")
        p_sp = shd.params_pspecs(cfg, params, mesh)
        o_sp = shd.opt_pspecs(cfg, opt_state, mesh)
        step = jax.jit(fl_round,
                       in_shardings=(shd.to_shardings(mesh, p_sp),
                                     shd.to_shardings(mesh, o_sp),
                                     None, None),
                       out_shardings=(shd.to_shardings(mesh, p_sp),
                                      shd.to_shardings(mesh, o_sp), None))
        gen = make_silo_batches(cfg, n_silos, per_silo, args.seq_len)
        print(f"[train] {cfg.name} scheme={cfg.fl_scheme} "
              f"silos={meta['n_silos']} vg={meta['vg_size']} "
              f"mesh={dict(mesh.shape)}")
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
            seed = jnp.asarray(
                np.random.RandomState(i).randint(0, 2**31, 2), jnp.uint32)
            params, opt_state, loss = step(params, opt_state, batch, seed)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"[train] round {i}: loss={float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")
        if args.checkpoint:
            save_checkpoint(args.checkpoint, params, step=args.steps)
            print(f"[train] checkpoint -> {args.checkpoint}")
    return float(loss)


if __name__ == "__main__":
    main()
