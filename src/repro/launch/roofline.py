"""Roofline analysis from compiled HLO (deliverable g).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), and every layer stack here is a ``lax.scan`` — so this module
parses ``compiled.as_text()`` directly and aggregates recursively through
while loops using their ``known_trip_count`` backend config:

  flops            : 2 * prod(result_shape) * prod(contracting dims) per dot
                     (fusion subcomputations traversed; elementwise flops
                     ignored — documented, dots dominate at these sizes)
  memory bytes     : sum over top-level ops of operand+result bytes
                     (post-fusion: fusion internals never touch HBM, so
                     top-level operands/results are the HBM traffic proxy)
  collective bytes : operand bytes of all-reduce / all-gather /
                     reduce-scatter / all-to-all / collective-permute
                     (operand size = bytes each device actually sends)

Terms (TPU v5e): compute = flops / 197e12, memory = bytes / 819e9,
collective = coll_bytes / 50e9. All per-chip (the HLO is the per-device
SPMD program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT )?%([\w\.\-]+) = (\(.*?\)|\S+?\[[^\]]*\]\S*) "
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%([\w\.\-]+)\s*\((.*?)\)\s*->.*{")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\(.*?\)|\S+?\[[^\]]*\])")


def shape_bytes(type_str: str) -> int:
    """bytes of 'f32[2,3]{...}' or tuple '(f32[2], u32[4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the '(' of operands
    operands: list = field(default_factory=list)

    @property
    def result_bytes(self):
        return shape_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # value name -> type_str


def _split_operands(rest: str):
    """operand list = %names before the closing paren at depth 0."""
    depth, out, cur = 0, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return [o.strip().lstrip("%") for o in out if o.strip().startswith("%")]


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if not line.strip():
            continue
        m = _COMP_RE.match(line)
        if m and not line.startswith(" "):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            for pname, ptype in _PARAM_RE.findall(m.group(2)):
                cur.shapes[pname] = ptype
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(mo.group(1), mo.group(2), mo.group(3), mo.group(4))
            op.operands = _split_operands(op.rest)
            cur.ops.append(op)
            cur.shapes[op.name] = op.type_str
        if line.startswith("}") and not line.startswith("  "):
            cur = None
    return {"computations": comps, "entry": entry}


def _trip_count(op: Op) -> int:
    m = re.search(r'known_trip_count[\'":{\s]+n[\'":\s]+(\d+)', op.rest)
    if m:
        return int(m.group(1))
    return 1


def _called(op: Op, attr: str):
    m = re.search(attr + r"=%([\w\.\-]+)", op.rest)
    return m.group(1) if m else None


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = 1
    for d in shape_dims(op.type_str):
        out_elems *= d
    lhs = op.operands[0] if op.operands else None
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
    contract = 1
    if lhs and lhs in comp.shapes and m:
        dims = shape_dims(comp.shapes[lhs])
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


_SKIP_MEM = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "partition-id", "replica-id", "after-all", "copy-start",
             "copy-done", "iota"}
_CONTROL = {"while", "conditional", "call"}
# ops that touch only slice/result-sized memory, NOT their full operand
# (counting the whole operand of a scan's per-step dynamic-slice would
# overcount traffic by the trip count — measured 3 orders of magnitude on
# jamba's selective scan before this fix)
_RESULT_SIZED = {"dynamic-slice", "gather", "broadcast", "slice", "reshape",
                 "transpose", "reverse", "pad"}
_UPDATE_SIZED = {"dynamic-update-slice", "scatter"}  # in-place update ops


def analyze_computation(comps, name, memo):
    if name in memo:
        return memo[name]
    comp = comps[name]
    flops = mem = coll = 0.0
    coll_by_kind: dict = {}
    n_coll = 0
    for op in comp.ops:
        base = op.opcode.replace("-done", "").replace("-start", "")
        if op.opcode == "while":
            body = _called(op, "body")
            cond = _called(op, "condition")
            trips = _trip_count(op)
            for sub in (body, cond):
                if sub and sub in comps:
                    s = analyze_computation(comps, sub, memo)
                    flops += trips * s["flops"]
                    mem += trips * s["memory_bytes"]
                    coll += trips * s["collective_bytes"]
                    n_coll += trips * s["n_collectives"]
                    for k, v in s["collective_by_kind"].items():
                        coll_by_kind[k] = coll_by_kind.get(k, 0) + trips * v
            continue
        if op.opcode in ("conditional", "call", "async-start"):
            sub = (_called(op, "to_apply") or _called(op, "called_computation")
                   or _called(op, "calls"))
            if sub and sub in comps:
                s = analyze_computation(comps, sub, memo)
                flops += s["flops"]
                mem += s["memory_bytes"]
                coll += s["collective_bytes"]
                n_coll += s["n_collectives"]
                for k, v in s["collective_by_kind"].items():
                    coll_by_kind[k] = coll_by_kind.get(k, 0) + v
            continue
        if op.opcode == "fusion":
            sub = _called(op, "calls")
            if sub and sub in comps:
                # dots inside fusions still execute; memory is top-level only
                s = analyze_computation(comps, sub, memo)
                flops += s["flops"]
            # memory: recognize in-place slice-update / slice-read fusions —
            # XLA aliases the big buffer, so HBM traffic is slice-sized,
            # not buffer-sized (a 4096-trip scan writing per-step residuals
            # would otherwise be charged trips x full-buffer).
            opb_list = [shape_bytes(comp.shapes.get(o, ""))
                        for o in op.operands]
            big = max(opb_list) if opb_list else 0
            if ("dynamic-update-slice" in op.name
                    and big == op.result_bytes and big > 0):
                mem += 2 * (sum(b for b in opb_list if b != big)
                            + (opb_list.count(big) - 1) * big)
            elif "dynamic-slice" in op.name and big > op.result_bytes:
                mem += 2 * op.result_bytes + (sum(opb_list) - big)
            else:
                # operands vastly larger than the result are sliced reads
                # (a dynamic-slice fused into the consumer): cap at result
                capped = [min(b, op.result_bytes)
                          if op.result_bytes and b > 32 * op.result_bytes
                          else b for b in opb_list]
                mem += sum(capped) + op.result_bytes
            continue
        if op.opcode == "dot":
            flops += _dot_flops(comp, op)
        if base in COLLECTIVES or op.opcode in COLLECTIVES:
            if op.opcode.endswith("-done"):
                continue  # counted at -start
            opb = sum(shape_bytes(comp.shapes.get(o, "")) for o in
                      op.operands)
            opb = opb or op.result_bytes
            coll += opb
            n_coll += 1
            coll_by_kind[base] = coll_by_kind.get(base, 0) + opb
        if op.opcode in _RESULT_SIZED:
            mem += 2 * op.result_bytes            # read slice + write result
        elif op.opcode in _UPDATE_SIZED:
            upd = (shape_bytes(comp.shapes.get(op.operands[1], ""))
                   if len(op.operands) > 1 else op.result_bytes)
            mem += 2 * upd                        # in-place region rw
        elif op.opcode not in _SKIP_MEM and op.opcode not in _CONTROL:
            opb = sum(shape_bytes(comp.shapes.get(o, "")) for o in
                      op.operands)
            mem += opb + op.result_bytes
    out = {"flops": flops, "memory_bytes": mem, "collective_bytes": coll,
           "n_collectives": n_coll, "collective_by_kind": coll_by_kind}
    memo[name] = out
    return out


def analyze_hlo(text: str) -> dict:
    mod = parse_module(text)
    memo: dict = {}
    entry = mod["entry"]
    stats = analyze_computation(mod["computations"], entry, memo)
    return dict(stats)


# --------------------------------------------------------------------------
# roofline terms
# --------------------------------------------------------------------------

def model_flops(cfg, shape, n_chips: int) -> float:
    """Analytic 'useful' FLOPs per chip: 6*N_active*tokens (train),
    2*N_active*tokens (prefill/decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per = 2.0
    else:  # decode: ONE token per sequence
        tokens = shape.global_batch * 1
        per = 2.0
    return per * n_active * tokens / n_chips


def roofline_terms(hlo_stats: dict, cfg, shape, n_chips: int) -> dict:
    compute_s = hlo_stats["flops"] / PEAK_FLOPS_BF16
    memory_s = hlo_stats["memory_bytes"] / HBM_BW
    collective_s = hlo_stats["collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_chips)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops": hlo_stats["flops"],
        "useful_flops_ratio": (mf / hlo_stats["flops"]
                               if hlo_stats["flops"] else 0.0),
        "collective_by_kind": hlo_stats["collective_by_kind"],
        "n_collectives": hlo_stats["n_collectives"],
        "memory_bytes": hlo_stats["memory_bytes"],
        "collective_bytes": hlo_stats["collective_bytes"],
    }


def format_report(name: str, terms: dict) -> str:
    t = terms
    return (f"{name}: compute={t['compute_s']:.4f}s "
            f"memory={t['memory_s']:.4f}s collective={t['collective_s']:.4f}s "
            f"dominant={t['dominant']} useful={t['useful_flops_ratio']:.2f}")
