"""Batched decode driver (deliverable b): prefill a prompt batch then decode
tokens with the KV cache, on a host mesh (reduced config) or the production
mesh (full config, real TPU).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config, get_reduced_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import decode_step, init_cache, init_params
from repro.models.model import forward_hidden


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    if cfg.encoder_decoder:
        raise SystemExit("whisper decode is out of scope (DESIGN.md)")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    B = args.batch
    max_len = args.prompt_len + args.new_tokens

    with compat.set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = rng.randint(1, cfg.vocab_size, (B, args.prompt_len))
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.frontend == "vision_stub":
            batch["patches"] = jnp.asarray(
                rng.randn(B, cfg.num_patch_tokens, cfg.d_model) * 0.02,
                jnp.float32)
        cache = init_cache(cfg, B, max_len)

        # prefill by stepping tokens through the cache (cache-faithful path)
        step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, cache = step(params, cache, batch["tokens"][:, t:t + 1])
        prefill_s = time.time() - t0

        out_tokens = []
        key = jax.random.PRNGKey(7)
        t0 = time.time()
        for _ in range(args.new_tokens):
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)[:, None]
            else:
                nxt = jnp.argmax(logits, axis=-1)[:, None]
            out_tokens.append(np.asarray(nxt))
            logits, cache = step(params, cache, nxt.astype(jnp.int32))
        decode_s = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: batch={B} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"[serve] prefill {prefill_s:.2f}s, decode {decode_s:.2f}s "
          f"({B * args.new_tokens / max(decode_s, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generations: {gen[:2].tolist()}")
    return gen


if __name__ == "__main__":
    main()
