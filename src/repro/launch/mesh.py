"""Production mesh construction (devops persona).

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 = (2, 16, 16) = ("pod", "data", "model").

Defined as functions (NOT module constants) so importing never touches jax
device state; ``dryrun.py`` sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh for CPU smoke tests (1 real device)."""
    n = len(jax.devices())
    return compat.make_mesh((n // model_parallel, model_parallel),
                            ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh):
    """Axes the batch/silo dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
