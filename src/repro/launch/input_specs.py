"""ShapeDtypeStruct stand-ins for every model input of every (arch x shape)
pair — shardable, weak-type-correct, no device allocation (deliverable e.2).

Shape-kind semantics:
  train   : one FL round; batch silo-blocked (n_silos, per_silo_B, ...).
  prefill : full-prompt forward, last-token logits.
  decode  : ONE new token against a KV cache of shape.seq_len.

Per-arch adaptations (DESIGN.md §Arch-applicability):
  whisper: seq_len = ENCODER frame count (stub embeddings); decoder length
           min(448, seq//8); decode shapes skipped (448-position decoder).
  llava  : 2880 stub patch embeddings + (seq_len - 2880) text tokens.
  full-attention archs at long_500k decode: sliding-window variant
           (window = cfg.long_context_window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.fl_step import n_silos_for

I32 = jnp.int32
F32 = jnp.float32
BF16 = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def whisper_decoder_len(cfg, seq_len: int) -> int:
    return min(cfg.max_decoder_len, max(32, seq_len // 8))


def train_batch_specs(cfg, shape, mesh):
    """Silo-blocked training batch structs."""
    n_silos = n_silos_for(cfg, mesh)
    assert shape.global_batch % n_silos == 0, (shape.name, n_silos)
    b = shape.global_batch // n_silos
    s = shape.seq_len
    if cfg.encoder_decoder:
        sd = whisper_decoder_len(cfg, s)
        return {
            "frames": sds((n_silos, b, s, cfg.d_model), BF16),
            "tokens": sds((n_silos, b, sd), I32),
            "targets": sds((n_silos, b, sd), I32),
            "mask": sds((n_silos, b, sd), F32),
        }
    if cfg.frontend == "vision_stub":
        p = cfg.num_patch_tokens
        st = s - p
        assert st > 0
        return {
            "patches": sds((n_silos, b, p, cfg.d_model), BF16),
            "tokens": sds((n_silos, b, st), I32),
            "targets": sds((n_silos, b, st), I32),
            "mask": sds((n_silos, b, st), F32),
        }
    return {
        "tokens": sds((n_silos, b, s), I32),
        "targets": sds((n_silos, b, s), I32),
        "mask": sds((n_silos, b, s), F32),
    }


def prefill_batch_specs(cfg, shape):
    b, s = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        sd = whisper_decoder_len(cfg, s)
        return {"frames": sds((b, s, cfg.d_model), BF16),
                "tokens": sds((b, sd), I32)}
    if cfg.frontend == "vision_stub":
        p = cfg.num_patch_tokens
        return {"patches": sds((b, p, cfg.d_model), BF16),
                "tokens": sds((b, s - p), I32)}
    return {"tokens": sds((b, s), I32)}


def decode_token_specs(cfg, shape):
    return sds((shape.global_batch, 1), I32)


def abstract_params(cfg, dtype=BF16):
    from repro.models import init_params
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def abstract_cache(cfg, shape, dtype=BF16):
    from repro.models import init_cache
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype))


def abstract_opt_state(params_struct, cfg=None):
    from repro.optim import adamw
    mdt = BF16 if (cfg is not None and cfg.opt_moments_bf16) else None
    return jax.eval_shape(lambda p: adamw(moment_dtype=mdt).init(p),
                          params_struct)


def round_seed_spec():
    return sds((2,), jnp.uint32)


def input_specs(cfg, shape, mesh=None, kind=None):
    """The full input-struct dict for the step lowered at (cfg, shape)."""
    kind = kind or shape.kind
    if kind == "train":
        assert mesh is not None
        params = abstract_params(cfg)
        return {
            "params": params,
            "opt_state": abstract_opt_state(params, cfg),
            "batch": train_batch_specs(cfg, shape, mesh),
            "round_seed": round_seed_spec(),
        }
    if kind == "prefill":
        return {"params": abstract_params(cfg),
                "batch": prefill_batch_specs(cfg, shape)}
    if kind == "decode":
        return {"params": abstract_params(cfg),
                "cache": abstract_cache(cfg, shape),
                "tokens": decode_token_specs(cfg, shape)}
    raise ValueError(kind)
