"""Launch layer (devops persona): mesh construction, sharding rules,
multi-pod dry-run, roofline analysis, train/serve drivers.

NOTE: do not import ``repro.launch.dryrun`` from library code — it sets
XLA_FLAGS at import time (device-count override) by design.
"""
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_host_mesh, make_production_mesh)

__all__ = ["make_host_mesh", "make_production_mesh", "PEAK_FLOPS_BF16",
           "HBM_BW", "ICI_BW"]
