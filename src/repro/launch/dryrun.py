import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) combination against the production mesh
— 16x16 single-pod and 2x16x16 multi-pod — and record memory / cost /
collective analysis for the roofline.

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init); this module is therefore the process entry point:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import ASSIGNED, get_config, get_shape, is_skipped  # noqa: E402
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.fl_step import make_fl_train_step  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze_hlo, roofline_terms  # noqa: E402
from repro.models import decode_step, prefill_logits  # noqa: E402


def needs_window_override(cfg) -> bool:
    """Full-attention archs need the sliding-window variant for long_500k."""
    return (not cfg.ssm_type and not cfg.local_global_alternate
            and cfg.sliding_window == 0)


def lower_pair(cfg, shape, mesh, *, secure=True, microbatches=None,
               vg_size=None, packed=False, donate=True, extra_tag=""):
    """-> (lowered, meta) for one (arch, shape) on one mesh."""
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    if shape.kind == "train":
        fl_round, fl_meta = make_fl_train_step(
            cfg, mesh, secure=secure, microbatches=microbatches,
            vg_size=vg_size, packed=packed)
        specs = ispec.input_specs(cfg, shape, mesh, "train")
        p_sh = shd.to_shardings(mesh, shd.params_pspecs(
            cfg, specs["params"], mesh))
        o_sh = shd.to_shardings(mesh, shd.opt_pspecs(
            cfg, specs["opt_state"], mesh))
        b_sh = shd.to_shardings(mesh, shd.silo_batch_pspecs(
            cfg, specs["batch"], mesh, cfg.fl_scheme))
        seed_sh = NamedSharding(mesh, P())
        lowered = jax.jit(
            fl_round,
            in_shardings=(p_sh, o_sh, b_sh, seed_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
            # params/opt-state buffers are consumed by the update — without
            # donation the old and new copies coexist (§Perf: measured
            # ~31 GiB on llama4-400b: 2x (params bf16 + adam moments f32))
            donate_argnums=(0, 1) if donate else (),
        ).lower(specs["params"], specs["opt_state"], specs["batch"],
                ispec.round_seed_spec())
        return lowered, dict(fl_meta, n_chips=n_chips)

    if shape.kind == "prefill":
        # §Perf: same GSPMD propagation pins as training (batch dim +
        # attention heads), measured on the train hillclimbs
        if cfg.activation_batch_axes is None:
            cfg = cfg.replace(activation_batch_axes=("pod", "data"))
        if cfg.shard_attn_heads is None:
            cfg = cfg.replace(shard_attn_heads=True)
        specs = ispec.input_specs(cfg, shape, mesh=None, kind="prefill")
        p_sh = shd.to_shardings(mesh, shd.params_pspecs(
            cfg, specs["params"], mesh, scheme="per_pod"))
        b_sh = shd.to_shardings(mesh, shd.batch_pspecs(
            cfg, specs["batch"], mesh, silo_blocked=False))

        def step(params, batch):
            return prefill_logits(cfg, params, batch)

        lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
            specs["params"], specs["batch"])
        return lowered, dict(n_chips=n_chips)

    # decode — do NOT pin heads: the KV-cache sequence dim owns the
    # 'model' axis (flash-decode layout); pinning heads there too forces
    # per-layer cache regathers (measured neutral-to-negative)
    if cfg.shard_attn_heads is None:
        cfg = cfg.replace(shard_attn_heads=False)
    wo = cfg.long_context_window if (shape.name == "long_500k"
                                     and needs_window_override(cfg)) else None
    specs = ispec.input_specs(cfg, shape, mesh=None, kind="decode")
    p_sh = shd.to_shardings(mesh, shd.params_pspecs(
        cfg, specs["params"], mesh, scheme="per_pod"))
    c_sh = shd.to_shardings(mesh, shd.cache_pspecs(
        cfg, specs["cache"], mesh, shape.global_batch))
    t_sh = shd.to_shardings(mesh, shd.batch_pspecs(
        cfg, specs["tokens"], mesh, silo_blocked=False))

    def step(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, window_override=wo)

    lowered = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                      # serving updates the KV cache in place
                      donate_argnums=(1,) if donate else ()).lower(
        specs["params"], specs["cache"], specs["tokens"])
    return lowered, dict(n_chips=n_chips, window_override=wo)


def _mem_analysis_dict(compiled):
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def _parse_overrides(s: str | None) -> dict:
    """--override "moe_dispatch_constraint=True,train_microbatches=8"."""
    import ast
    out = {}
    for item in (s or "").split(","):
        if not item.strip():
            continue
        k, v = item.split("=", 1)
        out[k.strip()] = ast.literal_eval(v.strip())
    return out


def run_pair(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             *, secure=True, microbatches=None, vg_size=None, tag="",
             overrides=None, packed=False, donate=True):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    skip = is_skipped(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "secure": secure, "tag": tag}
    os.makedirs(outdir, exist_ok=True)
    fname = os.path.join(
        outdir, f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
    if skip:
        rec.update(status="skipped", reason=skip)
        json.dump(rec, open(fname, "w"), indent=1)
        print(f"[dryrun] SKIP {arch} x {shape_name}: {skip}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            lowered, meta = lower_pair(cfg, shape, mesh, secure=secure,
                                       microbatches=microbatches,
                                       vg_size=vg_size, packed=packed,
                                       donate=donate)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = _mem_analysis_dict(compiled)
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis() or {}
            text = compiled.as_text()
            hlo = analyze_hlo(text)
            terms = roofline_terms(hlo, cfg, shape, meta["n_chips"])
            _dump_hlo(outdir, arch, shape_name, mesh_kind, tag, text)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), meta=meta,
                   memory_analysis=mem,
                   cost_analysis={k: float(v) for k, v in ca.items()
                                  if isinstance(v, (int, float))},
                   roofline=_jsonable(terms))
        print(f"[dryrun] OK {arch} x {shape_name} x {mesh_kind}{tag} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
              f"dominant={terms['dominant']} "
              f"mem/device={mem.get('total_bytes_per_device', 0)/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_kind}: {e}")
    json.dump(rec, open(fname, "w"), indent=1)
    return rec


def _dump_hlo(outdir, arch, shape_name, mesh_kind, tag, text):
    """Gzip the compiled HLO so the roofline can be re-analyzed offline
    (no recompile) — experiments/dryrun/hlo/<pair>.txt.gz."""
    import gzip
    d = os.path.join(outdir, "hlo")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{arch}__{shape_name}__{mesh_kind}{tag}.txt.gz")
    with gzip.open(path, "wt") as f:
        f.write(text)


def _jsonable(d):
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out[k] = _jsonable(v)
        elif isinstance(v, (int, float, str, bool)) or v is None:
            out[k] = v
        else:
            out[k] = float(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--insecure", action="store_true",
                    help="ablation: skip quantize/mask (plain f32 mean)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--vg-size", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default=None)
    ap.add_argument("--packed", action="store_true",
                    help="packed modular aggregation (2x13-bit per word)")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all" else args.shape.split(","))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_pair(arch, shape, mesh_kind, args.out,
                               secure=not args.insecure,
                               microbatches=args.microbatches,
                               vg_size=args.vg_size, tag=args.tag,
                               overrides=_parse_overrides(args.override),
                               packed=args.packed, donate=not args.no_donate)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
