"""Flight-recorder tracing for the FL round pipeline (paper §3.3: the
dashboard's "where did this round spend its time" question, answered at
production depth instead of ad-hoc log strings).

Three instruments, stdlib-only so ``repro.core`` modules can import it
without dependency cycles:

``span``/``Tracer``
    A span-based tracer: ``with tracing.span("mask_apply", task_id=3):``
    opens a timed span (monotonic wall clock via ``perf_counter`` + CPU
    clock via ``process_time``) that nests under whatever span is open on
    the SAME thread — the per-thread stack makes the selection -> train ->
    DP -> quantize -> mask -> VG sum -> limb combine tree fall out of the
    call structure with no plumbing. Finished top-level spans collect on
    the tracer (lock-protected; safe with the simulator's threads) and
    export as Chrome/Perfetto ``trace_events`` JSON (:meth:`Tracer
    .to_perfetto`) for timeline inspection in ``ui.perfetto.dev``.

    The default tracer is a :class:`NullTracer` whose ``span()`` returns a
    shared no-op context manager — library callers pay one dict build and
    one method call per span site (``bench_trace`` pins the end-to-end
    cost at < 2% of a 256-client sync round, tracing ON; off is noise).

    Stages fused into ONE jitted dispatch (DP/quantize/mask/VG-sum inside
    ``privacy_engine._cohort_interims``) cannot be separately timed
    without breaking the one-program contract; ``Span.mark_fused`` emits
    them as child spans sharing the dispatch window, tagged
    ``fused=True`` — the timeline shows the real stage tree and is honest
    about what XLA fused.

``FlightRecorder``
    A per-task JSONL round transcript: every closed round appends one
    structured event (cohort ids, survivors, stage timings lifted from
    the round's span subtree, ``stage2_route``, ``n_shards``, void
    reason). Self-sufficient for post-hoc inspection: ``florida trace
    <task>`` renders transcripts, and ``perfetto_from_flight`` rebuilds a
    Perfetto timeline from the recorded stage offsets alone.

``jit_cache_sizes``
    The ``jit_cache_misses`` probe: sums ``_cache_size()`` over the
    repo's shared jitted entry points (module-level table + dynamically
    ``register_jit``-ed per-instance executables, e.g. a CohortEngine's
    vmapped cohort fn). A fixed-shape contract regression (async batch
    pad classes, streaming-wave width) shows up as a nonzero per-round
    delta — testable, not just benchmarkable.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any

# ----------------------------------------------------------------------
# spans
# ----------------------------------------------------------------------


@dataclass
class Span:
    """One timed region. ``t0``/``t1`` are ``perf_counter`` seconds
    (monotonic wall), ``cpu0``/``cpu1`` ``process_time`` seconds."""
    name: str
    attrs: dict = field(default_factory=dict)
    t0: float = 0.0
    t1: float = 0.0
    cpu0: float = 0.0
    cpu1: float = 0.0
    thread: int = 0
    children: list = field(default_factory=list)
    fused: bool = False
    _tracer: Any = None
    _fused_names: tuple = ()

    @property
    def wall_s(self) -> float:
        return self.t1 - self.t0

    @property
    def cpu_s(self) -> float:
        return self.cpu1 - self.cpu0

    def set(self, **attrs):
        """Attach attributes mid-span (e.g. a route decided after entry)."""
        self.attrs.update(attrs)
        return self

    def mark_fused(self, *names):
        """Declare stages that ran INSIDE this span's single compiled
        dispatch: on exit each becomes a child span sharing this span's
        window with ``fused=True`` (they cannot be separately timed
        without splitting the XLA program)."""
        self._fused_names = tuple(names)
        return self

    def __enter__(self):
        self.t0 = time.perf_counter()
        self.cpu0 = time.process_time()
        self.thread = threading.get_ident()
        self._tracer._push(self)
        return self

    def __exit__(self, *exc):
        self.t1 = time.perf_counter()
        self.cpu1 = time.process_time()
        for nm in self._fused_names:
            self.children.append(Span(
                name=nm, attrs={"fused": True}, t0=self.t0, t1=self.t1,
                cpu0=self.cpu0, cpu1=self.cpu1, thread=self.thread,
                fused=True))
        self._tracer._pop(self)
        return False


class _NullSpan:
    """Shared do-nothing span: the only object the default tracer hands
    out, so uninstrumented runs allocate nothing per span site."""
    __slots__ = ()
    fused = False
    name = ""
    attrs: dict = {}
    children: list = []
    wall_s = 0.0
    cpu_s = 0.0

    def set(self, **attrs):
        return self

    def mark_fused(self, *names):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Default tracer: every span is the shared no-op. ``enabled`` lets
    hot paths skip even attr-dict construction when they care."""
    enabled = False

    def span(self, name, **attrs):
        return _NULL_SPAN

    def roots(self):
        return []

    def clear(self):
        pass


class Tracer:
    """Collecting tracer. Thread-safe: each thread keeps its own open-span
    stack (nesting = call structure per thread); finished top-level spans
    append to a lock-protected list. ``max_spans`` bounds memory — spans
    past it are counted in ``n_dropped`` instead of stored."""
    enabled = True

    def __init__(self, max_spans: int = 200_000):
        self.max_spans = max_spans
        self.n_dropped = 0
        self.n_spans = 0
        self.epoch = time.perf_counter()     # perfetto ts origin
        self.epoch_unix = time.time()
        self._roots: list = []
        self._lock = threading.Lock()
        self._tls = threading.local()

    # pickle safety: the service layer is pickled by the CLI session file;
    # locks and thread-locals are not picklable and hold no data we keep
    def __getstate__(self):
        d = dict(self.__dict__)
        d.pop("_lock", None)
        d.pop("_tls", None)
        return d

    def __setstate__(self, d):
        self.__dict__.update(d)
        self._lock = threading.Lock()
        self._tls = threading.local()

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name, **attrs) -> Span:
        return Span(name=name, attrs=attrs, _tracer=self)

    def _push(self, sp: Span):
        self._stack().append(sp)

    def _pop(self, sp: Span):
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        self.n_spans += 1
        if self.n_spans > self.max_spans:
            self.n_dropped += 1
            return
        if st:
            st[-1].children.append(sp)
        else:
            with self._lock:
                self._roots.append(sp)

    def current(self) -> Span | None:
        st = self._stack()
        return st[-1] if st else None

    def roots(self) -> list:
        with self._lock:
            return list(self._roots)

    def clear(self):
        with self._lock:
            self._roots = []
        self.n_spans = 0
        self.n_dropped = 0

    def find_roots(self, name=None, **attrs) -> list:
        """Finished top-level spans matching a name and/or attr values."""
        out = []
        for sp in self.roots():
            if name is not None and sp.name != name:
                continue
            if any(sp.attrs.get(k) != v for k, v in attrs.items()):
                continue
            out.append(sp)
        return out

    # -- Perfetto export ---------------------------------------------------

    def to_perfetto(self) -> dict:
        """Chrome ``trace_events`` JSON (complete 'X' events, µs): load in
        ui.perfetto.dev / chrome://tracing. One track per thread."""
        events = [{"name": "process_name", "ph": "M", "pid": 0,
                   "args": {"name": "florida"}}]
        tid_of: dict = {}
        for root in self.roots():
            self._emit(root, events, tid_of)
        for ident, tid in sorted(tid_of.items(), key=lambda kv: kv[1]):
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": f"thread-{tid}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"epoch_unix": self.epoch_unix,
                              "n_spans": self.n_spans,
                              "n_dropped": self.n_dropped}}

    def _emit(self, sp: Span, events: list, tid_of: dict):
        tid = tid_of.setdefault(sp.thread, len(tid_of))
        args = {k: _jsonable(v) for k, v in sp.attrs.items()}
        args["cpu_ms"] = round(sp.cpu_s * 1e3, 3)
        events.append({
            "name": sp.name, "ph": "X", "pid": 0, "tid": tid,
            "ts": round((sp.t0 - self.epoch) * 1e6, 3),
            "dur": round(max(sp.wall_s, 0.0) * 1e6, 3),
            "args": args,
        })
        for ch in sp.children:
            self._emit(ch, events, tid_of)

    def export_perfetto(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


def stage_list(span: Span, base: float | None = None, depth: int = 0
               ) -> list:
    """Flatten a span subtree into flight-recorder stage rows:
    ``{name, t0_ms (offset from the subtree root), dur_ms, depth
    [, fused]}`` — enough to rebuild a timeline without the live
    tracer."""
    base = span.t0 if base is None else base
    row = {"name": span.name, "t0_ms": round((span.t0 - base) * 1e3, 3),
           "dur_ms": round(span.wall_s * 1e3, 3), "depth": depth}
    if span.fused:
        row["fused"] = True
    out = [row]
    for ch in span.children:
        out.extend(stage_list(ch, base, depth + 1))
    return out


# ----------------------------------------------------------------------
# module-global tracer (the `logging` pattern: one process-wide sink)
# ----------------------------------------------------------------------

_TRACER: Any = NullTracer()


def get_tracer():
    return _TRACER


def set_tracer(tracer) -> Any:
    """Install the process tracer; returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def enabled() -> bool:
    return _TRACER.enabled


def span(name, **attrs):
    """``with tracing.span("mask_apply", task_id=3) as sp:`` — the one
    call every instrumented site makes; a no-op under the default
    :class:`NullTracer`."""
    return _TRACER.span(name, **attrs)


@contextmanager
def use_tracer(tracer):
    """Scoped ``set_tracer`` (tests, benches): restores the previous
    tracer on exit."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


# ----------------------------------------------------------------------
# jit cache probe
# ----------------------------------------------------------------------

# the repo's SHARED jitted entry points (ROADMAP fixed-shape contracts
# live here: async batch pad classes -> _flat_local_dp_rows_jit /
# _buffer_write_masked, streaming waves -> _wave_limb_state). Looked up
# lazily through sys.modules so importing tracing never imports jax.
_JIT_ENTRY_POINTS = (
    ("repro.core.dp", "_flat_local_dp_jit"),
    ("repro.core.dp", "_flat_local_dp_rows_jit"),
    ("repro.core.dp", "_flat_clip_jit"),
    ("repro.core.privacy_engine", "_cohort_interims"),
    ("repro.core.privacy_engine", "_cohort_interims_churn"),
    ("repro.core.privacy_engine", "_wave_limb_state"),
    ("repro.core.privacy_engine", "ravel_rows"),
    ("repro.core.secure_agg", "_shard_limbs_jit"),
    ("repro.core.secure_agg", "_merge_jit"),
    ("repro.core.secure_agg", "_finalize_jit"),
    ("repro.core.strategies", "_buffer_write"),
    ("repro.core.strategies", "_buffer_write_masked"),
    ("repro.core.strategies", "_drain_apply"),
    ("repro.core.dropout", "_bucket_corrections"),
)

# (label, id(fn)) -> fn: per-instance executables (CohortEngine's vmapped
# cohort fns) registered at creation time
_DYNAMIC_JITS: dict = {}


def register_jit(label: str, fn):
    """Track a dynamically created jitted callable in the cache probe
    (no-op for objects without ``_cache_size``)."""
    if hasattr(fn, "_cache_size"):
        _DYNAMIC_JITS[(label, id(fn))] = fn
    return fn


def jit_cache_sizes() -> dict:
    """{entry-point label: compiled-executable count}. Only modules
    ALREADY imported are probed — the probe never triggers imports."""
    out = {}
    for mod_name, attr in _JIT_ENTRY_POINTS:
        mod = sys.modules.get(mod_name)
        fn = getattr(mod, attr, None) if mod is not None else None
        if fn is not None and hasattr(fn, "_cache_size"):
            out[f"{mod_name.rsplit('.', 1)[-1]}.{attr}"] = \
                int(fn._cache_size())
    for (label, _), fn in _DYNAMIC_JITS.items():
        out[label] = out.get(label, 0) + int(fn._cache_size())
    return out


def jit_cache_total() -> int:
    """Total compiled executables across the registered entry points —
    per-round deltas of this are the ``jit_cache_misses`` counter."""
    return sum(jit_cache_sizes().values())


# ----------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------


class FlightRecorder:
    """Append-only per-task JSONL round transcripts under ``root``:
    ``<root>/task_<id>.jsonl``, one structured event per line. Holds only
    the directory path (pickles with the CLI session; files open per
    append)."""

    def __init__(self, root: str):
        self.root = root

    def path(self, task_id: int) -> str:
        return os.path.join(self.root, f"task_{int(task_id)}.jsonl")

    def record(self, task_id: int, event: dict) -> dict:
        os.makedirs(self.root, exist_ok=True)
        event = dict(event, ts_unix=round(time.time(), 3))
        with open(self.path(task_id), "a") as f:
            f.write(json.dumps(event, default=_jsonable) + "\n")
        return event

    def read(self, task_id: int) -> list:
        p = self.path(task_id)
        if not os.path.exists(p):
            return []
        with open(p) as f:
            return [json.loads(line) for line in f if line.strip()]

    def task_ids(self) -> list:
        if not os.path.isdir(self.root):
            return []
        out = []
        for fn in os.listdir(self.root):
            if fn.startswith("task_") and fn.endswith(".jsonl"):
                try:
                    out.append(int(fn[len("task_"):-len(".jsonl")]))
                except ValueError:
                    pass
        return sorted(out)


def round_event(*, round_idx: int, cohort, survivors, n_shards: int = 0,
                stage2_route: str | None = None, voided: bool = False,
                void_reason: str | None = None, span_tree: Span | None = None,
                metrics: dict | None = None) -> dict:
    """Build the flight-recorder round transcript event. ``span_tree`` is
    the round's root span — its subtree becomes the ``stages`` rows."""
    ev = {
        "event": "round_voided" if voided else "round",
        "round": int(round_idx),
        "cohort": list(cohort),
        "survivors": list(survivors),
        "n_dropped": len(cohort) - len(survivors),
    }
    if n_shards:
        ev["n_shards"] = int(n_shards)
    if stage2_route:
        ev["stage2_route"] = stage2_route
    if void_reason:
        ev["void_reason"] = void_reason
    if metrics:
        ev["metrics"] = {k: _jsonable(v) for k, v in metrics.items()}
    if span_tree is not None and not isinstance(span_tree, _NullSpan):
        ev["stages"] = stage_list(span_tree)
        ev["wall_ms"] = round(span_tree.wall_s * 1e3, 3)
    return ev


def perfetto_from_flight(events: list, task_id: int) -> dict:
    """Rebuild a Perfetto ``trace_events`` timeline from recorded flight
    events alone (no live tracer needed): rounds lay out back-to-back on
    one track, each round's recorded ``stages`` at their stored offsets."""
    out = [{"name": "process_name", "ph": "M", "pid": 0,
            "args": {"name": f"florida-task-{task_id}"}}]
    cursor_us = 0.0
    for ev in events:
        stages = ev.get("stages")
        if not stages:
            wall = float(ev.get("wall_ms", 1.0)) * 1e3
            out.append({"name": ev.get("event", "round"), "ph": "X",
                        "pid": 0, "tid": 0, "ts": cursor_us, "dur": wall,
                        "args": {"round": ev.get("round")}})
            cursor_us += wall
            continue
        for row in stages:
            args = {"round": ev.get("round"), "depth": row["depth"]}
            if row.get("fused"):
                args["fused"] = True
            out.append({"name": row["name"], "ph": "X", "pid": 0,
                        "tid": row["depth"],
                        "ts": cursor_us + row["t0_ms"] * 1e3,
                        "dur": row["dur_ms"] * 1e3, "args": args})
        cursor_us += float(ev.get("wall_ms",
                                  stages[0]["dur_ms"])) * 1e3
    return {"traceEvents": out, "displayTimeUnit": "ms"}
