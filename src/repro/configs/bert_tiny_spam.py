"""BERT-Tiny-class spam classifier — the paper's own experiment model
(prajjwal1/bert-tiny distilled BERT on SetFit/enron-spam, §5.1).

Used by the paper-validation benchmarks and examples; not part of the
assigned dry-run grid.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bert-tiny-spam",
    family="dense",
    source="paper §5.1 (prajjwal1/bert-tiny on SetFit/enron-spam)",
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=8_192,             # synthetic-tokenizer vocab
    use_bias=True,
    norm_type="layernorm",
    act="gelu",
    glu=False,
    pos_embed="learned",
    fl_scheme="per_silo",
)
