"""Llama 4 Maverick 400B (17B active) — MoE 128 experts top-1, shared expert,
early-fusion multimodal (vision frontend out of scope for this entry: the
assignment lists it as [moe]; the text backbone is what we build).

[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                    # per-expert ffn dim
    vocab_size=202_048,
    num_experts=128,
    experts_per_token=1,
    moe_shared_expert=True,
    moe_every=1,
    capacity_factor=1.25,
    moe_dispatch_constraint=True,  # §Perf hillclimb 2
    opt_moments_bf16=True,         # §Perf hillclimb 2 (400B moments)
    fl_scheme="per_pod",
    train_microbatches=8,
)
