"""Gemma 2 27B — dense GQA with alternating local/global attention + softcaps.

[arXiv:2408.00118]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    act="gelu",
    glu=True,
    local_global_alternate=True,
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    fl_scheme="per_silo",
    train_microbatches=8,
)
