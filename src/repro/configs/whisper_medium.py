"""Whisper medium — encoder-decoder audio backbone, conv frontend stubbed.

[arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs()`` provides post-conv frame embeddings (B, S_enc, d_model)
directly. The input-shape seq_len is interpreted as the encoder frame count;
the decoder length is min(448, seq_len // 8) (Whisper's decoder is hard
capped at 448 positions, hence decode_32k / long_500k are skipped — see
DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,                # decoder layers
    num_encoder_layers=24,
    encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    use_bias=True,
    norm_type="layernorm",
    act="gelu",
    glu=False,
    pos_embed="learned",
    max_decoder_len=448,
    frontend="audio_stub",
    tie_embeddings=True,
    fl_scheme="per_silo",
    train_microbatches=2,
)
