"""Jamba v0.1 52B — hybrid Mamba+attention (1:7 interleave) with MoE 16e top-2.

[arXiv:2403.19887]

Layer l is attention iff l % 8 == 4 (1 attention per 8-layer Jamba block);
MoE FFN every second layer (odd layers), dense FFN otherwise.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    ssm_type="mamba",
    attn_every=8,
    attn_offset=4,
    d_state=16,
    d_conv=4,
    ssm_expand=2,
    pos_embed="none",             # jamba uses no positional encoding
    ssm_scan_chunk=256,            # §Perf hillclimb 1 (chunk+remat scan)
    # moe_dispatch_constraint measured HARMFUL here (21.1 -> 57.2 GiB):
    # 16 experts/16-way model axis reshards badly under the pinned layout
    fl_scheme="per_pod",
    train_microbatches=4,
)
