"""DeepSeek 67B — dense llama-arch GQA decoder.

[arXiv:2401.02954]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102_400,
    fl_scheme="per_pod",
    train_microbatches=8,
)
