from repro.configs.base import ArchConfig, reduced
from repro.configs.registry import (
    ASSIGNED,
    SKIPS,
    get_config,
    get_reduced_config,
    is_skipped,
    list_archs,
)
from repro.configs.shapes import SHAPES, InputShape, get_shape

__all__ = [
    "ArchConfig",
    "reduced",
    "ASSIGNED",
    "SKIPS",
    "get_config",
    "get_reduced_config",
    "is_skipped",
    "list_archs",
    "SHAPES",
    "InputShape",
    "get_shape",
]
