"""RWKV6 (Finch) 7B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,                 # rwkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    norm_type="layernorm",
    pos_embed="none",
    ssm_type="rwkv6",
    glu=False,                    # rwkv channel-mix is its own gated form
    fl_scheme="per_silo",
    train_microbatches=4,
)
