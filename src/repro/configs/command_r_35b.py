"""Command R 35B — dense GQA decoder, no-bias.

[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    use_bias=False,
    norm_type="layernorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    fl_scheme="per_pod",
    train_microbatches=4,
)
