"""Qwen3 MoE 235B (22B active) — 128 experts top-8, QK-norm.

[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                    # per-expert ffn dim
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
    qk_norm=True,
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
    # moe_dispatch_constraint measured slightly harmful here (36.3 vs
    # 35.6 GiB, coll 33.3 vs 27.7 s) — left off; llama4 (top-1) keeps it
    fl_scheme="per_pod",
    train_microbatches=8,
)
