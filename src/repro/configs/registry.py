"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, reduced

_MODULES = {
    "command-r-35b": "repro.configs.command_r_35b",
    "whisper-medium": "repro.configs.whisper_medium",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "yi-9b": "repro.configs.yi_9b",
    "bert-tiny-spam": "repro.configs.bert_tiny_spam",
}

# the 10 assigned architectures (bert-tiny-spam is the paper's own extra)
ASSIGNED = [k for k in _MODULES if k != "bert-tiny-spam"]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced_config(name: str) -> ArchConfig:
    return reduced(get_config(name))


def list_archs() -> list[str]:
    return sorted(_MODULES)


# (arch, shape) pairs skipped in the dry-run grid, with reasons
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-medium", "decode_32k"):
        "whisper decoder hard-capped at 448 positions; a 32k KV cache has no "
        "meaning for this architecture (DESIGN.md §Arch-applicability)",
    ("whisper-medium", "long_500k"):
        "whisper decoder hard-capped at 448 positions (DESIGN.md "
        "§Arch-applicability)",
}


def is_skipped(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))
