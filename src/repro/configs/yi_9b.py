"""Yi 9B — dense llama-arch GQA decoder.

[arXiv:2403.04652]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    fl_scheme="per_silo",
    train_microbatches=2,
)
