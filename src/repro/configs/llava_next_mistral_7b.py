"""LLaVA-NeXT (Mistral-7B backbone) — VLM, anyres tiling frontend stubbed.

[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/CLIP vision tower + projector is a STUB per the assignment:
``input_specs()`` provides projected patch embeddings (B, P, d_model) which
the backbone early-fuses with the text token embeddings. The Mistral
backbone's native 4096 sliding window is kept.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    sliding_window=4096,
    frontend="vision_stub",
    num_patch_tokens=2880,        # anyres: 4 tiles + base, 576 each
    fl_scheme="per_silo",
    train_microbatches=2,
)
