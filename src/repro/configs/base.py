"""Architecture configuration schema.

One ``ArchConfig`` instance per assigned architecture (see files in this
package). The schema spans all six assigned families: dense GQA decoders,
MoE decoders, attention-free SSM (RWKV6), hybrid Mamba+attention (Jamba),
encoder-decoder audio backbones (Whisper), and VLM backbones (LLaVA-NeXT).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    # identity ---------------------------------------------------------
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""               # citation from the assignment

    # trunk ------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 512
    use_bias: bool = False
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    glu: bool = True               # gated MLP (SwiGLU/GeGLU)
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"        # rope | learned | none

    # attention variants -------------------------------------------------
    sliding_window: int = 0        # >0: sliding-window attention everywhere
    local_global_alternate: bool = False   # gemma2: even layers local
    local_window: int = 4096               # window for local layers
    attn_logit_softcap: float = 0.0        # gemma2 attn softcap
    final_logit_softcap: float = 0.0       # gemma2 output softcap
    qk_norm: bool = False                  # qwen3: rmsnorm on q,k heads

    # mixture-of-experts -------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # MoE FFN in layers with (idx % moe_every == moe_offset)
    moe_offset: int = 0
    moe_shared_expert: bool = False        # llama4: shared expert alongside routed
    capacity_factor: float = 1.25
    moe_group_size: int = 1024     # token group size for dispatch einsum
    # §Perf (hillclimb): pin expert-parallel shardings on the dispatched
    # activations so GSPMD emits all-to-all instead of replicate+reshard
    moe_dispatch_constraint: bool = False

    # ssm / hybrid ---------------------------------------------------------
    ssm_type: str = ""             # rwkv6 | mamba
    # §Perf (hillclimb): two-level selective scan — outer scan over chunks
    # of this many steps with a rematerialized inner scan, so backward
    # stores only chunk-boundary states instead of (T, B, d_in, n) f32
    # residual stacks. 0 = plain scan (paper-faithful baseline).
    ssm_scan_chunk: int = 0
    attn_every: int = 0            # jamba: layer idx % attn_every == attn_offset is attention
    attn_offset: int = 0
    d_state: int = 16              # mamba state dim
    d_conv: int = 4                # mamba conv width
    ssm_expand: int = 2            # mamba inner expansion
    rwkv_head_dim: int = 64

    # encoder-decoder ------------------------------------------------------
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    max_decoder_len: int = 448     # whisper decoder hard cap

    # modality frontend (STUB — input_specs provides embeddings directly) --
    frontend: str = ""             # "" | audio_stub | vision_stub
    num_patch_tokens: int = 0      # vlm: image patch tokens per example

    # long-context variant -------------------------------------------------
    # window used by full-attention archs for the long_500k decode shape
    long_context_window: int = 8192

    # §Perf: mesh axes to pin the activation batch dim to (empty = let
    # GSPMD propagate). Set by launch/fl_step for per_pod training after
    # measuring GSPMD replicate-batch/shard-feature propagation on jamba.
    activation_batch_axes: tuple | None = None  # None=auto, ()=off

    # §Perf: pin attention q/k/v head dims to the 'model' axis (GSPMD
    # propagation can otherwise replicate heads for per_silo training —
    # measured on gemma2). None=auto (on for per_silo train), False=off.
    shard_attn_heads: bool | None = None

    # §Perf: bf16 AdamW moments on the server optimizer (halves opt-state
    # memory; update math stays f32). Off = paper-faithful f32.
    opt_moments_bf16: bool = False

    # FL integration --------------------------------------------------------
    # per_silo: silo = one data-axis index, per-silo pseudo-grads via vmap
    # per_pod : silo = one pod, masking applied to the FSDP-sharded update
    fl_scheme: str = "per_silo"
    # microbatches for the train_4k production step (grad accumulation)
    train_microbatches: int = 1

    # ---------------------------------------------------------------------
    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_attn_layer(self, idx: int) -> bool:
        """hybrid archs: which layers are attention (vs SSM)."""
        if self.ssm_type and self.attn_every:
            return idx % self.attn_every == self.attn_offset
        return not self.ssm_type

    def is_moe_layer(self, idx: int) -> bool:
        if self.num_experts == 0:
            return False
        return idx % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        c = self
        n = c.vocab_size * c.d_model  # embed
        if not c.tie_embeddings:
            n += c.vocab_size * c.d_model
        if c.pos_embed == "learned":
            n += 8192 * c.d_model

        def attn_params():
            return c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model

        def dense_ffn():
            mult = 3 if c.glu else 2
            return mult * c.d_model * c.d_ff

        def moe_ffn():
            mult = 3 if c.glu else 2
            p = c.num_experts * mult * c.d_model * c.d_ff
            p += c.d_model * c.num_experts  # router
            if c.moe_shared_expert:
                p += mult * c.d_model * c.d_ff
            return p

        def rwkv_block():
            # time-mix: r,k,v,w,g projections + output + lora for w; channel-mix
            d = c.d_model
            return 6 * d * d + 2 * d * (c.d_ff if c.d_ff else 4 * d)

        def mamba_block():
            d_in = c.ssm_expand * c.d_model
            p = c.d_model * d_in * 2          # in_proj (x, z)
            p += d_in * c.d_conv              # conv
            p += d_in * (c.d_state * 2 + 1)   # B, C, dt proj (approx)
            p += d_in * c.d_model             # out proj
            p += d_in * c.d_state             # A
            return p

        layers = c.num_layers + (c.num_encoder_layers if c.encoder_decoder else 0)
        for i in range(c.num_layers):
            if c.ssm_type == "rwkv6":
                n += rwkv_block()
            elif c.ssm_type == "mamba" and not c.is_attn_layer(i):
                n += mamba_block()
                n += moe_ffn() if c.is_moe_layer(i) else dense_ffn()
                continue
            else:
                n += attn_params()
                n += moe_ffn() if c.is_moe_layer(i) else dense_ffn()
        if c.encoder_decoder:
            for _ in range(c.num_encoder_layers):
                n += attn_params() + dense_ffn()
            n += c.num_layers * attn_params()  # cross attention
        n += layers * 2 * c.d_model  # norms (approx)
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE rooflines: 6*N_active*D."""
        if self.num_experts == 0:
            return self.param_count()
        dense_like = self.replace(num_experts=0, experts_per_token=0)
        full_ffn_layers = sum(
            1 for i in range(self.num_layers) if self.is_moe_layer(i)
        )
        mult = 3 if self.glu else 2
        per_layer_ffn = mult * self.d_model * self.d_ff
        extra = full_ffn_layers * per_layer_ffn * (
            self.experts_per_token - 1 + (1 if self.moe_shared_expert else 0)
        )
        return dense_like.param_count() + extra


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Reduced variant of the same family for CPU smoke tests.

    2 layers, d_model<=512, <=4 experts, tiny vocab — per the deliverable
    contract. Keeps every structural flag (GQA ratio, local/global pattern,
    MoE interleave, SSM type, enc-dec) so the smoke test exercises the same
    code paths as the full config.
    """
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, min(cfg.num_heads, 4))
    ratio = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
    num_kv = max(1, num_heads // ratio)
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        local_window=16,
        sliding_window=16 if cfg.sliding_window else 0,
        long_context_window=32,
        moe_group_size=64,
        train_microbatches=1,
        rwkv_head_dim=32,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.encoder_decoder:
        kw.update(num_encoder_layers=2, max_decoder_len=16)
    if cfg.ssm_type == "mamba" and cfg.attn_every:
        kw.update(attn_every=2, attn_offset=1)  # keep the hybrid interleave
    if cfg.num_patch_tokens:
        kw.update(num_patch_tokens=8)
    return cfg.replace(**kw)
