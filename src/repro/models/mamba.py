"""Mamba (S6) selective-state-space block, as used by Jamba's SSM layers.

Training/prefill runs the selective scan as a ``lax.scan`` over time with a
(B, d_inner, d_state) carry (sequential but compile-cheap; the chunked
variant is a §Perf candidate — RWKV6 demonstrates the chunked pattern).
Decode is the natural O(1) recurrent step with conv + ssm state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init


def mamba_init(cfg, key):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.d_state
    ks = jax.random.split(key, 6)
    dt_rank = max(16, d // 16)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * d_in)),
        "conv": _dense_init(ks[1], (cfg.d_conv, d_in), scale=0.5),
        "conv_b": jnp.zeros((d_in,)),
        "w_bcdt": _dense_init(ks[2], (d_in, 2 * n + dt_rank)),
        "w_dt": _dense_init(ks[3], (dt_rank, d_in), scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (d_in,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))),
        "A_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                         )[None, :].repeat(d_in, 0),
        "D": jnp.ones((d_in,)),
        "w_out": _dense_init(ks[5], (d_in, d)),
    }


def _ssm_inputs(cfg, p, xc):
    """xc: (B, T, d_in) post-conv activations -> dt, B_t, C_t."""
    n = cfg.d_state
    bcdt = xc @ p["w_bcdt"]
    B_t = bcdt[..., :n]
    C_t = bcdt[..., n:2 * n]
    dt = jax.nn.softplus(bcdt[..., 2 * n:] @ p["w_dt"] + p["dt_bias"])
    return dt, B_t, C_t


def _selective_scan(cfg, p, xc, h0):
    """xc: (B,T,d_in); h0: (B,d_in,n) -> y: (B,T,d_in), hT.

    With cfg.ssm_scan_chunk > 0 the scan is two-level: an outer scan over
    T/K chunks whose body is ``jax.checkpoint``ed, so reverse-mode stores
    only the (B, d_in, n) chunk-boundary states and replays each chunk —
    peak residuals drop from O(T) to O(K + T/K) step-tensors (§Perf
    hillclimb 1; the plain path stacks (T, B, d_in, n) f32 residuals).
    """
    dt, B_t, C_t = _ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"])                       # (d_in, n)

    def step(h, inp):
        # xs stream in bf16 (halves the saved-residual stacks); the
        # recurrence carry h and per-step math stay f32
        x_t, dt_t, b_t, c_t = (a.astype(jnp.float32) for a in inp)
        dA = jnp.exp(dt_t[..., None] * A[None])    # (B,d_in,n)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y.astype(ys_dtype)

    T = xc.shape[1]
    ys_dtype = jnp.bfloat16 if cfg.ssm_scan_chunk else jnp.float32
    xs_dtype = ys_dtype
    xs = tuple(jnp.moveaxis(a, 1, 0).astype(xs_dtype)
               for a in (xc, dt, B_t, C_t))
    K = cfg.ssm_scan_chunk
    if K and T % K == 0 and T > K:
        chunked = tuple(a.reshape(T // K, K, *a.shape[1:]) for a in xs)

        @jax.checkpoint
        def chunk_body(h, chunk_xs):
            return jax.lax.scan(step, h, chunk_xs)

        hT, ys = jax.lax.scan(chunk_body, h0.astype(jnp.float32), chunked)
        ys = ys.reshape(T, *ys.shape[2:])
    else:
        hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(xc.dtype)
    return y + xc * p["D"].astype(xc.dtype), hT


def _causal_conv(p, x, d_conv):
    """depthwise causal conv. x: (B,T,d_in)."""
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["conv"][i]
              for i in range(d_conv))
    return out + p["conv_b"]


def mamba_apply(cfg, p, x, state=None):
    """x: (B,T,D). state: None (train) or dict (carried across calls)."""
    B, T, _ = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    xz = x @ p["w_in"]
    xi, z = xz[..., :d_in], xz[..., d_in:]
    if state is None:
        h0 = jnp.zeros((B, d_in, cfg.d_state))
        xc = jax.nn.silu(_causal_conv(p, xi, cfg.d_conv))
        y, _ = _selective_scan(cfg, p, xc, h0)
    else:
        y, state = mamba_decode_inner(cfg, p, xi, z, state)
        return (y * jax.nn.silu(z)) @ p["w_out"], state
    return (y * jax.nn.silu(z)) @ p["w_out"], None


def mamba_state_init(cfg, batch, dtype=jnp.float32):
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, cfg.d_state), jnp.float32),
    }


def mamba_decode_inner(cfg, p, xi, z, state):
    """One-token step. xi: (B,1,d_in)."""
    window = jnp.concatenate([state["conv"], xi.astype(state["conv"].dtype)],
                             axis=1)  # (B, d_conv, d_in)
    conv_out = jnp.einsum("bcd,cd->bd", window, p["conv"]) + p["conv_b"]
    xc = jax.nn.silu(conv_out)[:, None, :]          # (B,1,d_in)
    dt, B_t, C_t = _ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A[None])
    dBx = (dt[:, 0] * xc[:, 0])[..., None] * B_t[:, 0][:, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0])[:, None, :]
    y = y.astype(xc.dtype) + xc * p["D"].astype(xc.dtype)
    new_state = {"conv": window[:, 1:], "h": h}
    return y, new_state
