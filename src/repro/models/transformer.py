"""Decoder trunk: heterogeneous layer patterns + scanned blocks.

All assigned decoder families are expressed as a repeating *block pattern*
(the smallest repeating unit of layers), stacked ``n_blocks`` times and run
with ``lax.scan`` — this bounds HLO size (and hence compile time at 512-way
SPMD) even for 94-layer stacks:

  dense (command-r, deepseek, yi, llava, qwen3-moe, llama4): pattern = 1 layer
  gemma2:  pattern = [local-attn layer, global-attn layer]
  jamba:   pattern = 8 layers, attention at position 4, MoE at odd positions
  rwkv6:   pattern = 1 rwkv block (time-mix + channel-mix)

Train/prefill applies the pattern with a rematerialized scan body; decode
scans the same stack with per-position caches as scan xs/ys.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init


# --------------------------------------------------------------------------
# pattern
# --------------------------------------------------------------------------

def block_pattern(cfg):
    """-> (descriptors, n_blocks); descriptor = dict(kind, window, ffn)."""
    if cfg.ssm_type == "rwkv6":
        return [dict(kind="rwkv")], cfg.num_layers
    size = 1
    if cfg.ssm_type == "mamba" and cfg.attn_every:
        size = math.lcm(size, cfg.attn_every)
    if cfg.num_experts and cfg.moe_every > 1:
        size = math.lcm(size, cfg.moe_every)
    if cfg.local_global_alternate:
        size = math.lcm(size, 2)
    assert cfg.num_layers % size == 0, (cfg.name, cfg.num_layers, size)
    pattern = []
    for i in range(size):
        if cfg.ssm_type == "mamba" and not cfg.is_attn_layer(i):
            kind = "mamba"
            window = 0
        else:
            kind = "attn"
            if cfg.local_global_alternate:
                window = cfg.local_window if i % 2 == 0 else 0
            else:
                window = cfg.sliding_window
        ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
        pattern.append(dict(kind=kind, window=window, ffn=ffn))
    return pattern, cfg.num_layers // size


def _sublayer_init(cfg, desc, key):
    ks = jax.random.split(key, 4)
    if desc["kind"] == "rwkv":
        return {
            "norms": [norm_init(cfg, ks[0]), norm_init(cfg, ks[1])],
            "rwkv": rwkv_mod.rwkv_block_init(cfg, ks[2]),
        }
    p = {"norm1": norm_init(cfg, ks[0]), "norm2": norm_init(cfg, ks[1])}
    if desc["kind"] == "attn":
        p["attn"] = attn.attn_init(cfg, ks[2])
    else:
        p["mamba"] = mamba_mod.mamba_init(cfg, ks[2])
    if desc["ffn"] == "moe":
        p["ffn"] = moe_mod.moe_init(cfg, ks[3])
    else:
        p["ffn"] = mlp_init(cfg, ks[3])
    return p


def decoder_init(cfg, key):
    pattern, n_blocks = block_pattern(cfg)
    blocks = []
    for pos, desc in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos), n_blocks)
        blocks.append(jax.vmap(partial(_sublayer_init, cfg, desc))(keys))
    return {"blocks": tuple(blocks),
            "final_norm": norm_init(cfg, jax.random.fold_in(key, 999))}


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------

def _apply_sublayer(cfg, desc, p, x, window_override=None):
    """One sub-layer, full sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if desc["kind"] == "rwkv":
        B = x.shape[0]
        state = rwkv_mod.rwkv_state_init(cfg, B, x.dtype)
        x, _ = rwkv_mod.rwkv_block_apply(
            cfg, p["rwkv"], p["norms"], partial(norm_apply, cfg), x, state)
        return x, aux
    window = desc["window"] if window_override is None else window_override
    if desc["kind"] == "attn":
        h = attn.multihead_attention(cfg, p["attn"],
                                     norm_apply(cfg, p["norm1"], x),
                                     causal=True, window=window)
    else:
        h, _ = mamba_mod.mamba_apply(cfg, p["mamba"],
                                     norm_apply(cfg, p["norm1"], x))
    x = x + h
    if desc["ffn"] == "moe":
        h, aux = moe_mod.moe_apply(cfg, p["ffn"],
                                   norm_apply(cfg, p["norm2"], x))
    else:
        h = mlp_apply(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
    return x + h, aux


def decoder_apply(cfg, params, x, *, remat=True, window_override=None):
    """x: (B, S, D) embeddings -> (hidden (B,S,D), moe_aux scalar)."""
    pattern, _ = block_pattern(cfg)

    def block_body(carry, block_params):
        x, aux = carry
        for pos, desc in enumerate(pattern):
            x, a = _apply_sublayer(cfg, desc, block_params[pos], x,
                                   window_override)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(block_body) if remat else block_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return norm_apply(cfg, params["final_norm"], x), aux


# --------------------------------------------------------------------------
# decode (one token, cached)
# --------------------------------------------------------------------------

def init_decode_cache(cfg, batch, max_len, dtype):
    pattern, n_blocks = block_pattern(cfg)

    def per_block(desc):
        if desc["kind"] == "rwkv":
            return rwkv_mod.rwkv_state_init(cfg, batch, dtype)
        if desc["kind"] == "mamba":
            return mamba_mod.mamba_state_init(cfg, batch, dtype)
        return attn.init_kv_cache(cfg, batch, max_len, dtype)

    caches = tuple(
        jax.tree.map(lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape),
                     per_block(desc))
        for desc in pattern)
    return {"blocks": caches, "index": jnp.zeros((), jnp.int32)}


def decoder_decode(cfg, params, x, cache, *, window_override=None):
    """x: (B, 1, D); cache from init_decode_cache. -> (hidden, new cache)."""
    pattern, _ = block_pattern(cfg)
    index = cache["index"]

    def block_body(x, inp):
        block_params, block_cache = inp
        new_caches = []
        for pos, desc in enumerate(pattern):
            p, c = block_params[pos], block_cache[pos]
            if desc["kind"] == "rwkv":
                # single-token recurrence: exact sequential semantics
                xn = norm_apply(cfg, p["norms"][0], x)
                shifted = c["last_tm"][:, None, :].astype(xn.dtype)
                r, k, v, g, logw, H = rwkv_mod._project_rkvwg(
                    cfg, p["rwkv"]["tm"], xn, shifted)
                o, S = rwkv_mod.rwkv_scan_reference(
                    r, k, v, logw, p["rwkv"]["tm"]["u"], c["S"])
                B = x.shape[0]
                o = rwkv_mod._group_norm(o.reshape(B, 1, cfg.d_model),
                                         p["rwkv"]["tm"]["ln_x_scale"], H)
                h = (o * jax.nn.silu(g)) @ p["rwkv"]["tm"]["wo"]
                x = x + h
                new_last_tm = xn[:, 0, :]
                xn2 = norm_apply(cfg, p["norms"][1], x)
                cm = p["rwkv"]["cm"]
                xk = xn2 + (c["last_cm"][:, None, :] - xn2) * cm["mix_k"]
                xr = xn2 + (c["last_cm"][:, None, :] - xn2) * cm["mix_r"]
                kk = jnp.square(jax.nn.relu(xk @ cm["wk"]))
                x = x + jax.nn.sigmoid(xr @ cm["wr"]) * (kk @ cm["wv"])
                new_caches.append({"S": S, "last_tm": new_last_tm,
                                   "last_cm": xn2[:, 0, :]})
                continue
            if desc["kind"] == "attn":
                window = (desc["window"] if window_override is None
                          else window_override)
                h, new_kv = attn.decode_attention(
                    cfg, p["attn"], norm_apply(cfg, p["norm1"], x), c, index,
                    window=window)
                x = x + h
                new_caches.append(new_kv)
            else:  # mamba
                xn = norm_apply(cfg, p["norm1"], x)
                d_in = cfg.ssm_expand * cfg.d_model
                xz = xn @ p["mamba"]["w_in"]
                xi, z = xz[..., :d_in], xz[..., d_in:]
                y, new_state = mamba_mod.mamba_decode_inner(
                    cfg, p["mamba"], xi, z, c)
                x = x + (y * jax.nn.silu(z)) @ p["mamba"]["w_out"]
                new_caches.append(new_state)
            if desc["ffn"] == "moe":
                h, _ = moe_mod.moe_apply(cfg, p["ffn"],
                                         norm_apply(cfg, p["norm2"], x))
            else:
                h = mlp_apply(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
            x = x + h
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(block_body, x,
                                 (params["blocks"], cache["blocks"]))
    x = norm_apply(cfg, params["final_norm"], x)
    return x, {"blocks": new_blocks, "index": index + 1}
