"""Shared building blocks: norms, MLPs, embeddings, rotary embeddings.

Pure-functional style: ``*_init(cfg, key) -> params dict`` and
``*_apply(cfg, params, x) -> y``. Parameters for scanned layer stacks carry a
leading ``n_blocks`` dimension added by the caller via ``jax.vmap`` over init.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(cfg, key, dim=None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,))
    return p


def norm_apply(cfg, params, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * params["scale"]
    return y.astype(x.dtype)


def rms_head_norm(x, scale):
    """qwen3-style per-head q/k norm. x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# activations / MLP
# --------------------------------------------------------------------------

def _act(cfg, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def mlp_init(cfg, key, d_in=None, d_ff=None):
    d_in = d_in or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": _dense_init(ks[0], (d_in, d_ff)),
        "w_out": _dense_init(ks[1], (d_ff, d_in)),
    }
    if cfg.glu:
        p["w_gate"] = _dense_init(ks[2], (d_in, d_ff))
    if cfg.use_bias:
        p["b_in"] = jnp.zeros((d_ff,))
        p["b_out"] = jnp.zeros((d_in,))
    return p


def mlp_apply(cfg, params, x):
    h = x @ params["w_in"]
    if cfg.use_bias:
        h = h + params["b_in"]
    h = _act(cfg, h)
    if cfg.glu:
        h = h * (x @ params["w_gate"])
    y = h @ params["w_out"]
    if cfg.use_bias:
        y = y + params["b_out"]
    return y


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def embed_init(cfg, key, max_positions=8192):
    ks = jax.random.split(key, 3)
    p = {"tok": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if cfg.pos_embed == "learned":
        p["pos"] = _dense_init(ks[1], (max_positions, cfg.d_model), scale=0.02)
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[2], (cfg.d_model, cfg.vocab_size))
    return p


_ONE_HOT_EMBED_MAX_VOCAB = 1024


def _lookup(table, idx):
    """Row lookup. On CPU with a small table, lower as one-hot matmul:
    bit-exact (one nonzero term per row-sum), and its BACKWARD is a dense
    matmul instead of a scatter-add — XLA CPU scatter is a scalar loop that
    dominates vmapped per-client gradients in the cohort engine."""
    if (table.shape[0] <= _ONE_HOT_EMBED_MAX_VOCAB
            and jax.default_backend() == "cpu"):
        oh = jax.nn.one_hot(idx, table.shape[0], dtype=table.dtype)
        return oh @ table
    return jnp.take(table, idx, axis=0)


def embed_tokens(cfg, params, tokens, positions=None):
    x = _lookup(params["tok"], tokens)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_embed == "learned":
        if positions is None:
            positions = jnp.arange(tokens.shape[-1])
        x = x + _lookup(params["pos"], positions)
    return x


def unembed(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["tok"].T.astype(x.dtype)
    else:
        logits = x @ params["unembed"]
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# --------------------------------------------------------------------------
# rotary
# --------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    if ang.ndim == 2:  # (S, half) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
