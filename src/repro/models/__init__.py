from repro.models.model import (
    cast_floats,
    chunked_lm_loss,
    classifier_init,
    classify_logits,
    classify_loss,
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    loss_fn,
    prefill_logits,
)

__all__ = [
    "cast_floats", "chunked_lm_loss", "classifier_init", "classify_logits",
    "classify_loss", "decode_step", "forward_hidden", "init_cache",
    "init_params", "loss_fn", "prefill_logits",
]
