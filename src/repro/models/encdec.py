"""Encoder-decoder backbone (Whisper-style). Conv/mel frontend is stubbed:
the encoder consumes precomputed frame embeddings (B, S_enc, D) directly
(see DESIGN.md §Arch-applicability). Encoder positions use sinusoidal
embeddings (any length); decoder uses the learned table capped at
``cfg.max_decoder_len``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init


def _sinusoidal(S, D, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / D)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :D].astype(dtype)


def _enc_layer_init(cfg, key):
    ks = jax.random.split(key, 3)
    return {"norm1": norm_init(cfg, ks[0]), "attn": attn.attn_init(cfg, ks[1]),
            "norm2": norm_init(cfg, ks[2]),
            "ffn": mlp_init(cfg, jax.random.fold_in(key, 7))}


def _dec_layer_init(cfg, key):
    ks = jax.random.split(key, 5)
    return {
        "norm1": norm_init(cfg, ks[0]), "self_attn": attn.attn_init(cfg, ks[1]),
        "norm_x": norm_init(cfg, ks[2]), "cross_attn": attn.attn_init(cfg, ks[3]),
        "norm2": norm_init(cfg, ks[4]),
        "ffn": mlp_init(cfg, jax.random.fold_in(key, 7)),
    }


def encdec_init(cfg, key):
    k_enc = jax.random.split(jax.random.fold_in(key, 0),
                             cfg.num_encoder_layers)
    k_dec = jax.random.split(jax.random.fold_in(key, 1), cfg.num_layers)
    return {
        "encoder": jax.vmap(partial(_enc_layer_init, cfg))(k_enc),
        "decoder": jax.vmap(partial(_dec_layer_init, cfg))(k_dec),
        "enc_norm": norm_init(cfg, jax.random.fold_in(key, 2)),
        "final_norm": norm_init(cfg, jax.random.fold_in(key, 3)),
    }


def encoder_apply(cfg, params, frames, *, remat=True):
    """frames: (B, S_enc, D) stub embeddings -> (B, S_enc, D)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model, frames.dtype)

    def body(x, p):
        h = attn.multihead_attention(cfg, p["attn"],
                                     norm_apply(cfg, p["norm1"], x),
                                     causal=False)
        x = x + h
        x = x + mlp_apply(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        return x, None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norm_apply(cfg, params["enc_norm"], x)


def decoder_apply(cfg, params, x, enc_out, *, remat=True):
    """x: (B, S_dec, D) token embeds (learned pos added by caller)."""

    def body(x, p):
        h = attn.multihead_attention(cfg, p["self_attn"],
                                     norm_apply(cfg, p["norm1"], x),
                                     causal=True)
        x = x + h
        h = attn.multihead_attention(cfg, p["cross_attn"],
                                     norm_apply(cfg, p["norm_x"], x),
                                     causal=False, kv_src=enc_out)
        x = x + h
        x = x + mlp_apply(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        return x, None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return norm_apply(cfg, params["final_norm"], x)
