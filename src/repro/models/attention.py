"""GQA attention with sliding windows, logit softcap, q-chunking and KV cache.

Supports the assigned variants:
  - grouped-query attention (any heads:kv ratio)           [all dense archs]
  - sliding-window / local attention                        [mistral, gemma2]
  - attention-logit softcapping                             [gemma2]
  - per-head q/k RMS norm                                   [qwen3]
  - cross attention (encoder-decoder)                       [whisper]
  - one-token decode against a (possibly sequence-sharded) KV cache

Long sequences use query-chunking (``lax.scan`` over query blocks) so the
(Sq, Sk) score matrix never materializes at more than (chunk, Sk) — the pure
JAX analogue of flash attention's memory behaviour (compute is left to the
MXU via einsum; see DESIGN.md §5 for why there is no Pallas kernel here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import _dense_init, rms_head_norm, rope

Q_CHUNK = 2048
NEG_INF = -2.3819763e38  # == finfo(f32).min / 2, safe under softcap tanh


def attn_init(cfg, key, cross=False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": _dense_init(ks[0], (d, cfg.q_dim)),
        "wk": _dense_init(ks[1], (d, cfg.kv_dim)),
        "wv": _dense_init(ks[2], (d, cfg.kv_dim)),
        "wo": _dense_init(ks[3], (cfg.q_dim, d)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,))
        p["bk"] = jnp.zeros((cfg.kv_dim,))
        p["bv"] = jnp.zeros((cfg.kv_dim,))
        p["bo"] = jnp.zeros((d,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,))
        p["k_norm"] = jnp.ones((cfg.head_dim,))
    return p


def _shard_heads(cfg, t):
    """Pin the heads dim to 'model' (§Perf: GSPMD can silently replicate
    attention heads when params are replicated over data — per_silo)."""
    if not cfg.shard_attn_heads:
        return t
    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if "model" not in names:
        return t
    size = compat.mesh_axis_sizes(mesh)["model"]
    if t.shape[2] % size or t.shape[2] < size:
        return t
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.PartitionSpec(None, None, "model", None))


def _project_qkv(cfg, params, x, kv_src=None):
    B, S, _ = x.shape
    kv_src = x if kv_src is None else kv_src
    Skv = kv_src.shape[1]
    q = x @ params["wq"]
    k = kv_src @ params["wk"]
    v = kv_src @ params["wv"]
    if cfg.use_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = _shard_heads(cfg, q.reshape(B, S, cfg.num_heads, cfg.head_dim))
    k = _shard_heads(cfg, k.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim))
    v = _shard_heads(cfg, v.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim))
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    return q, k, v


def _scores_to_out(cfg, q, k, v, mask):
    """q: (B,Sq,H,hd)  k,v: (B,Sk,KV,hd)  mask: (B|1, Sq, Sk) bool."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(B, Sq, H, hd)


def _make_mask(q_pos, k_pos, *, causal, window):
    """q_pos: (Sq,), k_pos: (Sk,) absolute positions -> (Sq, Sk) bool."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def multihead_attention(cfg, params, x, *, causal=True, window=0,
                        kv_src=None, q_offset=0):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = _project_qkv(cfg, params, x, kv_src=kv_src)
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    if cfg.pos_embed == "rope" and kv_src is None:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, k_pos, cfg.rope_theta)

    if Sq > Q_CHUNK and Sq % Q_CHUNK == 0:
        n_chunk = Sq // Q_CHUNK
        qc = q.reshape(B, n_chunk, Q_CHUNK, cfg.num_heads, cfg.head_dim)
        qc = jnp.moveaxis(qc, 1, 0)  # (n_chunk, B, C, H, hd)
        qpc = q_pos.reshape(n_chunk, Q_CHUNK)

        def body(carry, inp):
            qi, qpi = inp
            mask = _make_mask(qpi, k_pos, causal=causal, window=window)[None]
            return carry, _scores_to_out(cfg, qi, k, v, mask)

        _, outs = jax.lax.scan(body, None, (qc, qpc))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, cfg.num_heads,
                                               cfg.head_dim)
    else:
        mask = _make_mask(q_pos, k_pos, causal=causal, window=window)[None]
        out = _scores_to_out(cfg, q, k, v, mask)

    y = out.reshape(B, Sq, cfg.q_dim) @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return y


# --------------------------------------------------------------------------
# decode with KV cache
# --------------------------------------------------------------------------

def init_kv_cache(cfg, batch, max_len, dtype):
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype=dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype=dtype),
    }


def decode_attention(cfg, params, x, cache, index, *, window=0):
    """One-token decode step. x: (B, 1, D); index: scalar position."""
    q, k_new, v_new = _project_qkv(cfg, params, x)
    B = x.shape[0]
    S_max = cache["k"].shape[1]
    pos = jnp.full((1,), index)
    if cfg.pos_embed == "rope":
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))
    k_pos = jnp.arange(S_max)
    mask = _make_mask(pos, k_pos, causal=True, window=window)[None]
    out = _scores_to_out(cfg, q, k_cache, v_cache, mask)
    y = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return y, {"k": k_cache, "v": v_cache}
