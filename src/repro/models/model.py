"""Public model API: init / loss / prefill / decode for every assigned arch.

Batch formats (all jnp arrays):
  LM (dense/moe/ssm/hybrid):  {"tokens" (B,S) i32, "targets" (B,S) i32,
                               "mask" (B,S) f32}
  audio (whisper):            {"frames" (B,S_enc,D) f32 — STUB embeddings,
                               "tokens"/"targets"/"mask" (B,S_dec)}
  vlm (llava):                {"patches" (B,P,D) f32 — STUB embeddings,
                               "tokens"/"targets"/"mask" (B,S_text)}
                              (early fusion: sequence = patches ++ text)

The LM loss is computed with a sequence-chunked cross-entropy so the full
(B, S, vocab) logits tensor is never materialized (vocabs up to 256k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import encdec, transformer
from repro.models.layers import (_dense_init, embed_init, embed_tokens,
                                 unembed)

LOSS_CHUNK = 512


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg, key, dtype=jnp.float32, max_positions=None):
    """``max_positions`` bounds the learned positional table (default 8192).
    Size it to the actual sequence length for small-sequence workloads —
    an oversized table is pure waste, and its gradient (a scatter into
    mostly-untouched rows) dominates per-client update cost in the
    vectorized FL paths."""
    k_embed, k_trunk = jax.random.split(key)
    max_pos = max_positions or (
        cfg.max_decoder_len if cfg.encoder_decoder else 8192)
    params = {"embed": embed_init(cfg, k_embed, max_positions=max_pos)}
    if cfg.encoder_decoder:
        params["trunk"] = encdec.encdec_init(cfg, k_trunk)
    else:
        params["trunk"] = transformer.decoder_init(cfg, k_trunk)
    return cast_floats(params, dtype)


def cast_floats(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def classifier_init(cfg, key, n_classes=2):
    return {"w": _dense_init(key, (cfg.d_model, n_classes)),
            "b": jnp.zeros((n_classes,))}


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _constrain_batch_axis(cfg, x):
    """Pin the activation batch dim to cfg.activation_batch_axes (§Perf:
    GSPMD otherwise propagates feature-sharded/batch-replicated layouts
    from FSDP weights through the embedding gather)."""
    if not cfg.activation_batch_axes:
        return x
    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    axes = tuple(a for a in cfg.activation_batch_axes if a in names)
    if not axes:
        return x
    size = 1
    for a in axes:
        size *= compat.mesh_axis_sizes(mesh)[a]
    if x.shape[0] % size or x.shape[0] < size:
        return x  # e.g. long_500k's batch of 1
    spec = jax.sharding.PartitionSpec(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _embed_batch(cfg, params, batch):
    """-> (x (B,S,D), targets', mask') with modality fusion applied."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params["embed"], tokens)
    targets = batch.get("targets")
    mask = batch.get("mask")
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)  # early fusion
        if targets is not None:
            B, P = patches.shape[:2]
            pad_t = jnp.zeros((B, P), targets.dtype)
            pad_m = jnp.zeros((B, P), mask.dtype)
            targets = jnp.concatenate([pad_t, targets], axis=1)
            mask = jnp.concatenate([pad_m, mask], axis=1)
    return x, targets, mask


def forward_hidden(cfg, params, batch, *, remat=True, window_override=None):
    """-> (hidden (B,S,D), targets, mask, moe_aux)."""
    if cfg.encoder_decoder:
        enc_out = encdec.encoder_apply(cfg, params["trunk"], batch["frames"],
                                       remat=remat)
        x = embed_tokens(cfg, params["embed"], batch["tokens"])
        h = encdec.decoder_apply(cfg, params["trunk"], x, enc_out, remat=remat)
        return h, batch.get("targets"), batch.get("mask"), jnp.zeros(())
    x, targets, mask = _embed_batch(cfg, params, batch)
    x = _constrain_batch_axis(cfg, x)
    h, aux = transformer.decoder_apply(cfg, params["trunk"], x, remat=remat,
                                       window_override=window_override)
    h = _constrain_batch_axis(cfg, h)
    return h, targets, mask, aux


def chunked_lm_loss(cfg, params, hidden, targets, mask):
    """Sequence-chunked masked cross entropy. Never materializes (B,S,V)."""
    B, S, D = hidden.shape
    chunk = LOSS_CHUNK if S % LOSS_CHUNK == 0 and S > LOSS_CHUNK else S
    n = S // chunk

    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(acc, inp):
        h, t, m = inp
        logits = unembed(cfg, params["embed"], h).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return acc + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts, ms))
    return total / jnp.clip(jnp.sum(mask), 1.0)


def loss_fn(cfg, params, batch, *, remat=True, aux_weight=0.01):
    hidden, targets, mask, aux = forward_hidden(cfg, params, batch,
                                                remat=remat)
    loss = chunked_lm_loss(cfg, params, hidden, targets.astype(jnp.int32),
                           mask.astype(jnp.float32))
    return loss + aux_weight * aux


def classify_logits(cfg, params, head, batch):
    """mean-pool classification (spam task)."""
    hidden, _, _, _ = forward_hidden(cfg, params, batch, remat=False)
    mask = batch["mask"].astype(hidden.dtype)[..., None]
    pooled = jnp.sum(hidden * mask, axis=1) / jnp.clip(
        jnp.sum(mask, axis=1), 1.0)
    return pooled @ head["w"] + head["b"]


def classify_loss(cfg, params, head, batch):
    logits = classify_logits(cfg, params, head, batch).astype(jnp.float32)
    labels = batch["label"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


# --------------------------------------------------------------------------
# inference
# --------------------------------------------------------------------------

def prefill_logits(cfg, params, batch, *, window_override=None):
    """Process the full prompt, return last-position logits (B, V)."""
    hidden, _, _, _ = forward_hidden(cfg, params, batch, remat=False,
                                     window_override=window_override)
    return unembed(cfg, params["embed"], hidden[:, -1, :])


def init_cache(cfg, batch, max_len, dtype=jnp.float32):
    if cfg.encoder_decoder:
        raise NotImplementedError(
            "whisper decode is out of the assigned grid (DESIGN.md)")
    return transformer.init_decode_cache(cfg, batch, max_len, dtype)


def decode_step(cfg, params, cache, tokens, *, window_override=None):
    """tokens: (B, 1) next token ids -> (logits (B, V), new cache)."""
    x = embed_tokens(cfg, params["embed"], tokens,
                     positions=cache["index"][None])
    h, cache = transformer.decoder_decode(cfg, params["trunk"], x, cache,
                                          window_override=window_override)
    logits = unembed(cfg, params["embed"], h[:, 0, :])
    return logits, cache
