"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Semantics (per head, k/v head dim ``hd``), for t = 1..T:

    o_t = r_t @ S_{t-1} + (r_t . (u * k_t)) v_t        (bonus on current token)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T                (w_t in (0,1), per k-chan)

with the decay w_t *data dependent* through a low-rank projection
(w_t = exp(-exp(w0 + tanh(x_w A_w) B_w))) — the Finch contribution.

Training uses the chunked-parallel form (flash-linear-attention style):
within a chunk of length CT the quadratic term is a masked matmul on
decay-rescaled r/k, across chunks a ``lax.scan`` carries the (hd, hd) state.
This keeps the compute MXU-shaped instead of a length-T scalar scan.
``rwkv_scan_reference`` is the sequential oracle used by the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, norm_apply

CHUNK = 16
LOG_CLAMP = -30.0  # clamp cumulative log-decay used in ratio rescaling
# NOTE: the chunked path is exact while the per-chunk cumulative log-decay
# stays above LOG_CLAMP (|sum over 16 steps of log w| < 30) — true for
# trained RWKV decays (w ~ 0.9..0.999) and for our init; beyond it the
# rescale saturates (documented approximation, see tests/test_rwkv.py).


def rwkv_block_init(cfg, key):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    dff = cfg.d_ff
    lora = max(32, d // 64)
    ks = jax.random.split(key, 12)
    return {
        "tm": {  # time mix
            "mix_r": jnp.full((d,), 0.5), "mix_k": jnp.full((d,), 0.5),
            "mix_v": jnp.full((d,), 0.5), "mix_w": jnp.full((d,), 0.5),
            "mix_g": jnp.full((d,), 0.5),
            "wr": _dense_init(ks[0], (d, d)),
            "wk": _dense_init(ks[1], (d, d)),
            "wv": _dense_init(ks[2], (d, d)),
            "wg": _dense_init(ks[3], (d, d)),
            "wo": _dense_init(ks[4], (d, d)),
            "w0": jnp.full((d,), -1.0),           # base decay logit
            "w_lora_a": _dense_init(ks[5], (d, lora)),
            "w_lora_b": _dense_init(ks[6], (lora, d), scale=0.01),
            "u": (jax.random.normal(ks[7], (H, hd)) * 0.1),  # bonus
            "ln_x_scale": jnp.ones((d,)),
        },
        "cm": {  # channel mix
            "mix_k": jnp.full((d,), 0.5), "mix_r": jnp.full((d,), 0.5),
            "wk": _dense_init(ks[8], (d, dff)),
            "wv": _dense_init(ks[9], (dff, d)),
            "wr": _dense_init(ks[10], (d, d)),
        },
    }


def _token_shift(x, last):
    """x: (B,T,D); last: (B,D) value preceding x[:,0]. -> shifted, new_last."""
    shifted = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def _time_mix_inputs(p, x, shifted):
    def mix(name):
        m = p["mix_" + name]
        return x + (shifted - x) * m
    return mix("r"), mix("k"), mix("v"), mix("w"), mix("g")


def _decay_logit(p, xw):
    # data-dependent decay (Finch): logit in log-space; w = exp(-exp(lw))
    lw = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    return -jnp.exp(jnp.clip(lw, -8.0, 4.0))  # = log w_t  (<= 0)


def _group_norm(x, scale, H):
    """per-head layernorm on (B,T,H*hd)."""
    B, T, D = x.shape
    xh = x.reshape(B, T, H, D // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y.reshape(B, T, D) * scale).astype(x.dtype)


def _wkv_chunked(r, k, v, logw, u, state):
    """Chunked-parallel WKV6.

    r,k,v: (B,T,H,hd); logw: (B,T,H,hd) (log decay, <=0);
    u: (H,hd); state: (B,H,hd,hd)  ->  (o: (B,T,H,hd), state')
    """
    B, T, H, hd = r.shape
    # pad T to a CHUNK multiple (k=v=0, logw=0 contribute nothing): keeps
    # chunks short so the log-decay rescale never exceeds LOG_CLAMP
    T_pad = (T + CHUNK - 1) // CHUNK * CHUNK if T > CHUNK else T
    if T_pad != T:
        pad = ((0, 0), (0, T_pad - T), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    ct = CHUNK if T_pad % CHUNK == 0 and T_pad >= CHUNK else T_pad
    nc = T_pad // ct

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, ct, H, hd), 1, 0)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))  # (nc,B,ct,H,hd)

    def chunk_body(S, inp):
        ri, ki, vi, lwi = (a.astype(jnp.float32) for a in inp)
        la = jnp.cumsum(lwi, axis=1)                    # (B,ct,H,hd)
        la_prev = la - lwi                              # cum log decay before t
        la_c = jnp.clip(la, LOG_CLAMP, 0.0)
        la_prev_c = jnp.clip(la_prev, LOG_CLAMP, 0.0)
        r_t = ri * jnp.exp(la_prev_c)                   # rescaled r
        k_t = ki * jnp.exp(-la_c)                       # rescaled k
        # intra-chunk quadratic term, strictly-lower mask (s < t)
        P = jnp.einsum("bthd,bshd->bhts", r_t, k_t)
        mask = jnp.tril(jnp.ones((ct, ct), bool), k=-1)
        P = jnp.where(mask[None, None], P, 0.0)
        o = jnp.einsum("bhts,bshd->bthd", P, vi)
        # current-token bonus
        bonus = jnp.einsum("bthd,bthd->bth", ri, u[None, None] * ki)
        o = o + bonus[..., None] * vi
        # contribution from carried state
        o = o + jnp.einsum("bthd,bhde->bthe", r_t, S)
        # state update
        la_T = la[:, -1:, :, :]                         # (B,1,H,hd)
        k_dec = ki * jnp.exp(jnp.clip(la_T - la, LOG_CLAMP, 0.0))
        S_new = S * jnp.exp(la_T[:, 0])[..., None] + jnp.einsum(
            "bthd,bthe->bhde", k_dec, vi)
        return S_new, o.astype(r.dtype)

    state, oc = jax.lax.scan(chunk_body, state.astype(jnp.float32),
                             (rc, kc, vc, lwc))
    o = jnp.moveaxis(oc, 0, 1).reshape(B, T_pad, H, hd)[:, :T]
    return o, state


def rwkv_scan_reference(r, k, v, logw, u, state):
    """Sequential oracle for the chunked form (tests)."""
    B, T, H, hd = r.shape

    def step(S, inp):
        rt, kt, vt, lwt = (a.astype(jnp.float32) for a in inp)
        o = jnp.einsum("bhd,bhde->bhe", rt, S)
        o = o + jnp.einsum("bhd,bhd->bh", rt, u[None] * kt)[..., None] * vt
        S = S * jnp.exp(lwt)[..., None] + kt[..., None] * vt[..., None, :]
        return S, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, o = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), state


def _project_rkvwg(cfg, p, x, shifted):
    B, T, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    xr, xk, xv, xw, xg = _time_mix_inputs(p, x, shifted)
    r = (xr @ p["wr"]).reshape(B, T, H, hd)
    k = (xk @ p["wk"]).reshape(B, T, H, hd)
    v = (xv @ p["wv"]).reshape(B, T, H, hd)
    g = xg @ p["wg"]
    logw = _decay_logit(p, xw).reshape(B, T, H, hd)
    return r, k, v, g, logw, H


def time_mix_apply(cfg, p, x, state, last):
    """x: (B,T,D); state: (B,H,hd,hd); last: (B,D) prev token (token shift)."""
    shifted, new_last = _token_shift(x, last)
    r, k, v, g, logw, H = _project_rkvwg(cfg, p, x, shifted)
    o, state = _wkv_chunked(r, k, v, logw, p["u"], state)
    B, T, d = x.shape
    o = _group_norm(o.reshape(B, T, d), p["ln_x_scale"], H)
    y = (o * jax.nn.silu(g)) @ p["wo"]
    return y, state, new_last


def channel_mix_apply(cfg, p, x, last):
    shifted, new_last = _token_shift(x, last)
    xk = x + (shifted - x) * p["mix_k"]
    xr = x + (shifted - x) * p["mix_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), new_last


def rwkv_state_init(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "last_tm": jnp.zeros((batch, d), dtype),
        "last_cm": jnp.zeros((batch, d), dtype),
    }


def rwkv_block_apply(cfg, params, norms, norm_fn, x, state):
    """Full RWKV block (pre-norm time-mix + pre-norm channel-mix)."""
    h, S, last_tm = time_mix_apply(
        cfg, params["tm"], norm_fn(norms[0], x), state["S"], state["last_tm"])
    x = x + h
    h, last_cm = channel_mix_apply(
        cfg, params["cm"], norm_fn(norms[1], x), state["last_cm"])
    x = x + h
    return x, {"S": S, "last_tm": last_tm, "last_cm": last_cm}
