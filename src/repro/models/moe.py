"""Mixture-of-experts FFN with capacity-based einsum dispatch.

Mesh-TF / Switch-Transformer lineage: tokens are split into groups of
``cfg.moe_group_size``; within a group each token is routed to its top-k
experts subject to a per-expert capacity C = ceil(k * G / E * capacity_factor)
(overflow tokens are dropped — the standard trade for a static-shape, SPMD-
friendly dispatch). The dispatched activations (n_groups, E, C, D) carry the
expert dim, which the launch-layer sharding rules place on the ``model`` mesh
axis — XLA inserts the expert-parallel all-to-all.

Returns the load-balancing auxiliary loss alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import _act, _dense_init, mlp_apply, mlp_init


def moe_init(cfg, key):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": _dense_init(ks[0], (D, E)),
        "w_in": _dense_init(ks[1], (E, D, F)),
        "w_out": _dense_init(ks[2], (E, F, D)),
    }
    if cfg.glu:
        p["w_gate"] = _dense_init(ks[3], (E, D, F))
    if cfg.moe_shared_expert:
        p["shared"] = mlp_init(cfg, ks[4])
    return p


def _constrain(cfg, x, spec):
    """Pin expert-parallel sharding when a mesh with a 'model' axis is
    ambient (no-op in unmeshed smoke tests). §Perf hillclimb change."""
    if not cfg.moe_dispatch_constraint:
        return x
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def expert_capacity(cfg, group_size: int) -> int:
    c = int(cfg.experts_per_token * group_size / cfg.num_experts
            * cfg.capacity_factor)
    return max(4, c)


def moe_apply(cfg, params, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * S
    G = cfg.moe_group_size if N % cfg.moe_group_size == 0 else N
    G = min(G, N)
    Ng = N // G
    C = expert_capacity(cfg, G)

    xt = x.reshape(Ng, G, D)
    logits = (xt @ params["router"]).astype(jnp.float32)       # (Ng, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_ids = jax.lax.top_k(probs, k)              # (Ng, G, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # slot assignment: slot j tokens claim capacity after slots < j
    counts = jnp.zeros((Ng, 1, E), jnp.int32)
    dispatch = jnp.zeros((Ng, G, E, C), x.dtype)
    combine = jnp.zeros((Ng, G, E, C), x.dtype)
    for j in range(k):
        oh = jax.nn.one_hot(gate_ids[..., j], E, dtype=jnp.int32)  # (Ng,G,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts                  # (Ng,G,E)
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)
        d_j = pos_oh * keep.astype(x.dtype)[..., None]             # (Ng,G,E,C)
        dispatch = dispatch + d_j
        combine = combine + d_j * gate_vals[..., j, None, None].astype(x.dtype)
        counts = counts + jnp.sum(oh, axis=1, keepdims=True)

    # expert-parallel compute (E on the 'model' axis; the dispatch einsum
    # is the all-to-all boundary when the constraint flag is on)
    xe = jnp.einsum("ngd,ngec->necd", xt, dispatch)            # (Ng,E,C,D)
    xe = _constrain(cfg, xe, (None, "model", None, None))
    h = jnp.einsum("necd,edf->necf", xe, params["w_in"])
    h = _act(cfg, h)
    if cfg.glu:
        h = h * jnp.einsum("necd,edf->necf", xe, params["w_gate"])
    ye = jnp.einsum("necf,efd->necd", h, params["w_out"])
    ye = _constrain(cfg, ye, (None, "model", None, None))
    y = jnp.einsum("necd,ngec->ngd", ye, combine).reshape(B, S, D)

    if cfg.moe_shared_expert:
        y = y + mlp_apply(cfg, params["shared"], x)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_ids[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
