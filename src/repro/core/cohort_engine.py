"""Vectorized cohort execution engine: run a whole cohort's local training
as ONE compiled computation.

The AzureML-style simulator (paper §5, Fig. 10) and every scale study on top
of it previously executed each client's local update in a serial Python
loop — one jit dispatch, one tiny-matmul trace per client per round. This
module stacks the cohort along a leading *client axis* (batches always;
params too, for personalized / clustered / mixed-version-async schemes) and
runs all clients' local steps with a single ``jax.vmap``-over-clients call,
optionally ``shard_map``-ed so the client axis shards over the mesh's
``data`` devices for pod-scale cohorts.

Layout conventions (leading axes):

    shared params   : leaves  (...,)                 replicated over clients
    stacked params  : leaves  (n_clients, ...)       personalized path
    stacked batches : leaves  (n_clients, local_steps, B, ...)

Three execution paths over the same ``local_update`` body (so parity is a
testable property, not an aspiration):

    serial_cohort  — python loop over per-client jitted calls (reference)
    vmap_cohort    — jit(vmap(local_update))            [default fast path]
    shard_cohort   — jit(shard_map(vmap(local_update))) [client axis over
                     the mesh's data axis; degenerates to vmap on 1 device]

``CohortEngine`` packages a ``LocalTrainSpec`` + per-client batch sampling
into the object the simulator / orchestrator consume; its ``make_trainer``
emits a paper-Fig.-3-compatible serial trainer from the SAME local_update,
which is both the migration path for existing SimClient code and the
reference the parity tests check the vectorized paths against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import tracing  # stdlib-only; safe for core to depend on
from repro.optim.adamw import Optimizer, apply_updates


@dataclass(frozen=True)
class LocalTrainSpec:
    """What one client's local round looks like.

    loss_fn(params, batch) -> scalar; optimizer is the functional
    init/update pair from ``repro.optim``; every client runs exactly
    ``local_steps`` steps on batches of identical shape (vectorization
    requires uniform local work — ragged cohorts pad or fall back to the
    serial path).
    """
    loss_fn: Callable
    optimizer: Optimizer
    local_steps: int = 1


def make_local_update(spec: LocalTrainSpec) -> Callable:
    """-> local_update(params, client_batches) -> (delta, mean_loss).

    client_batches: pytree with leaves (local_steps, B, ...). The returned
    delta (new - start params, f32) is the client's pseudo-gradient payload
    in the paper's convention (strategies add it; ``launch/fl_step.py``
    negates it where a server *gradient* is expected).
    """
    opt = spec.optimizer

    def local_update(params, client_batches):
        def body(carry, batch):
            p, s = carry
            loss, g = jax.value_and_grad(spec.loss_fn)(p, batch)
            upd, s = opt.update(g, s, p)
            return (apply_updates(p, upd), s), loss

        (new_params, _), losses = jax.lax.scan(
            body, (params, opt.init(params)), client_batches)
        delta = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
            new_params, params)
        return delta, jnp.mean(losses)

    return local_update


def serial_cohort(spec: LocalTrainSpec) -> Callable:
    """Reference path: one jitted per-client call, python loop over clients.

    -> f(params, stacked_batches) -> (stacked_deltas, losses (n,)).
    ``params`` leaves may carry a leading client axis (personalized) —
    detected against the batch stacking, mirroring vmap_cohort's in_axes.
    """
    one = jax.jit(make_local_update(spec))

    def run(params, stacked_batches, *, personalized=False):
        n = jax.tree.leaves(stacked_batches)[0].shape[0]
        deltas, losses = [], []
        for j in range(n):
            p_j = jax.tree.map(lambda a: a[j], params) if personalized \
                else params
            b_j = jax.tree.map(lambda a: a[j], stacked_batches)
            d, l = one(p_j, b_j)
            deltas.append(d)
            losses.append(l)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        return stacked, jnp.stack(losses)

    return run


def vmap_cohort(spec: LocalTrainSpec, *, personalized: bool = False
                ) -> Callable:
    """One compiled vmap-over-clients call.

    -> f(params, stacked_batches) -> (stacked_deltas, losses (n,)).
    personalized=True: params leaves carry a leading (n_clients,) axis.
    """
    f = make_local_update(spec)
    return jax.jit(jax.vmap(f, in_axes=(0 if personalized else None, 0)))


def shard_cohort(spec: LocalTrainSpec, mesh, *, axis: str = "data",
                 personalized: bool = False) -> Callable:
    """vmap_cohort with the client axis sharded over ``mesh``'s ``axis``.

    Each device traces a vmap over its n/axis_size local clients; params
    are replicated (or client-sharded when personalized). n_clients must
    divide the axis size. On a 1-device mesh this is exactly vmap_cohort.
    """
    f = jax.vmap(make_local_update(spec),
                 in_axes=(0 if personalized else None, 0))
    in_specs = (P(axis) if personalized else P(), P(axis))
    sharded = compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                               out_specs=(P(axis), P(axis)))
    return jax.jit(sharded)


def stack_trees(trees: list):
    """[pytree, ...] -> pytree with leading len(trees) axis (np.stack)."""
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *trees)


def unstack_tree(tree, n: int):
    """pytree with leading n axis -> [pytree, ...] of length n.

    Pulls each leaf to host ONCE before slicing — per-client slicing of
    device arrays would issue n_clients * n_leaves separate transfers,
    which dominates the whole round at simulator scale."""
    host = jax.tree.map(np.asarray, tree)
    return [jax.tree.map(lambda a: a[j], host) for j in range(n)]


class CohortEngine:
    """Batched cohort executor the simulator / orchestrator plug into.

    batch_fn(client_id, round_idx) -> pytree with leaves
    (local_steps, B, ...) — the client's local data for that round
    (deterministic in (client_id, round_idx) so serial and vectorized
    paths see identical data).

    mesh/axis select the shard_map path; mesh=None (default) uses plain
    vmap — right for CPU and single-host runs.
    """

    def __init__(self, spec: LocalTrainSpec, batch_fn: Callable,
                 template_params=None, *, mesh=None, axis: str = "data",
                 wave_size: int | None = None):
        # wave_size: stream cohorts LARGER than this through fixed-width
        # compiled waves (the last wave pads by repeating its final member
        # and the pad rows are dropped) — one compiled shape serves any
        # cohort size, bounding both compile count and device memory at
        # 10^4-10^5-client cohorts. Per-client outputs are bit-identical
        # to the single-dispatch path (vmap width does not change per-row
        # float bits — the serial/vmap parity property). None/0 = off.
        self.spec = spec
        self.batch_fn = batch_fn
        self.template = template_params
        self.mesh = mesh
        self.axis = axis
        self.wave_size = wave_size
        self._local = jax.jit(make_local_update(spec))
        self._fns: dict = {}

    def _cohort_fn(self, personalized: bool):
        key = bool(personalized)
        if key not in self._fns:
            if self.mesh is not None:
                self._fns[key] = shard_cohort(self.spec, self.mesh,
                                              axis=self.axis,
                                              personalized=personalized)
            else:
                self._fns[key] = vmap_cohort(self.spec,
                                             personalized=personalized)
            label = "personalized" if key else "shared"
            tracing.register_jit(f"cohort_engine.{label}", self._fns[key])
        return self._fns[key]

    # -- core entry points -------------------------------------------------

    def run_cohort(self, params, client_ids, round_idx: int):
        """Shared-params cohort -> {cid: (delta, n_samples, metrics)}.
        client_ids must be unique (one submission per client per round)."""
        out = self.run_cohort_stacked(params, client_ids, round_idx)
        return dict(zip(client_ids, self._unpack(*out)))

    def run_cohort_stacked(self, params, client_ids, round_idx: int):
        """Fused-path variant of :meth:`run_cohort`: returns
        ``(stacked_deltas, losses (n,), n_samples_per_client)`` with the
        client axis still stacked on device — feed straight into the
        vectorized privacy pipeline (``privacy_engine.aggregate_stacked`` /
        ``ManagementService.submit_cohort``) without the unstack-to-host
        round trip that ``run_cohort`` pays."""
        w = self.wave_size
        with tracing.span("local_train", n=len(client_ids),
                          round=round_idx):
            if w and len(client_ids) > w:
                return self._run_waves(params, list(client_ids),
                                       round_idx, w)
            batches = stack_trees([self.batch_fn(cid, round_idx)
                                   for cid in client_ids])
            if self.mesh is not None:
                self._check_divisible(len(client_ids))
            deltas, losses = self._cohort_fn(False)(params, batches)
            return deltas, losses, self._n_samples(batches, stacked=True)

    def _run_waves(self, params, client_ids, round_idx: int, w: int):
        """Stream an oversized cohort through fixed-width ``w``-client
        waves of the shared-params executable. Each wave's outputs are
        pulled to host before the next dispatches, so device memory holds
        ONE wave regardless of cohort size; the short last wave pads by
        repeating its final member (pad rows dropped on host), so a single
        compiled shape serves every cohort size."""
        if self.mesh is not None:
            self._check_divisible(w)
        fn = self._cohort_fn(False)
        delta_parts, loss_parts, n_samples = [], [], None
        for s in range(0, len(client_ids), w):
            chunk = client_ids[s:s + w]
            n_real = len(chunk)
            if n_real < w:
                chunk = chunk + [chunk[-1]] * (w - n_real)
            with tracing.span("train_wave", wave=s // w, w=w,
                              n_real=n_real):
                batches = stack_trees([self.batch_fn(cid, round_idx)
                                       for cid in chunk])
                deltas, losses = fn(params, batches)
                if n_samples is None:
                    n_samples = self._n_samples(batches, stacked=True)
                host = jax.tree.map(np.asarray, deltas)
                delta_parts.append(jax.tree.map(lambda a: a[:n_real],
                                                host))
                loss_parts.append(np.asarray(losses)[:n_real])
        stacked = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0),
                               *delta_parts)
        return stacked, jnp.asarray(np.concatenate(loss_parts)), n_samples

    def run_cohort_personalized(self, params_list, client_ids, round_idxs):
        """Per-client params (clustered FL branches, async mixed-version
        cohorts) -> [(delta, n_samples, metrics), ...] in input order.
        Positional because async event groups may contain the same client
        twice (a fast client re-submitting before the next server step)."""
        return self._unpack(*self.run_cohort_personalized_stacked(
            params_list, client_ids, round_idxs))

    def run_cohort_personalized_stacked(self, params_list, client_ids,
                                        round_idxs):
        """Fused-path variant of :meth:`run_cohort_personalized`: returns
        ``(stacked_deltas, losses (n,), n_samples_per_client)`` with the
        client axis still stacked on device — feed straight into the async
        bulk route (``ManagementService.submit_updates_async`` ->
        ``AsyncServer.submit_batch``) without the unstack-to-host round
        trip. Positional like its per-client twin (async event groups may
        repeat a client)."""
        with tracing.span("local_train", n=len(client_ids),
                          personalized=True):
            stacked_params = jax.tree.map(lambda *xs: jnp.stack(xs),
                                          *params_list)
            batches = stack_trees([self.batch_fn(cid, r)
                                   for cid, r in zip(client_ids,
                                                     round_idxs)])
            if self.mesh is not None:
                self._check_divisible(len(client_ids))
            deltas, losses = self._cohort_fn(True)(stacked_params, batches)
            return deltas, losses, self._n_samples(batches, stacked=True)

    # -- adapters ----------------------------------------------------------

    def make_trainer(self, client_id):
        """Paper-Fig.-3 serial trainer from the same local_update — the
        migration path for legacy SimClient code and the parity reference."""
        from repro.checkpoint import deserialize_pytree

        def trainer(blob, round_idx):
            params = deserialize_pytree(blob, like=self.template)
            b = jax.tree.map(jnp.asarray, self.batch_fn(client_id, round_idx))
            delta, loss = self._local(params, b)
            n = self._n_samples(b, stacked=False)
            return (jax.tree.map(lambda a: np.asarray(a, np.float32), delta),
                    n, {"loss": float(loss)})

        return trainer

    # -- internals ---------------------------------------------------------

    def _check_divisible(self, n: int):
        size = self.mesh.shape[self.axis]
        if n % size:
            raise ValueError(
                f"cohort of {n} does not divide mesh axis "
                f"{self.axis!r} of size {size}")

    @staticmethod
    def _n_samples(batches, *, stacked: bool) -> int:
        leaf = jax.tree.leaves(batches)[0]
        steps, b = leaf.shape[(1 if stacked else 0):][:2]
        return int(steps) * int(b)

    def _unpack(self, deltas, losses, n_samples):
        losses = np.asarray(losses)
        return [(delta, n_samples, {"loss": float(losses[j])})
                for j, delta in enumerate(unstack_tree(deltas,
                                                       len(losses)))]
