"""Pairwise-mask secure aggregation math (paper §4.1, Bonawitz-style).

Client i in a virtual group of n uploads, instead of its quantized update
x_i, the masked payload

    y_i = x_i + sum_{v > i} s_{i,v} - sum_{v < i} s_{v,i}      (mod 2^32)

where s_{u,v} is the pair (u,v)'s KDF-expanded mask. Summing all y_i cancels
every mask term exactly (uint32 wraparound arithmetic is associative and
commutative), so sum y_i == sum x_i (mod 2^32) bit-exactly — `tests/` proves
this with hypothesis over arbitrary group sizes and seeds.

Cost: each client expands n-1 masks over the full update vector — the
O(n^2)-total cost the paper's Virtual Groups exist to cap. This module is the
pure-jnp reference; ``repro.kernels.mask_gen`` is the Pallas hot-path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kdf import U32, mask_stream, pair_seed


def net_mask(i: int, n: int, round_seed, size: int, offset: int = 0):
    """Net mask for client i in a VG of n clients: (size,) uint32."""
    if n == 1:
        return jnp.zeros((size,), U32)

    others = jnp.array([v for v in range(n) if v != i], U32)
    i_arr = jnp.full_like(others, i)
    lo = jnp.minimum(i_arr, others)
    hi = jnp.maximum(i_arr, others)
    seeds = jax.vmap(lambda u, v: pair_seed(round_seed, u, v))(lo, hi)
    masks = jax.vmap(lambda s: mask_stream(s, offset, size))(seeds)
    # + for pairs where i is the lower index, - (mod 2^32) otherwise
    sign_pos = (i_arr < others)[:, None]
    signed = jnp.where(sign_pos, masks, jnp.zeros((), U32) - masks)
    return jnp.sum(signed, axis=0, dtype=U32)


def apply_mask(q, i: int, n: int, round_seed, offset: int = 0):
    """q: (size,) uint32 quantized update -> masked payload (size,) uint32."""
    return q + net_mask(i, n, round_seed, q.shape[0], offset)


def net_mask_traced(i, vg_id, vg_size: int, round_seed, size: int,
                    offset: int = 0):
    """Traced-index variant for in-jit cohorts (launch/fl_step.py).

    i: traced global silo id; vg_id: traced virtual-group id; peers are the
    ``vg_size`` silos of that VG (global ids vg_id*vg_size + 0..g-1).
    Returns the net mask (size,) uint32; zero contribution for peer == i.
    """
    peers = jnp.asarray(vg_id, U32) * U32(vg_size) + jnp.arange(vg_size,
                                                                dtype=U32)
    i = jnp.asarray(i, U32)

    def one(peer):
        lo = jnp.minimum(i, peer)
        hi = jnp.maximum(i, peer)
        seed = pair_seed(round_seed, lo, hi)
        m = mask_stream(seed, offset, size)
        signed = jnp.where(i < peer, m, jnp.zeros((), U32) - m)
        return jnp.where(peer == i, jnp.zeros((), U32), signed)

    return jnp.sum(jax.vmap(one)(peers), axis=0, dtype=U32)


def modular_sum(payloads):
    """Stage-1 VG aggregation: wrapping uint32 sum over the client axis.

    payloads: (n, size) uint32 -> (size,) uint32 == sum of unmasked updates.
    """
    return jnp.sum(payloads.astype(U32), axis=0, dtype=U32)


@partial(jax.jit, static_argnums=(1,))
def protect_cohort(qs, vg_size: int, round_seed):
    """Vectorized whole-cohort masking: one jit, i traced via vmap.

    qs: (n, size) uint32 with n % vg_size == 0 (uniform VGs, protocol order
    = array order). Returns masked payloads, same shape. This is the
    cohort-scale path used by the scaling benchmark and the production
    fl_step (per-leaf variant there)."""
    n = qs.shape[0]
    ids = jnp.arange(n, dtype=U32)
    vgs = ids // U32(vg_size)

    def protect(i, vg, q):
        return q + net_mask_traced(i, vg, vg_size, round_seed, q.shape[0])

    return jax.vmap(protect)(ids, vgs, qs)


def protect_cohort_grouped(qs, idxs, group_seeds, vg_size: int,
                           offset: int = 0):
    """Vectorized masking with the serial protocol's PER-GROUP seeds.

    ``protect_cohort`` above addresses pairs by global silo id under one
    shared round seed (the launch/fl_step convention); the cross-device
    reference protocol (``secure_agg.secure_aggregate_round``) instead
    domain-separates groups by seed and addresses pairs by index WITHIN the
    group. This is that scheme, vmapped: client k has within-group index
    ``idxs[k]`` and its group's seed ``group_seeds[k]`` — bit-identical to
    ``apply_mask(q, idx, vg_size, seed)`` per client (net_mask_traced with
    vg_id=0 reduces to exactly those pair seeds).

    qs: (n, size) uint32; idxs: (n,) uint32; group_seeds: (n, 2) uint32.
    All groups must share ``vg_size`` (the privacy engine buckets ragged
    plans by group size first). Traceable — runs inside the engine's jit.
    """
    size = qs.shape[1]

    def protect(q, i, seed):
        return q + net_mask_traced(i, jnp.zeros((), U32), vg_size, seed,
                                   size, offset)

    return jax.vmap(protect)(qs, idxs, group_seeds)


def vg_sums(payloads, vg_size: int):
    """(n, size) -> (n/vg_size, size) wrapping per-VG sums (stage 1)."""
    n, size = payloads.shape
    return jnp.sum(payloads.reshape(n // vg_size, vg_size, size),
                   axis=1, dtype=U32)
