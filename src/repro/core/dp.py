"""Differential privacy (paper §4.2): clipping, Gaussian mechanism (local or
global), and a subsampled Rényi-DP accountant (Wang et al. 2018 / Mironov).

On task configuration the user picks the mechanism ("local": each client
noises its clipped update before upload; "global": the server noises the
aggregate) and the noise multiplier z = sigma / clip. The accountant exposes
the current privacy loss epsilon at given delta, as the Florida dashboard
does.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

DEFAULT_ORDERS = tuple(range(2, 33)) + (40, 48, 64, 128, 256)


@dataclass(frozen=True)
class DPConfig:
    mechanism: str = "off"        # off | local | global
    clip_norm: float = 0.5        # paper §5.1 uses 0.5
    noise_multiplier: float = 0.0  # z = sigma / clip
    delta: float = 1e-5


# --------------------------------------------------------------------------
# mechanism
# --------------------------------------------------------------------------

def flat_local_dp(flat, key, *, clip_norm: float, sigma: float):
    """Canonical per-client DP row: L2-clip a FLAT f32 update to
    ``clip_norm``, then add N(0, sigma^2) noise (sigma == 0 skips it).

    This single function is the bit-exactness anchor of the privacy
    pipeline: the serial reference jits it per client and the vectorized
    engine runs ``vmap`` of the SAME function inside its cohort jit, so
    both sides see identical XLA op patterns (eager execution differs from
    jit by FMA contraction in the clip-scale/noise chain — measured, not
    hypothetical)."""
    flat = flat.astype(jnp.float32)
    norm = jnp.linalg.norm(flat)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    clipped = flat * scale
    if sigma > 0:
        clipped = clipped + sigma * jax.random.normal(key, flat.shape,
                                                      jnp.float32)
    return clipped


_flat_local_dp_jit = jax.jit(flat_local_dp,
                             static_argnames=("clip_norm", "sigma"))


@partial(jax.jit, static_argnames=("clip_norm", "sigma"))
def _flat_local_dp_rows_jit(rows, key, start, *, clip_norm, sigma):
    keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(
        start + jnp.arange(rows.shape[0], dtype=jnp.uint32))
    return jax.vmap(partial(flat_local_dp, clip_norm=clip_norm,
                            sigma=sigma))(rows, keys)


def flat_local_dp_rows(rows, key, start: int, *, clip_norm: float,
                       sigma: float):
    """Batched :func:`flat_local_dp` over (n, size) stacked rows in ONE
    jitted call; row ``i`` uses ``fold_in(key, start + i)`` — the same
    deterministic key-fold the async server's serial submit loop applies at
    submission counter ``start + i``, and the same vmap-of-the-shared-
    function pattern the sync privacy engine uses, so serial and batched
    DP rows are bit-identical (the PR-2 parity contract)."""
    return _flat_local_dp_rows_jit(rows.astype(jnp.float32), key,
                                   jnp.asarray(start, jnp.uint32),
                                   clip_norm=float(clip_norm),
                                   sigma=float(sigma))


def flat_clip(flat, *, clip_norm: float):
    """Clip-only row (the per-client half of the "global" mechanism)."""
    flat = flat.astype(jnp.float32)
    norm = jnp.linalg.norm(flat)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    return flat * scale


_flat_clip_jit = jax.jit(flat_clip, static_argnames=("clip_norm",))


def clip_update(update_pytree, clip_norm: float):
    """Jitted pytree clip — the serial-reference twin of the engine's
    vmapped :func:`flat_clip` (see :func:`flat_local_dp` on why both sides
    must go through jit)."""
    flat, unflatten = ravel_pytree(update_pytree)
    return unflatten(_flat_clip_jit(flat, clip_norm=float(clip_norm)))


def clip_by_global_norm(update_pytree, clip_norm: float):
    """L2-clip a pytree update to ``clip_norm``. Returns (clipped, norm)."""
    flat, unflatten = ravel_pytree(update_pytree)
    norm = jnp.linalg.norm(flat.astype(jnp.float32))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    return unflatten(flat * scale), norm


def add_gaussian_noise(update_pytree, sigma: float, key):
    flat, unflatten = ravel_pytree(update_pytree)
    noise = sigma * jax.random.normal(key, flat.shape, jnp.float32)
    return unflatten(flat + noise)


def local_dp(update_pytree, cfg: DPConfig, key):
    """Client-side: clip then noise (before quantization/masking).

    Routes through the jitted :func:`flat_local_dp` so the serial
    reference and the vectorized privacy engine produce bit-identical
    floats for the same (update, key)."""
    flat, unflatten = ravel_pytree(update_pytree)
    sigma = float(cfg.noise_multiplier * cfg.clip_norm) \
        if cfg.noise_multiplier > 0 else 0.0
    return unflatten(_flat_local_dp_jit(flat, key,
                                        clip_norm=float(cfg.clip_norm),
                                        sigma=sigma))


def global_dp(agg_update_pytree, cfg: DPConfig, n_clients: int, key):
    """Server-side: noise the aggregate; sensitivity = clip / n (mean agg)."""
    if cfg.noise_multiplier > 0:
        sigma = cfg.noise_multiplier * cfg.clip_norm / max(1, n_clients)
        return add_gaussian_noise(agg_update_pytree, sigma, key)
    return agg_update_pytree


# --------------------------------------------------------------------------
# subsampled RDP accountant
# --------------------------------------------------------------------------

def _log_comb(n, k):
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def _compute_rdp_order(q: float, z: float, alpha: int) -> float:
    """RDP of the subsampled Gaussian at integer order alpha.

    Standard upper bound (Mironov/Wang): for q = 1 it is alpha / (2 z^2);
    otherwise log-sum over the binomial expansion.
    """
    if z == 0:
        return float("inf")
    if q >= 1.0:
        return alpha / (2 * z * z)
    if q == 0.0:
        return 0.0
    log_terms = []
    for i in range(alpha + 1):
        log_b = _log_comb(alpha, i)
        log_term = (log_b + i * math.log(q) + (alpha - i) * math.log(1 - q)
                    + (i * i - i) / (2 * z * z))
        log_terms.append(log_term)
    m = max(log_terms)
    log_a = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return log_a / (alpha - 1)


def compute_rdp(q: float, noise_multiplier: float, steps: int,
                orders=DEFAULT_ORDERS):
    """RDP of ``steps`` compositions of the subsampled Gaussian mechanism."""
    return [steps * _compute_rdp_order(q, noise_multiplier, a)
            for a in orders]


def get_privacy_spent(rdp, delta: float, orders=DEFAULT_ORDERS):
    """Convert RDP to (epsilon, best_order) at the given delta."""
    best_eps, best_order = float("inf"), None
    for a, r in zip(orders, rdp):
        if math.isinf(r):
            continue
        eps = r + math.log(1.0 / delta) / (a - 1)
        if eps < best_eps:
            best_eps, best_order = eps, a
    return best_eps, best_order


class RdpAccountant:
    """Tracks privacy loss across rounds (the dashboard's accountant)."""

    def __init__(self, cfg: DPConfig, sample_rate: float,
                 orders=DEFAULT_ORDERS):
        self.cfg = cfg
        self.q = sample_rate
        self.orders = orders
        self._rdp = [0.0] * len(orders)

    def step(self, n_steps: int = 1):
        inc = compute_rdp(self.q, self.cfg.noise_multiplier, n_steps,
                          self.orders)
        self._rdp = [a + b for a, b in zip(self._rdp, inc)]

    def epsilon(self) -> float:
        eps, _ = get_privacy_spent(self._rdp, self.cfg.delta, self.orders)
        return eps
