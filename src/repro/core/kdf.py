"""Counter-based key-derivation function for pairwise mask generation.

The paper (§4.1) requires *cross-platform consistent* mask generation from a
negotiated pair secret: both ends of a client pair must expand the same seed
into the same integer mask stream. Production Florida uses standard KDFs
(HKDF family); here we use ``florida_kdf`` — a deterministic counter-mode ARX
hash (murmur3-finalizer rounds keyed by the pair seed). It is NOT
cryptographically strong (documented in DESIGN.md §2); it has the same
interface and the same algebraic role, and is vector/TPU-friendly — the
Pallas kernel in ``repro.kernels.mask_gen`` implements bit-identical logic.

All arithmetic is uint32 with wraparound (mod 2^32).
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32

# python-int constants (NOT jnp arrays): the same code must trace inside
# Pallas kernel bodies, which reject captured device constants.
_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_GOLDEN = 0x9E3779B9


def _mix(x):
    x = x ^ (x >> U32(16))
    x = x * U32(_M1)
    x = x ^ (x >> U32(15))
    x = x * U32(_M2)
    x = x ^ (x >> U32(16))
    return x


def kdf_u32(k0, k1, ctr):
    """Keyed hash of a uint32 counter -> uint32. All args broadcastable."""
    k0 = jnp.asarray(k0, U32)
    k1 = jnp.asarray(k1, U32)
    x = jnp.asarray(ctr, U32)
    x = _mix(x ^ k0)
    x = _mix(x + (k1 ^ U32(_GOLDEN)))
    x = _mix(x ^ (k0 + k1))
    return x


def pair_seed(round_seed, u, v):
    """Derive the (k0, k1) seed for client pair (u, v), u < v.

    Stands in for Diffie-Hellman key negotiation (DESIGN.md §2): the
    orchestrator distributes ``round_seed``; the pair secret is a keyed hash
    of the ordered pair ids, identical on both clients.
    round_seed: (2,) uint32. Returns (2,) uint32.
    """
    r0, r1 = jnp.asarray(round_seed, U32)
    u = jnp.asarray(u, U32)
    v = jnp.asarray(v, U32)
    s0 = kdf_u32(r0, r1, u * U32(0x01000193) + v)
    s1 = kdf_u32(r1, r0 ^ U32(_GOLDEN), v * U32(0x01000193) + u + U32(1))
    return jnp.stack([s0, s1])


def mask_stream(seed, offset, size):
    """Expand a (2,) uint32 seed into ``size`` uint32 mask words starting at
    stream position ``offset`` (counter mode: position-addressable, which is
    what lets the sharded/per-pod scheme mask disjoint shards independently).
    """
    ctr = jnp.arange(size, dtype=U32) + U32(offset)
    return kdf_u32(seed[0], seed[1], ctr)
