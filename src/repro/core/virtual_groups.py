"""Virtual Group construction (paper §3.1.2).

The Secure Aggregator groups registered clients into Virtual Groups: "large
enough to provide reasonable security and privacy guarantees while managing
the quadratic cost of running the secure protocol". Cost model:

    total pairwise-mask work = n_clients * (vg_size - 1) * update_size
    (vs n_clients * (n_clients - 1) * update_size ungrouped)

``benchmarks/bench_secureagg.py`` measures exactly this O(n^2) -> O(n*g)
reduction (the paper's core scaling argument).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class VirtualGroup:
    vg_id: int
    members: tuple  # client ids, protocol order == index within group


@dataclass
class VGPlan:
    groups: list = field(default_factory=list)

    @property
    def n_clients(self):
        return sum(len(g.members) for g in self.groups)

    def group_of(self, client_id):
        for g in self.groups:
            if client_id in g.members:
                return g
        raise KeyError(client_id)


def make_virtual_groups(client_ids, vg_size: int, seed: int = 0,
                        min_vg_size: int = 2) -> VGPlan:
    """Randomly permute clients into groups of ``vg_size``.

    A trailing remainder smaller than ``min_vg_size`` is merged into the
    previous group (a 1-client "group" would give that client no masking
    peers — no privacy).
    """
    ids = list(client_ids)
    if not ids:
        return VGPlan([])
    rng = np.random.RandomState(seed)
    perm = [ids[i] for i in rng.permutation(len(ids))]
    groups, start, gid = [], 0, 0
    while start < len(perm):
        members = perm[start:start + vg_size]
        start += vg_size
        if len(members) < min_vg_size and groups:
            old = groups.pop()
            members = list(old.members) + members
            gid = old.vg_id
        groups.append(VirtualGroup(gid, tuple(members)))
        gid += 1
    return VGPlan(groups)


def pairwise_cost(n_clients: int, vg_size: int | None = None,
                  min_vg_size: int = 2) -> int:
    """Number of per-element mask expansions across the cohort, for the
    plan ``make_virtual_groups`` actually builds: a trailing remainder
    smaller than ``min_vg_size`` MERGES into the previous group (costing
    (g+rem)(g+rem-1), not g(g-1) + rem(rem-1)); larger remainders form
    their own group. The pre-fix model priced every remainder as its own
    group and under-counted merged plans."""
    if not vg_size or vg_size >= n_clients:
        return n_clients * (n_clients - 1)
    n_full = n_clients // vg_size
    rem = n_clients - n_full * vg_size
    if rem and rem < min_vg_size and n_full:
        merged = vg_size + rem
        return (n_full - 1) * vg_size * (vg_size - 1) + merged * (merged - 1)
    cost = n_full * vg_size * (vg_size - 1)
    if rem:
        cost += rem * (rem - 1)
    return cost


def recommended_vg_size(n_clients: int, target_ratio: float = 0.05,
                        min_size: int = 4, max_size: int = 64) -> int:
    """Pick g so the MPC overhead stays ~target fraction of ungrouped cost."""
    if n_clients <= min_size:
        return max(2, n_clients)
    g = int(math.sqrt(max(1.0, target_ratio) * n_clients)) or min_size
    return int(np.clip(g, min_size, min(max_size, n_clients)))
