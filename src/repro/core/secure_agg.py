"""Two-stage secure aggregation (paper §3.1.2–3.1.3, Fig. 2), over pytrees.

Stage 1 (Secure Aggregator, per Virtual Group):
    each client flattens its update pytree, quantizes it, applies its net
    pairwise mask, and uploads the masked uint32 payload; the VG's wrapping
    modular sum is the *interim result* (masks cancel exactly).

Stage 2 (Master Aggregator):
    interim results are dequantized to mean-updates and combined with the
    user-defined aggregation logic (a Strategy — FedAvg/FedProx/DGA/...),
    optionally after global DP noise.

The async path (paper §4.3) skips masking: with a trusted aggregation
boundary (confidential container / on-pod aggregation) clients upload
quantized updates directly into a buffer — see ``strategies.FedBuff``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import masking
from repro.core.kdf import U32
from repro.core.quantize import (DEFAULT_BITS, DEFAULT_CLIP,
                                 check_headroom, check_master_headroom,
                                 dequantize_interim_sum, quantize)


@dataclass(frozen=True)
class SecureAggConfig:
    bits: int = DEFAULT_BITS
    clip: float = DEFAULT_CLIP
    use_kernels: bool = False   # route mask expansion through Pallas kernels
    vectorized: bool = True     # whole-cohort pipeline as one compiled call
                                # (False: serial per-client reference loop)


def flatten_update(update_pytree):
    """-> (flat f32 vector, unflatten fn)."""
    flat, unflatten = ravel_pytree(update_pytree)
    return flat.astype(jnp.float32), unflatten


def client_protect(update_pytree, idx_in_vg: int, vg_size: int, round_seed,
                   cfg: SecureAggConfig = SecureAggConfig()):
    """Client-side: quantize + mask. Returns (payload uint32, unflatten)."""
    check_headroom(cfg.bits, vg_size)
    flat, unflatten = flatten_update(update_pytree)
    q = quantize(flat, cfg.clip, cfg.bits)
    if cfg.use_kernels:
        from repro.kernels import ops
        payload = ops.mask_apply(q, idx_in_vg, vg_size, round_seed)
    else:
        payload = masking.apply_mask(q, idx_in_vg, vg_size, round_seed)
    return payload, unflatten


def vg_aggregate(payloads):
    """Stage 1: (n, size) uint32 masked payloads -> interim (size,) uint32."""
    return masking.modular_sum(jnp.stack(list(payloads)))


# The combine is jitted ONCE and shared by the serial reference and the
# vectorized engine: jit FMA-contracts the dequantize mul/sub chain, so an
# eager master and a jitted engine would differ by ulps. Interims are exact
# integers on both sides, so sharing this executable makes the final floats
# bit-identical.
_combine_jit = jax.jit(dequantize_interim_sum, static_argnums=(1, 2, 3))


def master_aggregate(interims, group_sizes, unflatten,
                     cfg: SecureAggConfig = SecureAggConfig()):
    """Stage 2: combine interim VG sums into the cohort-mean update pytree.

    interims: list of (size,) uint32; group_sizes: list of int.

    Each interim is exact per the per-group headroom check, but their naive
    uint32 TOTAL wraps once bits + ceil(log2(total_cohort)) > 32 (4097+
    clients at the default 20 bits) — the pre-fix code silently corrupted
    the global mean there. The combine now goes through the split-limb
    accumulator :func:`repro.core.quantize.dequantize_interim_sum`, exact
    for any cohort the master can hold (< 2^16 groups, enforced)."""
    n = int(sum(group_sizes))
    for g in group_sizes:
        check_headroom(cfg.bits, int(g))
    check_master_headroom(len(group_sizes))
    stacked = jnp.stack([i.astype(U32) for i in interims])
    mean_flat = _combine_jit(stacked, n, float(cfg.clip), int(cfg.bits))
    return unflatten(mean_flat)


def secure_aggregate_round(client_updates, vg_plan, round_seed,
                           cfg: SecureAggConfig = SecureAggConfig()):
    """End-to-end reference protocol over a cohort (used by the simulator).

    client_updates: dict client_id -> update pytree (all same structure).
    Returns the cohort-mean update pytree.
    """
    interims, sizes, unflatten = [], [], None
    for group in vg_plan.groups:
        payloads = []
        for idx, cid in enumerate(group.members):
            payload, unflatten = client_protect(
                client_updates[cid], idx, len(group.members),
                _group_seed(round_seed, group.vg_id), cfg)
            payloads.append(payload)
        interims.append(vg_aggregate(payloads))
        sizes.append(len(group.members))
    return master_aggregate(interims, sizes, unflatten, cfg)


def group_seed(round_seed, vg_id):
    """Domain-separated per-VG round seed. ``vg_id`` may be a python int or
    a traced uint32 (the vectorized engine vmaps this over group ids)."""
    from repro.core.kdf import kdf_u32
    rs = jnp.asarray(round_seed, U32)
    vg = jnp.asarray(vg_id, U32)
    return jnp.stack([kdf_u32(rs[0], rs[1], vg),
                      kdf_u32(rs[1], rs[0], vg ^ U32(0x5BF03635))])


_group_seed = group_seed  # backwards-compat alias
