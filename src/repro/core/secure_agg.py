"""Two-stage secure aggregation (paper §3.1.2–3.1.3, Fig. 2), over pytrees.

Stage 1 (Secure Aggregator, per Virtual Group):
    each client flattens its update pytree, quantizes it, applies its net
    pairwise mask, and uploads the masked uint32 payload; the VG's wrapping
    modular sum is the *interim result* (masks cancel exactly).

Stage 2 (Master Aggregator):
    interim results combine through the hierarchical limb-state tree of
    ``repro.core.quantize`` (per-pod shards, exact cross-shard merge, one
    shared dequantize) into the cohort mean, then the user-defined
    aggregation logic (a Strategy — FedAvg/FedProx/DGA/...), optionally
    after global DP noise.

The async path (paper §4.3) skips masking: with a trusted aggregation
boundary (confidential container / on-pod aggregation) clients upload
quantized updates directly into a buffer — see ``strategies.FedBuff``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import masking
from repro.core.kdf import U32
from repro.core.quantize import (DEFAULT_BITS, DEFAULT_CLIP, MAX_MASTER_GROUPS,
                                 check_headroom, check_master_headroom,
                                 check_shard_headroom, dequantize_limb_state,
                                 merge_limb_states, min_master_shards,
                                 quantize, shard_limb_states)


class AggregationRefused(ValueError):
    """Secure aggregation declined to release a result (privacy refusal):
    no survivors at all, or every surviving virtual group fell below
    ``min_survivors_per_vg``. The service layer voids the round."""


@dataclass(frozen=True)
class SecureAggConfig:
    bits: int = DEFAULT_BITS
    clip: float = DEFAULT_CLIP
    use_kernels: bool = False   # route mask expansion through Pallas kernels
    vectorized: bool = True     # whole-cohort pipeline as one compiled call
                                # (False: serial per-client reference loop)
    master_shards: int = 0      # stage-2 combine shards (per-pod tier-1
                                # accumulators); 0 = auto: 1 shard while the
                                # plan fits the single-tier bound, else the
                                # smallest exact shard count
    limbs: int = 3              # stage-2 limb lanes: 3 (default, exact to
                                # ~2^32 VGs) or 4 (adds the 2^48 lane —
                                # headroom for > 2^32-VG plans; bit-identical
                                # to 3 within the 3-limb bound)
    wave_clients: int = 0       # stream cohorts larger than this through
                                # fixed-width compiled waves of ~this many
                                # clients (privacy_engine): one compiled
                                # shape serves any cohort size, partial
                                # VG/limb sums fold exactly (bit-identical
                                # to the single-dispatch path). 0 = off.
    min_survivors_per_vg: int = 2   # dropout recovery refuses (VOIDS) any
                                    # group left with fewer survivors: after
                                    # the server reconstructs the dropped
                                    # net masks, a single-survivor group's
                                    # interim is that client's BARE update.
                                    # 1 restores the pre-refusal behaviour.


def flatten_update(update_pytree):
    """-> (flat f32 vector, unflatten fn). Lossless for f32 leaves (wider
    dtypes narrow to f32 — the protocol's carrier precision)."""
    flat, unflatten = ravel_pytree(update_pytree)
    return flat.astype(jnp.float32), unflatten


def client_protect(update_pytree, idx_in_vg: int, vg_size: int, round_seed,
                   cfg: SecureAggConfig = SecureAggConfig()):
    """Client-side: quantize + mask. Returns (payload uint32, unflatten).

    Precondition (enforced): ``check_headroom(cfg.bits, vg_size)`` — the
    VG's unmasked sum must fit uint32, bits + ceil(log2(g)) <= 32. The
    masked payload itself wraps freely by design (mask cancellation is
    modular); quantization is the chain's only lossy step."""
    check_headroom(cfg.bits, vg_size)
    flat, unflatten = flatten_update(update_pytree)
    q = quantize(flat, cfg.clip, cfg.bits)
    if cfg.use_kernels:
        from repro.kernels import ops
        payload = ops.mask_apply(q, idx_in_vg, vg_size, round_seed)
    else:
        payload = masking.apply_mask(q, idx_in_vg, vg_size, round_seed)
    return payload, unflatten


def vg_aggregate(payloads):
    """Stage 1: (n, size) uint32 masked payloads -> interim (size,) uint32.

    The wrapping sum cancels every pairwise mask exactly; the residue
    equals the sum of unmasked codes, which is EXACT (no wrap) under the
    per-group ``check_headroom`` each payload was built with."""
    return masking.modular_sum(jnp.stack(list(payloads)))


# The stage-2 combine splits into integer limb stages (exact in ANY
# executable — inside the cohort jit, under shard_map, per pod) and ONE
# float tail. Only the tail can differ across compilations (XLA
# FMA-contracts the dequantize mul/sub chain), so it is jitted ONCE here
# and shared by the serial reference, the vectorized engine, and every
# sharded route — that is what keeps the final floats bit-identical.
_shard_limbs_jit = jax.jit(shard_limb_states, static_argnums=(1, 2))
_merge_jit = jax.jit(merge_limb_states)
_finalize_jit = jax.jit(dequantize_limb_state, static_argnums=(1, 2, 3))


def resolve_master_shards(n_groups: int,
                          cfg: SecureAggConfig = SecureAggConfig(),
                          n_shards=None) -> int:
    """Shard count for a stage-2 combine over ``n_groups`` VGs: an explicit
    ``n_shards`` wins, then ``cfg.master_shards``, then auto (1 while the
    single-tier tier-1 bound holds, else the smallest exact count). The
    returned count always satisfies both tier guards or raises."""
    if n_shards is None:
        n_shards = cfg.master_shards or \
            (1 if n_groups < MAX_MASTER_GROUPS else
             min_master_shards(n_groups))
    n_shards = max(1, min(int(n_shards), max(1, n_groups)))
    check_shard_headroom(n_shards)
    check_master_headroom(-(-n_groups // n_shards))
    return n_shards


def combine_limb_states(states, n: int,
                        cfg: SecureAggConfig = SecureAggConfig()):
    """Merge per-shard limb states and dequantize to the cohort-mean flat
    update: (p, N_LIMBS, size) uint32 -> (size,) f32. Preconditions: each
    shard held < 2^16 groups (tier 1) and p < 2^16 (tier 2). The float
    tail is the shared ``_finalize_jit`` executable."""
    check_shard_headroom(states.shape[0])
    merged = _merge_jit(states)
    return _finalize_jit(merged, int(n), float(cfg.clip), int(cfg.bits))


def master_aggregate(interims, group_sizes, unflatten,
                     cfg: SecureAggConfig = SecureAggConfig(), *,
                     n_shards=None):
    """Stage 2: combine interim VG sums into the cohort-mean update pytree.

    interims: list of (size,) uint32; group_sizes: list of int.

    Each interim is exact per the per-group headroom check, but their naive
    uint32 TOTAL wraps once bits + ceil(log2(total_cohort)) > 32 (4097+
    clients at the default 20 bits) — the pre-PR-2 code silently corrupted
    the global mean there. The combine is the hierarchical limb-state tree
    of ``repro.core.quantize``: disjoint VG shards fold into per-shard
    (per-pod) limb states — exact for < 2^16 groups per shard — which
    merge exactly across < 2^16 shards, then dequantize through the one
    shared float tail. Any shard count (``n_shards`` explicit,
    ``cfg.master_shards``, or auto) is bit-identical; the guards raise
    rather than wrap when a plan exceeds the active tier bounds."""
    n = int(sum(group_sizes))
    for g in group_sizes:
        check_headroom(cfg.bits, int(g))
    m = len(group_sizes)
    n_shards = resolve_master_shards(m, cfg, n_shards)
    stacked = jnp.stack([i.astype(U32) for i in interims])
    states = _shard_limbs_jit(stacked, n_shards, cfg.limbs)
    mean_flat = combine_limb_states(states, n, cfg)
    return unflatten(mean_flat)


def secure_aggregate_round(client_updates, vg_plan, round_seed,
                           cfg: SecureAggConfig = SecureAggConfig()):
    """End-to-end reference protocol over a cohort (used by the simulator).

    client_updates: dict client_id -> update pytree (all same structure).
    Returns the cohort-mean update pytree. Exact up to quantization
    resolution under the per-group stage-1 headroom and the two-tier
    stage-2 bounds (auto-sharded past 2^16 VGs via ``master_aggregate``);
    this serial loop is the bit-parity oracle for the vectorized engine.
    """
    interims, sizes, unflatten = [], [], None
    for group in vg_plan.groups:
        payloads = []
        for idx, cid in enumerate(group.members):
            payload, unflatten = client_protect(
                client_updates[cid], idx, len(group.members),
                _group_seed(round_seed, group.vg_id), cfg)
            payloads.append(payload)
        interims.append(vg_aggregate(payloads))
        sizes.append(len(group.members))
    return master_aggregate(interims, sizes, unflatten, cfg)


def secure_aggregate_survivors(client_updates, vg_plan, round_seed,
                               cfg: SecureAggConfig = SecureAggConfig()):
    """Serial dropout-tolerant protocol round (the churn twin of
    :func:`secure_aggregate_round`, and the vectorized engine's parity
    oracle for it).

    ``client_updates`` holds ONLY the survivors; ``vg_plan`` covers the
    FULL selected cohort — missing members are the dropped set D. Each
    survivor uploads the payload it built BEFORE drops were known (full
    net mask over all g-1 peers, original within-group index), so a
    group's wrapping survivor sum keeps the non-cancelling residual
    ``-sum_{d in D} M_d|S``; ``dropout.dropped_net_mask`` reconstructs it
    from the round's pair seeds and adds it back, leaving the exact
    unmasked survivor sum. Groups with no survivors contribute nothing;
    the master combine and its guards retarget to the survivor counts
    (the mean divides by |S|). Bit-identical to a clean
    ``secure_aggregate_round`` over the survivors alone — for ANY clean
    regrouping of S, since the stage-2 limb digits are layout-independent
    and every float stage is the same shared jitted executable."""
    from repro.core import dropout
    interims, sizes, unflatten = [], [], None
    for group in vg_plan.groups:
        g = len(group.members)
        seed = _group_seed(round_seed, group.vg_id)
        payloads, surv_idx, drop_idx = [], [], []
        for idx, cid in enumerate(group.members):
            if cid in client_updates:
                payload, unflatten = client_protect(
                    client_updates[cid], idx, g, seed, cfg)
                payloads.append(payload)
                surv_idx.append(idx)
            else:
                drop_idx.append(idx)
        if not payloads:
            continue                      # whole VG dropped
        if len(surv_idx) < cfg.min_survivors_per_vg:
            continue  # VOIDED: recovering this group's dropped masks
            #           would leave < min_survivors_per_vg payloads in
            #           the sum — at 1 survivor, the client's bare update
        interim = vg_aggregate(payloads)
        if drop_idx:
            interim = interim + dropout.dropped_net_mask(
                drop_idx, surv_idx, g, seed, interim.shape[0])
        interims.append(interim)
        sizes.append(len(surv_idx))
    if unflatten is None:
        raise AggregationRefused(
            "no survivors: every selected client dropped — nothing to "
            "aggregate")
    if not interims:
        raise AggregationRefused(
            "round refused: every surviving virtual group fell below "
            f"min_survivors_per_vg={cfg.min_survivors_per_vg}")
    return master_aggregate(interims, sizes, unflatten, cfg)


def group_seed(round_seed, vg_id):
    """Domain-separated per-VG round seed. ``vg_id`` may be a python int or
    a traced uint32 (the vectorized engine vmaps this over group ids)."""
    from repro.core.kdf import kdf_u32
    rs = jnp.asarray(round_seed, U32)
    vg = jnp.asarray(vg_id, U32)
    return jnp.stack([kdf_u32(rs[0], rs[1], vg),
                      kdf_u32(rs[1], rs[0], vg ^ U32(0x5BF03635))])


_group_seed = group_seed  # backwards-compat alias
