"""Dropout-tolerant secure aggregation: Bonawitz-style mask recovery.

The pairwise-mask protocol (``repro.core.masking``) cancels only when every
member of a virtual group submits: client i's payload carries signed mask
terms for ALL of its g-1 peers, so a missing peer d leaves a non-cancelling
residual in the group's wrapping sum. Concretely, with survivors S and
dropped set D, the survivor sum is

    sum_{i in S} y_i = sum_{i in S} q_i  -  sum_{d in D} M_d|S   (mod 2^32)

where ``M_d|S = sum_{i in S} sign_d(i) * m_{(d,i)}`` is the net mask the
dropped client d *would have contributed*, restricted to the surviving
peers (``sign_d(i) = +1`` if d < i else -1, matching ``masking.net_mask``;
the i-side terms flip sign, which is where the minus comes from). Recovery
therefore reconstructs ``sum_d M_d|S`` from the round's ``kdf.pair_seed``
expansions and ADDS it back, leaving the exact unmasked survivor sum —
bit-identical to a clean round run over S only.

Trust model (documented in docs/ARCHITECTURE.md): in Bonawitz et al. the
pair secrets of dropped clients are recovered via Shamir secret shares held
by the surviving peers, so no single party ever holds them all. Here the
ORCHESTRATOR stands in for that key-recovery service — it already
distributes ``round_seed`` (DESIGN.md §2 stands pair negotiation in with a
keyed hash), so it can re-derive any pair seed directly. The algebra and
cost profile are the paper-faithful parts; the key custody is simulated.

Cost: recovery expands ``g-1`` pair masks per dropped client — O(|D| * g *
size) work, independent of the number of groups and of the cohort size, so
a round with few drops pays almost nothing (``benchmarks/bench_dropout.py``
measures exactly this scaling). The whole cohort's reconstruction runs as
ONE jitted batched call per group-size bucket (at most two, mirroring
``privacy_engine``'s bucketing), with the dropped axis padded to a power of
two so per-round |D| jitter does not recompile: pad rows carry an all-False
survivor mask and therefore contribute exact uint32 zeros.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kdf import U32, mask_stream, pair_seed
from repro.core.secure_agg import group_seed


def net_mask_restricted(idx, alive, vg_size: int, seed, size: int,
                        offset: int = 0):
    """Net mask of group member ``idx`` against the ALIVE peers only.

    ``alive``: (vg_size,) bool — which members of the group survived (the
    entry at ``idx`` itself is ignored). Traced-friendly; with ``alive``
    all-True (minus self) this is exactly ``masking.net_mask_traced`` with
    ``vg_id=0`` — the per-group-seed convention of the serial protocol."""
    peers = jnp.arange(vg_size, dtype=U32)
    i = jnp.asarray(idx, U32)

    def one(peer, peer_alive):
        lo = jnp.minimum(i, peer)
        hi = jnp.maximum(i, peer)
        m = mask_stream(pair_seed(seed, lo, hi), offset, size)
        signed = jnp.where(i < peer, m, jnp.zeros((), U32) - m)
        keep = peer_alive & (peer != i)
        return jnp.where(keep, signed, jnp.zeros((), U32))

    return jnp.sum(jax.vmap(one)(peers, jnp.asarray(alive, bool)),
                   axis=0, dtype=U32)


def dropped_net_mask(dropped_idxs, survivor_idxs, vg_size: int, seed,
                     size: int, offset: int = 0):
    """Serial reference: ``sum_{d in D} M_d|S`` for ONE virtual group.

    Pure python loop over pairs — the oracle the batched path is
    parity-tested against. Returns (size,) uint32; adding it to the
    group's survivor sum recovers the exact unmasked survivor total."""
    total = jnp.zeros((size,), U32)
    for d in dropped_idxs:
        for i in survivor_idxs:
            lo, hi = min(d, i), max(d, i)
            m = mask_stream(pair_seed(seed, lo, hi), offset, size)
            total = total + (m if d < i else jnp.zeros((), U32) - m)
    return total


@partial(jax.jit, static_argnames=("vg_size", "size", "offset"))
def _bucket_corrections(round_seed, d_idxs, d_vgs, d_alive, *,
                        vg_size: int, size: int, offset: int = 0):
    """One batched reconstruction for every dropped client of a bucket:
    (n_d,) within-group indices + plan vg_ids + (n_d, vg_size) survivor
    masks -> (n_d, size) uint32 corrections ``M_d|S``. Rows whose alive
    mask is all-False (the pow2 padding) contribute exact zeros."""
    seeds = jax.vmap(lambda v: group_seed(round_seed, v))(d_vgs)
    return jax.vmap(
        lambda d, s, a: net_mask_restricted(d, a, vg_size, s, size, offset)
    )(d_idxs, seeds, d_alive)


def _pad_pow2(k: int) -> int:
    p = 1
    while p < k:
        p <<= 1
    return p


def recover_interims(interims, buckets, alive, round_seed, *,
                     offset: int = 0, stats: dict | None = None):
    """Repair a cohort's stacked per-VG interims after dropout.

    ``interims``: (G, size) uint32 survivor-only wrapping group sums, rows
    in bucket order (the layout ``privacy_engine._cohort_interims``
    produces). ``buckets``: the plan's ``BucketSpec`` tuple against the
    FULL cohort row order. ``alive``: (n_clients,) bool by stack row —
    False rows are the dropped set D. Returns the corrected (G, size)
    interims, each group's row now the exact unmasked sum of its survivor
    codes (uint32 scatter-add wraps mod 2^32, as the algebra requires).

    One jitted ``_bucket_corrections`` call per group-size bucket (<= 2),
    dropped axis padded to a power of two; groups with no drops are
    untouched and a fully-dropped group's row corrects to exact zero.
    ``stats`` (optional dict) receives ``n_dropped`` and ``recovery_s``
    (wall time of the reconstruction, device-synchronized)."""
    alive = np.asarray(alive, bool)
    size = interims.shape[1]
    if stats is not None:
        # the upstream cohort jit is dispatched async — sync on it first
        # so recovery_s clocks the reconstruction alone (churn rounds
        # already pay a host sync right after, at the limb combine)
        jax.block_until_ready(interims)
    t0 = time.perf_counter()
    n_dropped = 0
    row_off = 0
    for b in buckets:
        rows = np.asarray(b.rows, np.int64)
        a = alive[rows].reshape(b.n_groups, b.g)
        gj, di = np.nonzero(~a)              # bucket-group idx, member idx
        if len(gj):
            n_dropped += len(gj)
            pad = _pad_pow2(len(gj))
            d_idxs = np.zeros(pad, np.uint32)
            d_idxs[:len(gj)] = di
            d_vgs = np.zeros(pad, np.uint32)
            d_vgs[:len(gj)] = np.asarray(b.vg_ids, np.uint32)[gj]
            d_alive = np.zeros((pad, b.g), bool)
            d_alive[:len(gj)] = a[gj]
            corr = _bucket_corrections(
                jnp.asarray(round_seed, U32), jnp.asarray(d_idxs),
                jnp.asarray(d_vgs), jnp.asarray(d_alive),
                vg_size=b.g, size=size, offset=offset)
            target = np.zeros(pad, np.int32)  # pad rows add 0 to row 0
            target[:len(gj)] = row_off + gj
            interims = interims.at[jnp.asarray(target)].add(corr)
        row_off += b.n_groups
    if stats is not None:
        jax.block_until_ready(interims)
        stats["n_dropped"] = n_dropped
        stats["recovery_s"] = time.perf_counter() - t0
    return interims
