"""Fixed-point quantization for secure aggregation (paper §4.1).

Masks are applied with modular integer arithmetic, so model updates "must be
quantized and transformed into an array of integers". We use a ``bits``-bit
affine fixed-point code in a uint32 carrier:

    q = round( (clamp(x, -c, c) + c) / (2c) * (2^bits - 1) )

The *unmasked aggregate* (a sum of n codes, each < 2^bits) must not wrap mod
2^32, which requires bits + ceil(log2(n)) <= 32 — ``check_headroom`` enforces
it. The masked sum wraps freely by design (that is what makes the pairwise
masks cancel exactly).

Quantization is only partially reversible (paper: "an operation which can be
only partially reversed") — dequantizing the aggregate recovers the mean up
to 2c / (2^bits - 1) resolution; tests bound this error.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
DEFAULT_BITS = 20
DEFAULT_CLIP = 1.0


def levels(bits: int):
    return jnp.float32((1 << bits) - 1)


def check_headroom(bits: int, n_clients: int):
    need = bits + max(1, (n_clients - 1).bit_length())
    if need > 32:
        raise ValueError(
            f"bits={bits} with n={n_clients} clients needs {need} > 32 bits "
            f"of headroom; the unmasked aggregate would wrap mod 2^32")


def quantize(x, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """f32 array -> uint32 codes in [0, 2^bits - 1]."""
    xf = jnp.clip(x.astype(jnp.float32), -clip, clip)
    q = jnp.round((xf + clip) / (2.0 * clip) * levels(bits))
    return q.astype(U32)


def dequantize(q, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """uint32 code(s) -> f32 value(s). Inverse of ``quantize`` per element."""
    return (q.astype(jnp.float32) / levels(bits)) * (2.0 * clip) - clip


def dequantize_sum(q_sum, n, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """Recover the MEAN of n quantized values from their (non-wrapped) sum."""
    mean_code = q_sum.astype(jnp.float32) / jnp.float32(n)
    return (mean_code / levels(bits)) * (2.0 * clip) - clip


MAX_MASTER_GROUPS = 1 << 16


def check_master_headroom(n_groups: int):
    """Stage-2 guard: the split-limb accumulator of
    :func:`dequantize_interim_sum` is exact for up to 2^16 virtual groups
    (each 16-bit half-sum stays below 2^32). Beyond that the master must
    shard its combine — raise rather than wrap."""
    if n_groups >= MAX_MASTER_GROUPS:
        raise ValueError(
            f"master combine over {n_groups} virtual groups exceeds the "
            f"{MAX_MASTER_GROUPS - 1}-group exact-accumulation limit")


def dequantize_interim_sum(interims, n, clip=DEFAULT_CLIP,
                           bits=DEFAULT_BITS):
    """Overflow-safe stage-2 combine: per-VG interim sums -> cohort MEAN.

    ``interims``: (n_groups, size) uint32 exact per-group sums (stage 1
    guarantees each fits uint32 via the per-group ``check_headroom``);
    ``n``: total cohort size. The naive uint32 total wraps whenever
    bits + ceil(log2(n)) > 32 (e.g. 4097+ clients at the default 20 bits).
    Instead each interim is split into 16-bit halves and the halves are
    summed in uint32 — exact for < 2^16 groups — then recombined in f32,
    so the master combine never wraps regardless of cohort size.
    Wrapping-add is associative, so the result is independent of group
    order (the vectorized engine relies on this for bit-exact parity with
    the serial reference)."""
    interims = interims.astype(U32)
    lo = jnp.sum(interims & U32(0xFFFF), axis=0, dtype=U32)
    hi = jnp.sum(interims >> U32(16), axis=0, dtype=U32)
    total = hi.astype(jnp.float32) * jnp.float32(65536.0) \
        + lo.astype(jnp.float32)
    mean_code = total / jnp.float32(n)
    return (mean_code / levels(bits)) * (2.0 * clip) - clip


def quantization_resolution(clip=DEFAULT_CLIP, bits=DEFAULT_BITS) -> float:
    return float(2.0 * clip / ((1 << bits) - 1))


# --------------------------------------------------------------------------
# packed modular aggregation (beyond-paper; addresses the paper §7 remark
# that secure aggregation "may prohibit gradient compression")
# --------------------------------------------------------------------------
#
# Two b-bit codes share one uint32 carrier as 16-bit fields. Pairwise masks
# are applied to the PACKED words (mask cancellation is oblivious to the
# field structure), and the unmasked aggregate stays exact as long as each
# field's sum fits its 16 bits: b + ceil(log2(g)) <= 16. With b=13, VGs up
# to g=8 aggregate exactly at HALF the upload/collective bytes.

PACK_FIELD_BITS = 16


def check_pack_headroom(bits: int, n_clients: int):
    need = bits + max(1, (n_clients - 1).bit_length())
    if need > PACK_FIELD_BITS:
        raise ValueError(
            f"packed agg: bits={bits} with n={n_clients} needs {need} > "
            f"{PACK_FIELD_BITS} bits per field")


def pack2(q):
    """(..., 2k) uint32 codes (< 2^16) -> (..., k) packed uint32."""
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return lo | (hi << U32(PACK_FIELD_BITS))


def unpack2_sum(packed_sum):
    """Packed aggregate -> interleaved per-field sums, (..., 2k) uint32."""
    lo = packed_sum & U32(0xFFFF)
    hi = packed_sum >> U32(PACK_FIELD_BITS)
    return jnp.stack([lo, hi], axis=-1).reshape(
        *packed_sum.shape[:-1], -1)


def quantize_packed(x_flat, clip=DEFAULT_CLIP, bits=13):
    """flat f32 (even length) -> packed uint32 of half length."""
    assert x_flat.shape[-1] % 2 == 0
    return pack2(quantize(x_flat, clip, bits))


def dequantize_packed_sum(packed_sum, n, clip=DEFAULT_CLIP, bits=13):
    return dequantize_sum(unpack2_sum(packed_sum), n, clip, bits)
