"""Fixed-point quantization for secure aggregation (paper §4.1).

Masks are applied with modular integer arithmetic, so model updates "must be
quantized and transformed into an array of integers". We use a ``bits``-bit
affine fixed-point code in a uint32 carrier:

    q = round( (clamp(x, -c, c) + c) / (2c) * (2^bits - 1) )

The *unmasked aggregate* (a sum of n codes, each < 2^bits) must not wrap mod
2^32, which requires bits + ceil(log2(n)) <= 32 — ``check_headroom`` enforces
it. The masked sum wraps freely by design (that is what makes the pairwise
masks cancel exactly).

Quantization is only partially reversible (paper: "an operation which can be
only partially reversed") — dequantizing the aggregate recovers the mean up
to 2c / (2^bits - 1) resolution; tests bound this error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32
DEFAULT_BITS = 20
DEFAULT_CLIP = 1.0


def levels(bits: int):
    return jnp.float32((1 << bits) - 1)


def check_headroom(bits: int, n_clients: int):
    """Stage-1 guard: a virtual group's unmasked uint32 sum of ``n_clients``
    ``bits``-bit codes is exact iff bits + ceil(log2(n)) <= 32. This bounds
    the GROUP size only; the cross-group (stage-2) total has its own
    two-tier bound — see :func:`check_master_headroom` /
    :func:`check_shard_headroom`."""
    need = bits + max(1, (n_clients - 1).bit_length())
    if need > 32:
        raise ValueError(
            f"bits={bits} with n={n_clients} clients needs {need} > 32 bits "
            f"of headroom; the unmasked aggregate would wrap mod 2^32")


def quantize(x, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """f32 array -> uint32 codes in [0, 2^bits - 1]. Lossy by design
    (resolution :func:`quantization_resolution`); every integer stage
    DOWNSTREAM of it is exact under the headroom preconditions."""
    xf = jnp.clip(x.astype(jnp.float32), -clip, clip)
    q = jnp.round((xf + clip) / (2.0 * clip) * levels(bits))
    return q.astype(U32)


def dequantize(q, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """uint32 code(s) -> f32 value(s). Inverse of ``quantize`` per element."""
    return (q.astype(jnp.float32) / levels(bits)) * (2.0 * clip) - clip


def dequantize_sum(q_sum, n, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """Recover the MEAN of n quantized values from their (non-wrapped) sum.

    Precondition: ``q_sum`` did not wrap, i.e. ``check_headroom(bits, n)``
    held for the group that produced it. For sums OF sums (the stage-2
    master), use the limb-state combine below instead — a uint32 grand
    total wraps long before the per-group bound does."""
    mean_code = q_sum.astype(jnp.float32) / jnp.float32(n)
    return (mean_code / levels(bits)) * (2.0 * clip) - clip


# --------------------------------------------------------------------------
# hierarchical stage-2 master combine (two-tier limb-state tree)
# --------------------------------------------------------------------------
#
# The master's job is the EXACT integer total of all per-VG interim sums.
# A naive uint32 total wraps once bits + ceil(log2(total_cohort)) > 32, so
# the combine instead carries a LIMB STATE: the canonical base-2^16 digits
# of the running total, held in ``n_limbs`` uint32 lanes (default 3):
#
#     value = limbs[0] + limbs[1] * 2^16 + limbs[2] * 2^32 [+ limbs[3] * 2^48]
#     limbs[0], limbs[1] in [0, 2^16);  limbs[2] <= 2^16 per shard (3-limb)
#     limbs[0..2] in [0, 2^16), limbs[3] the open top lane (4-limb)
#
# Tier 1 (per pod / per shard): ``interim_limb_state`` folds a shard of
# < 2^16 interims into one limb state — each 16-bit half-sum stays below
# 2^32, so the shard total is exact (``check_master_headroom``).
# Tier 2 (cross-pod): ``merge_limb_states`` sums < 2^16 limb states
# per-limb in uint32 and carry-normalizes (``check_shard_headroom``) —
# exact again, lifting the overall exact bound from 2^16 VGs total to
# 2^16 per shard x 2^16 shards (~2^32 VGs).
#
# The 3-limb state caps the representable total at < 2^48-ish (the top
# lane holds the 2^32 digit); planetary plans past ~2^32 VGs overflow the
# VALUE even though each tier's arithmetic is exact. ``n_limbs=4``
# (``SecureAggConfig.limbs``) adds a 2^48 lane, making the representable
# total < 2^64 — headroom for > 2^32 virtual groups. Within the 3-limb
# bound the two variants are bit-identical: the first three canonical
# digits agree exactly and the 4th is zero (parity-tested).
#
# Because the canonical digits of a sum do not depend on how its terms are
# sharded, EVERY shard count (including 1 = the single-tier path) yields
# bit-identical limbs; the only float stage, ``dequantize_limb_state``, is
# jitted ONCE and shared by all routes (``secure_agg._finalize_jit``), so
# sharded and serial combines are bit-identical end to end.

MAX_MASTER_GROUPS = 1 << 16     # tier-1 bound: VGs per shard
MAX_MASTER_SHARDS = 1 << 16     # tier-2 bound: shards per merge
LIMB_BITS = 16
N_LIMBS = 3                     # default lanes; 4 buys > 2^32-VG headroom
_LIMB_MASK = 0xFFFF


def check_master_headroom(n_groups: int):
    """Tier-1 guard: one shard's limb state (:func:`interim_limb_state`)
    is exact for up to 2^16 - 1 virtual groups — each 16-bit half-sum
    stays below 2^32. Precondition for every single-shard combine; a
    master holding more VGs must shard its combine (tree-combine across
    pods, :func:`merge_limb_states`) — raise rather than wrap."""
    if n_groups >= MAX_MASTER_GROUPS:
        raise ValueError(
            f"master combine over {n_groups} virtual groups exceeds the "
            f"{MAX_MASTER_GROUPS - 1}-group per-shard exact-accumulation "
            f"limit; shard the stage-2 combine (master_shards / n_shards)")


def check_shard_headroom(n_shards: int):
    """Tier-2 (cross-pod) guard: the per-limb uint32 sums of
    :func:`merge_limb_states` are exact for up to 2^16 - 1 shards (limb
    values are <= 2^16, so 2^16 - 1 of them plus carries stay below
    2^32). Precondition of every cross-shard merge."""
    if n_shards >= MAX_MASTER_SHARDS:
        raise ValueError(
            f"cross-shard merge over {n_shards} shards exceeds the "
            f"{MAX_MASTER_SHARDS - 1}-shard exact-merge limit")


def min_master_shards(n_groups: int) -> int:
    """Smallest shard count that keeps a ``n_groups``-VG stage-2 combine
    exact (tier-1 bound per shard; tier-2 bound checked by the caller)."""
    return -(-max(1, n_groups) // (MAX_MASTER_GROUPS - 1))


def interim_limb_state(interims, n_limbs: int = N_LIMBS):
    """Tier-1 fold: (m, *shape) uint32 exact per-VG sums -> (n_limbs,
    *shape) uint32 canonical base-2^16 digits of the shard total.

    Precondition: m < 2^16 (:func:`check_master_headroom`) — the lo/hi
    half-sums then stay below 2^32 and the digits are exact. Integer-only,
    so any compilation (inside the cohort jit, under shard_map, per pod)
    produces identical bits; wrapping-add associativity makes the result
    independent of row order within the shard. ``n_limbs=4`` carries the
    2^48 lane too (``SecureAggConfig.limbs`` — headroom past ~2^32 VGs);
    the first three digits are identical to the 3-limb state whenever the
    total fits it."""
    if n_limbs not in (3, 4):
        raise ValueError(f"n_limbs must be 3 or 4, got {n_limbs}")
    interims = interims.astype(U32)
    lo = jnp.sum(interims & U32(_LIMB_MASK), axis=0, dtype=U32)
    hi = jnp.sum(interims >> U32(LIMB_BITS), axis=0, dtype=U32)
    l0 = lo & U32(_LIMB_MASK)
    t1 = (lo >> U32(LIMB_BITS)) + (hi & U32(_LIMB_MASK))
    l1 = t1 & U32(_LIMB_MASK)
    t2 = (t1 >> U32(LIMB_BITS)) + (hi >> U32(LIMB_BITS))
    if n_limbs == 3:
        return jnp.stack([l0, l1, t2])
    return jnp.stack([l0, l1, t2 & U32(_LIMB_MASK), t2 >> U32(LIMB_BITS)])


def shard_limb_states(interims, n_shards: int, n_limbs: int = N_LIMBS):
    """Split the VG axis into ``n_shards`` disjoint shards and fold each:
    (m, *shape) uint32 -> (n_shards, n_limbs, *shape) per-shard states.

    The ONE sharding implementation every route uses (serial master,
    vectorized engine, fl_step, benches) so edge semantics stay uniform:
    a non-dividing shard count zero-pads the VG axis (zero rows are exact
    no-ops in the integer sums). Preconditions: ceil(m / n_shards) < 2^16
    per shard (:func:`check_master_headroom`) and n_shards < 2^16
    (:func:`check_shard_headroom`). Traceable — callable inside a jit."""
    m = interims.shape[0]
    per = -(-m // n_shards)
    interims = interims.astype(U32)
    if per * n_shards > m:
        interims = jnp.concatenate(
            [interims,
             jnp.zeros((per * n_shards - m, *interims.shape[1:]), U32)])
    return jax.vmap(lambda s: interim_limb_state(s, n_limbs))(
        interims.reshape(n_shards, per, *interims.shape[1:]))


def carry_normalize(limb_sums):
    """Per-limb uint32 sums of canonical limb states -> the canonical limb
    state of the total (schoolbook carry propagation, any lane count; the
    top lane keeps its overflow). Exact while each input lane stays below
    2^32 — guaranteed for < 2^16 summed states
    (:func:`check_shard_headroom`). The cross-pod ``psum``-merge in
    ``launch/fl_step.py`` lands here after its integer collective."""
    s = limb_sums.astype(U32)
    lanes, carry = [], None
    for j in range(s.shape[0]):
        t = s[j] if carry is None else s[j] + carry
        if j < s.shape[0] - 1:
            lanes.append(t & U32(_LIMB_MASK))
            carry = t >> U32(LIMB_BITS)
        else:
            lanes.append(t)
    return jnp.stack(lanes)


def merge_limb_states(states):
    """Tier-2 merge: (p, n_limbs, *shape) uint32 per-shard limb states ->
    (n_limbs, *shape) canonical state of the grand total.

    Precondition: p < 2^16 (:func:`check_shard_headroom`). Exact and
    shard-layout-independent: merging any partition of the same interims
    yields the digits of the same integer, so a 1-shard "merge" is the
    identity and every shard count is bit-identical."""
    return carry_normalize(jnp.sum(states.astype(U32), axis=0, dtype=U32))


def dequantize_limb_state(limbs, n, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    """The ONLY float stage of the master combine: canonical limb state ->
    f32 cohort MEAN update (3- or 4-lane states, shape-dispatched).

    ``n``: total cohort size (clients, not groups). The integer digits are
    exact on entry; this conversion rounds to f32 resolution exactly once.
    Both the serial master and every sharded/vectorized route call the
    single jitted instance (``secure_agg._finalize_jit``) — XLA contracts
    the mul/sub chain differently per executable, so sharing it is what
    makes the final floats bit-identical across paths (the PR-2 parity
    discipline)."""
    total = (limbs[2].astype(jnp.float32) * jnp.float32(4294967296.0)
             + limbs[1].astype(jnp.float32) * jnp.float32(65536.0)
             + limbs[0].astype(jnp.float32))
    if limbs.shape[0] == 4:
        # 2^48 lane last, so a zero top lane adds +0.0 to the 3-limb chain
        # (exact for the non-negative totals digits encode)
        total = total + limbs[3].astype(jnp.float32) * jnp.float32(2.0 ** 48)
    mean_code = total / jnp.float32(n)
    return (mean_code / levels(bits)) * (2.0 * clip) - clip


def dequantize_interim_sum(interims, n, clip=DEFAULT_CLIP,
                           bits=DEFAULT_BITS):
    """Single-tier stage-2 combine: per-VG interim sums -> cohort MEAN.

    ``interims``: (n_groups, size) uint32 exact per-group sums (stage 1
    guarantees each fits uint32 via the per-group ``check_headroom``);
    ``n``: total cohort size. Exact for < 2^16 groups — the tier-1
    precondition ``check_master_headroom`` — via the limb-state fold
    (:func:`interim_limb_state`); larger masters must go through the
    sharded route (``secure_agg.master_aggregate`` with ``n_shards`` > 1),
    which produces bit-identical results for any cohort this single-tier
    form can hold."""
    return dequantize_limb_state(interim_limb_state(interims), n, clip,
                                 bits)


def quantization_resolution(clip=DEFAULT_CLIP, bits=DEFAULT_BITS) -> float:
    return float(2.0 * clip / ((1 << bits) - 1))


# --------------------------------------------------------------------------
# packed modular aggregation (beyond-paper; addresses the paper §7 remark
# that secure aggregation "may prohibit gradient compression")
# --------------------------------------------------------------------------
#
# Two b-bit codes share one uint32 carrier as 16-bit fields. Pairwise masks
# are applied to the PACKED words (mask cancellation is oblivious to the
# field structure), and the unmasked aggregate stays exact as long as each
# field's sum fits its 16 bits: b + ceil(log2(g)) <= 16. With b=13, VGs up
# to g=8 aggregate exactly at HALF the upload/collective bytes.

PACK_FIELD_BITS = 16


def check_pack_headroom(bits: int, n_clients: int):
    need = bits + max(1, (n_clients - 1).bit_length())
    if need > PACK_FIELD_BITS:
        raise ValueError(
            f"packed agg: bits={bits} with n={n_clients} needs {need} > "
            f"{PACK_FIELD_BITS} bits per field")


def pack2(q):
    """(..., 2k) uint32 codes (< 2^16) -> (..., k) packed uint32."""
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return lo | (hi << U32(PACK_FIELD_BITS))


def unpack2_sum(packed_sum):
    """Packed aggregate -> interleaved per-field sums, (..., 2k) uint32."""
    lo = packed_sum & U32(0xFFFF)
    hi = packed_sum >> U32(PACK_FIELD_BITS)
    return jnp.stack([lo, hi], axis=-1).reshape(
        *packed_sum.shape[:-1], -1)


def quantize_packed(x_flat, clip=DEFAULT_CLIP, bits=13):
    """flat f32 (even length) -> packed uint32 of half length."""
    assert x_flat.shape[-1] % 2 == 0
    return pack2(quantize(x_flat, clip, bits))


def dequantize_packed_sum(packed_sum, n, clip=DEFAULT_CLIP, bits=13):
    return dequantize_sum(unpack2_sum(packed_sum), n, clip, bits)
