"""Top-k sparse updates under secure aggregation (paper §7 names update
compression under secure aggregation as an open problem; ROADMAP
"Compressed updates at LLM scale").

The obstacle: pairwise masks only cancel when every member of a virtual
group masks the SAME coordinates. Naive per-client top-k gives each client
its own support set, so either the server learns every client's support
(an information leak — the largest-magnitude coordinates of a private
update) or the masks don't cancel. This module resolves it with a
round-common index domain:

  shared-index draw   Each round r draws ``k`` coordinates of the flat
                      update domain from a seeded host-side PCG64 stream —
                      a function of (seed, round, size, k) only, so every
                      client and the server derive the identical support
                      without communicating it. The wire payload is the
                      update restricted to those k coordinates: DENSE in k,
                      identical support across the whole cohort, so the
                      quantize -> mask -> VG-sum -> limb-combine chain runs
                      unchanged on a (k,)-vector and masking never leaks
                      which coordinates any client cared about.

  error feedback      What makes the shared draw behave like top-k over
                      time: each client keeps a residual — the part of its
                      accumulated update NOT yet transmitted. Per round the
                      client compresses ``update + residual``; transmitted
                      coordinates are zeroed out of the residual, the rest
                      carries to the next round. Every coordinate's mass is
                      eventually delivered (the draw revisits all of the
                      domain in expectation), which is the standard EF
                      convergence argument (Stich et al. 2018; SCAFFOLD-
                      style memory) — pinned empirically by the quickstart
                      convergence test.

  true top-k          The async path aggregates inside a trusted boundary
                      (paper §4.3) with NO masks, so per-client supports
                      leak nothing the aggregator doesn't already see:
                      ``compress_topk`` sends genuine per-client top-k
                      magnitudes as (indices, values) pairs, scattered back
                      to dense before the FedBuff buffer write (the buffer
                      math is support-agnostic).

Bit-exactness: compression happens BEFORE the §4 privacy chain, entirely
in host numpy — the serial reference and the vectorized/wave engines
consume the same (n, k) payload rows, so the existing bit-parity contract
extends to sparse rounds for free (tested in tests/test_compression.py).

DP composition: local/global DP clip and noise the TRANSMITTED k-vector —
the quantity that actually leaves the device — so sensitivity analysis is
unchanged (clip_norm bounds the payload's L2 norm).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SparseConfig:
    """Round-common top-k sparsification knobs.

    ``k``: coordinates per round (the shared index domain's size).
    ``error_feedback``: carry untransmitted mass in per-client residuals
    (off = plain rand-k, which discards it — only right for diagnostics).
    ``seed``: domain-separates the shared draw from every other RNG.
    """
    k: int
    error_feedback: bool = True
    seed: int = 0


def resolve_k(size: int, *, k: int = 0, frac: float = 0.0) -> int:
    """Coordinates per round: explicit ``k`` wins, else ``ceil(size *
    frac)``; always clamped to [1, size]."""
    if k <= 0:
        k = int(np.ceil(size * frac)) if frac > 0 else size
    return max(1, min(int(k), int(size)))


def shared_indices(size: int, k: int, round_idx: int,
                   seed: int = 0) -> np.ndarray:
    """The round-common support: ``k`` distinct coordinates of
    ``[0, size)``, sorted, drawn from PCG64 seeded by (seed, round, size,
    k) — identical on every party that knows the round index, never
    transmitted.

    Host-side numpy on purpose: the draw must be platform-deterministic
    (device PRNGs vary by backend) and it is O(k), off the compiled path.
    """
    if not 0 < k <= size:
        raise ValueError(f"k={k} outside [1, {size}]")
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence((int(seed), int(round_idx), int(size),
                                int(k)))))
    if k == size:
        return np.arange(size, dtype=np.int64)
    if k * 2 >= size:                       # dense regime: permute once
        idx = rng.permutation(size)[:k].astype(np.int64)
        idx.sort()
        return idx
    # sparse regime: rejection-free top-up — collisions are rare for
    # k << size, so a couple of O(k) draws suffice
    idx = np.unique(rng.integers(0, size, size=k + k // 4 + 16,
                                 dtype=np.int64))
    while idx.size < k:
        idx = np.unique(np.concatenate(
            [idx, rng.integers(0, size, size=k, dtype=np.int64)]))
    if idx.size > k:
        # drop the surplus uniformly (slicing the sorted array would bias
        # the support toward small coordinates)
        idx = idx[np.sort(rng.permutation(idx.size)[:k])]
    return idx


def topk_indices(flat: np.ndarray, k: int) -> np.ndarray:
    """Sorted indices of the ``k`` largest-|.| coordinates (ties broken by
    index via argpartition's deterministic introselect)."""
    flat = np.asarray(flat)
    if k >= flat.size:
        return np.arange(flat.size, dtype=np.int64)
    idx = np.argpartition(np.abs(flat), flat.size - k)[flat.size - k:]
    idx.sort()
    return idx.astype(np.int64)


def scatter(values, indices, size: int) -> np.ndarray:
    """(k,) values at (k,) indices -> dense (size,) f32."""
    out = np.zeros(size, np.float32)
    out[np.asarray(indices)] = np.asarray(values, np.float32)
    return out


class TopKCompressor:
    """Per-task compressor: the shared-index draw plus every client's
    error-feedback residual (server-simulated — in production each device
    keeps only its own row).

    ``compress_rows`` / ``decompress`` are the sync secure-agg pair
    (round-common support, dense-in-k payloads); ``compress_topk`` is the
    async trusted-boundary entry (true per-client top-k as index/value
    pairs). Residuals are consumed AT transmission: a round the server
    later voids loses the transmitted component, exactly like a real
    client that cannot know the round's server-side fate.
    """

    def __init__(self, cfg: SparseConfig, size: int):
        if not 0 < cfg.k <= size:
            raise ValueError(f"k={cfg.k} outside [1, {size}]")
        self.cfg = cfg
        self.size = int(size)
        self._residuals: dict = {}          # cid -> (size,) np.float32

    @property
    def k(self) -> int:
        return int(self.cfg.k)

    def payload_bytes(self, *, with_indices: bool = False) -> int:
        """Upload bytes per client per round: k f32 values; the sync path
        never ships indices (the support is derived, not transmitted),
        the async top-k path ships k int32 indices too."""
        return self.k * (8 if with_indices else 4)

    def round_indices(self, round_idx: int) -> np.ndarray:
        return shared_indices(self.size, self.k, round_idx, self.cfg.seed)

    def residual(self, cid) -> np.ndarray:
        r = self._residuals.get(cid)
        if r is None:
            r = np.zeros(self.size, np.float32)
            self._residuals[cid] = r
        return r

    # -- sync secure-agg pair ---------------------------------------------

    def compress_rows(self, client_ids, rows, round_idx: int) -> np.ndarray:
        """(n, size) per-client flat updates -> (n, k) dense-in-k payload
        rows on the round's shared support, row order preserved.

        Each row is compressed from ``update + residual``; transmitted
        coordinates leave the residual, the rest carries to next round.
        Call once per (client, round) — compression is the client's wire
        transmission, so repeating it double-counts the residual."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[0] != len(list(client_ids)):
            raise ValueError(f"expected ({len(list(client_ids))}, "
                             f"{self.size}) rows, got {rows.shape}")
        if rows.shape[1] != self.size:
            raise ValueError(f"rows have {rows.shape[1]} coordinates, "
                             f"compressor built for {self.size}")
        idx = self.round_indices(round_idx)
        if not self.cfg.error_feedback:
            return rows[:, idx].copy()
        out = np.empty((rows.shape[0], self.k), np.float32)
        for j, cid in enumerate(client_ids):
            r = self.residual(cid)
            v = rows[j] + r
            out[j] = v[idx]
            v[idx] = 0.0
            self._residuals[cid] = v
        return out

    def decompress(self, mean_k, round_idx: int) -> np.ndarray:
        """Aggregated (k,) mean on the round's shared support -> dense
        (size,) f32 server delta (zeros off-support)."""
        mean_k = np.asarray(mean_k, np.float32)
        if mean_k.shape != (self.k,):
            raise ValueError(f"expected ({self.k},) aggregate, got "
                             f"{mean_k.shape}")
        return scatter(mean_k, self.round_indices(round_idx), self.size)

    # -- async trusted-boundary entry -------------------------------------

    def compress_topk(self, cid, flat):
        """One client's TRUE top-k transmission -> (indices (k,) int64,
        values (k,) f32, dense (size,) f32 reconstruction).

        The dense reconstruction is what enters the FedBuff buffer (its
        math is support-agnostic); the (indices, values) pair is what the
        wire would carry — ``payload_bytes(with_indices=True)``."""
        v = np.asarray(flat, np.float32)
        if v.shape != (self.size,):
            raise ValueError(f"expected ({self.size},) update, got "
                             f"{v.shape}")
        if self.cfg.error_feedback:
            v = v + self.residual(cid)
        idx = topk_indices(v, self.k)
        vals = v[idx].copy()
        if self.cfg.error_feedback:
            r = v.copy()
            r[idx] = 0.0
            self._residuals[cid] = r
        return idx, vals, scatter(vals, idx, self.size)
