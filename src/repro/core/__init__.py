"""Project Florida's primary contribution: two-stage secure aggregation over
Virtual Groups, pairwise-mask protocol, DP, and aggregation strategies."""
from repro.core.cohort_engine import (CohortEngine, LocalTrainSpec,
                                      make_local_update, serial_cohort,
                                      shard_cohort, vmap_cohort)
from repro.core.dp import DPConfig, RdpAccountant, compute_rdp, get_privacy_spent
from repro.core.kdf import kdf_u32, mask_stream, pair_seed
from repro.core.masking import apply_mask, modular_sum, net_mask
from repro.core.orchestrator import (AsyncServer, ClientResult, RoundInfo,
                                     execute_cohort, run_sync_round,
                                     run_sync_round_stacked)
from repro.core.privacy_engine import (BucketSpec, PrivacyEngine,
                                       plan_buckets, ravel_rows,
                                       stack_flat_updates)
from repro.core.raveling import cached_unflatten, tree_signature
from repro.core.quantize import (DEFAULT_BITS, DEFAULT_CLIP, check_headroom,
                                 check_master_headroom, dequantize,
                                 dequantize_interim_sum, dequantize_sum,
                                 quantize)
from repro.core.secure_agg import (SecureAggConfig, client_protect,
                                   group_seed, master_aggregate,
                                   secure_aggregate_round, vg_aggregate)
from repro.core.strategies import (DGA, STRATEGIES, FedAvg, FedBuff, FedProx,
                                   make_strategy)
from repro.core.virtual_groups import (VGPlan, VirtualGroup,
                                       make_virtual_groups, pairwise_cost,
                                       recommended_vg_size)
