"""Project Florida's primary contribution: two-stage secure aggregation over
Virtual Groups, pairwise-mask protocol, DP, and aggregation strategies."""
from repro.core.cohort_engine import (CohortEngine, LocalTrainSpec,
                                      make_local_update, serial_cohort,
                                      shard_cohort, vmap_cohort)
from repro.core.dp import DPConfig, RdpAccountant, compute_rdp, get_privacy_spent
from repro.core.dropout import (dropped_net_mask, net_mask_restricted,
                                recover_interims)
from repro.core.kdf import kdf_u32, mask_stream, pair_seed
from repro.core.masking import apply_mask, modular_sum, net_mask
from repro.core.orchestrator import (AsyncServer, ClientResult, RoundInfo,
                                     execute_cohort, run_sync_round,
                                     run_sync_round_stacked)
from repro.core.privacy_engine import (BucketSpec, PrivacyEngine,
                                       plan_buckets, ravel_rows,
                                       stack_flat_updates)
from repro.core.raveling import cached_unflatten, tree_signature
from repro.core.quantize import (DEFAULT_BITS, DEFAULT_CLIP,
                                 MAX_MASTER_GROUPS, MAX_MASTER_SHARDS,
                                 carry_normalize, check_headroom,
                                 check_master_headroom, check_shard_headroom,
                                 dequantize, dequantize_interim_sum,
                                 dequantize_limb_state, dequantize_sum,
                                 interim_limb_state, merge_limb_states,
                                 min_master_shards, quantize,
                                 shard_limb_states)
from repro.core.secure_agg import (SecureAggConfig, client_protect,
                                   combine_limb_states, group_seed,
                                   master_aggregate, resolve_master_shards,
                                   secure_aggregate_round,
                                   secure_aggregate_survivors, vg_aggregate)
from repro.core.strategies import (DGA, STRATEGIES, FedAvg, FedBuff, FedProx,
                                   make_strategy)
from repro.core.virtual_groups import (VGPlan, VirtualGroup,
                                       make_virtual_groups, pairwise_cost,
                                       recommended_vg_size)
