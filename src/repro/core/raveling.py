"""Cached pytree <-> flat-vector raveling helpers.

``jax.flatten_util.ravel_pytree`` rebuilds its unflatten closure (re-walking
the treedef and recomputing every leaf's shape/offset) on every call. The
round- and step-level hot paths ravel the SAME structure every time — the
sync orchestrator once per round (``privacy_engine.stack_flat_updates``),
the async server once per drain (``strategies.FedBuff``'s raveled-params
cache) — so the closure is cached here, keyed by everything it can depend
on: the treedef plus per-leaf shapes and dtypes.

A cache hit also avoids the throwaway data ravel that callers previously
paid just to obtain the closure (``ravel_pytree(updates[0])[1]``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

_UNFLATTEN_CACHE: dict = {}


def tree_signature(tree) -> tuple:
    """Hashable (treedef, ((shape, dtype), ...)) key — exactly the inputs
    ``ravel_pytree``'s unflatten closure is a function of."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef,
            tuple((jnp.shape(leaf), jnp.result_type(leaf))
                  for leaf in leaves))


def cached_unflatten(tree):
    """-> (flat_size, unflatten) for ``tree``'s structure.

    On a hit no per-call flatten work happens at all; on a miss the closure
    is built once via ``ravel_pytree`` and memoized. Sound because the
    closure depends only on :func:`tree_signature` (leaf VALUES never enter
    it)."""
    sig = tree_signature(tree)
    hit = _UNFLATTEN_CACHE.get(sig)
    if hit is None:
        flat, unflatten = ravel_pytree(tree)
        hit = (int(flat.size), unflatten)
        _UNFLATTEN_CACHE[sig] = hit
    return hit


def flat_f32(tree):
    """Ravel ``tree`` to a (size,) f32 row (exact: reshape/concat/cast
    only — no float arithmetic)."""
    return ravel_pytree(tree)[0].astype(jnp.float32)
