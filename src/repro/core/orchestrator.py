"""Round-level FL protocol engine: what the Management Service's "task
orchestrator" role does per iteration (paper §3.1.1), with the privacy
pipeline of §4 wired in the paper's order:

  client update -> [local DP clip+noise] -> quantize -> pairwise mask
    -> stage-1 VG modular sum -> stage-2 master combine
    -> [global DP noise] -> strategy server update

The service personas (selection, auth, task state) live in ``repro.fl``;
this module is the pure protocol math so it can be tested and reused by both
the cross-device simulator and the on-pod ``launch/train.py`` path.

The async (Papaya/FedBuff) path lives in :class:`AsyncServer`: a serial
per-submission reference (``submit``) and a fused batch entry
(``submit_batch``) over the same device-resident buffer
(``strategies.FedBuff`` — see its module docstring for the buffer layout).
Parity contract: batch DP key-folds follow the global submission counter,
so N serial submits and one batch produce bit-identical buffers, weights,
and models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import raveling
from repro.core import secure_agg as sa
from repro.core.strategies import FedBuff
from repro.core.virtual_groups import make_virtual_groups
from repro import tracing  # stdlib-only; safe for core to depend on


@dataclass
class RoundInfo:
    round_idx: int
    n_participants: int          # survivors actually aggregated (== |S|)
    n_groups: int
    metrics: dict = field(default_factory=dict)
    n_shards: int = 1   # stage-2 combine shards (hierarchical master)
    # churn telemetry (paper §3.1.4 heterogeneity): selected cohort size,
    # mid-round dropouts, and the mask-recovery wall time
    n_selected: int = 0          # set to n_participants when nobody drops
    n_dropped: int = 0
    recovery_s: float = 0.0
    # compressed rounds: bytes per client entering secure aggregation (the
    # measured upload the ROADMAP <1%-of-model acceptance reads); 0 = dense
    upload_bytes: int = 0
    # stage-2 aggregation path this round took: "single_dispatch" / "waved"
    # / "churn_recovery" (vectorized engine) or "serial" (reference loop)
    stage2_route: str = "serial"


@dataclass
class ClientResult:
    update: Any                  # pseudo-gradient pytree
    n_samples: int
    metrics: dict = field(default_factory=dict)


def _round_randomness(key, round_seed, round_idx: int):
    key = key if key is not None else jax.random.PRNGKey(round_idx)
    if round_seed is None:
        round_seed = jax.random.key_data(
            jax.random.fold_in(jax.random.PRNGKey(17), round_idx)
        ).astype(jnp.uint32)[:2]
    return key, round_seed


def _secure_mean_serial(updates_sorted: dict, plan, round_seed, key,
                        secure_cfg, dp_cfg):
    """Bit-exact reference: per-client python loop (DP -> protect), then
    the two-stage combine. Kept verbatim as the parity oracle for the
    vectorized engine."""
    updates = {}
    for j, (cid, u) in enumerate(updates_sorted.items()):
        if dp_cfg.mechanism == "local":
            u = dp_mod.local_dp(u, dp_cfg, jax.random.fold_in(key, j))
        elif dp_cfg.mechanism == "global":
            u = dp_mod.clip_update(u, dp_cfg.clip_norm)
        updates[cid] = u
    return sa.secure_aggregate_round(updates, plan, round_seed, secure_cfg)


def _secure_mean_survivors(updates_sorted: dict, plan, round_seed, key,
                           secure_cfg, dp_cfg, fold_of: dict):
    """Churn twin of :func:`_secure_mean_serial`: ``updates_sorted`` holds
    only the survivors while ``plan`` covers the full selected cohort.
    DP keys fold at ``fold_of[cid]`` — the client's SELECTION-TIME row in
    the full sorted cohort, assigned before anyone dropped — so a
    survivor's noised update is bit-identical whether or not its peers
    survived (and matches the vectorized engine's row-indexed folds)."""
    updates = {}
    for cid, u in updates_sorted.items():
        if dp_cfg.mechanism == "local":
            u = dp_mod.local_dp(u, dp_cfg,
                                jax.random.fold_in(key, fold_of[cid]))
        elif dp_cfg.mechanism == "global":
            u = dp_mod.clip_update(u, dp_cfg.clip_norm)
        updates[cid] = u
    return sa.secure_aggregate_survivors(updates, plan, round_seed,
                                         secure_cfg)


def _compressed_secure_mean(compressor, flat_rows, cids_sorted,
                            protocol_order, plan, round_idx, round_seed,
                            key, secure_cfg, dp_cfg, n_shards, stats):
    """Sparse sync round core: compress the survivors' flat rows onto the
    round's shared support, run the UNCHANGED §4 chain on the (n, k)
    payload, then noise (global DP) and scatter the aggregated k-vector
    back to the dense domain.

    Compression precedes the privacy chain — DP clip/noise apply to the
    transmitted k-vector, the quantity that actually leaves the device —
    and is pure host numpy, so the serial reference and the vectorized /
    wave / churn engines consume bit-identical payload rows. Returns the
    dense (size,) f32 mean delta."""
    payload = compressor.compress_rows(cids_sorted,
                                       np.asarray(flat_rows, np.float32),
                                       round_idx)
    if stats is not None:
        stats["upload_bytes"] = compressor.payload_bytes()
    if list(protocol_order) == list(cids_sorted):
        if secure_cfg.vectorized:
            mean_k = pe.aggregate_flat(
                jnp.asarray(payload), plan, cids_sorted, round_seed,
                secure_cfg=secure_cfg, dp_cfg=dp_cfg, key=key,
                n_shards=n_shards, stats=stats)
        else:
            mean_k = _secure_mean_serial(
                {cid: jnp.asarray(payload[j])
                 for j, cid in enumerate(cids_sorted)}, plan, round_seed,
                key, secure_cfg, dp_cfg)
    elif secure_cfg.vectorized:
        # churn: scatter survivor payload rows into their selection-time
        # cohort rows; recovery then runs over the SPARSE interims (the
        # chain is size-agnostic — k is just a small `size`)
        pos_of = {cid: j for j, cid in enumerate(protocol_order)}
        alive = np.zeros(len(protocol_order), bool)
        full = np.zeros((len(protocol_order), payload.shape[1]),
                        np.float32)
        for j, cid in enumerate(cids_sorted):
            full[pos_of[cid]] = payload[j]
            alive[pos_of[cid]] = True
        mean_k = pe.aggregate_flat(
            jnp.asarray(full), plan, list(protocol_order), round_seed,
            secure_cfg=secure_cfg, dp_cfg=dp_cfg, key=key,
            n_shards=n_shards, alive=alive, stats=stats)
    else:
        fold_of = {cid: j for j, cid in enumerate(protocol_order)}
        mean_k = _secure_mean_survivors(
            {cid: jnp.asarray(payload[j])
             for j, cid in enumerate(cids_sorted)}, plan, round_seed, key,
            secure_cfg, dp_cfg, fold_of)
    if dp_cfg.mechanism == "global":
        # noise the aggregated k-vector (the released quantity) BEFORE
        # scattering — off-support coordinates carry no signal and get
        # no noise
        mean_k = dp_mod.global_dp(mean_k, dp_cfg, len(cids_sorted),
                                  jax.random.fold_in(key, 10_000))
    return jnp.asarray(compressor.decompress(np.asarray(mean_k),
                                             round_idx))


def run_sync_round(params, strategy, strategy_state,
                   client_results: dict,
                   *, round_idx: int, vg_size: int,
                   secure_cfg: sa.SecureAggConfig = sa.SecureAggConfig(),
                   dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig(),
                   key=None, round_seed=None, cohort=None,
                   compressor=None):
    """One synchronous FL round over a cohort of client results.

    ``secure_cfg.vectorized`` (default) runs the whole privacy pipeline —
    DP, quantize, mask, VG sums, master combine — as one compiled call via
    ``repro.core.privacy_engine``; ``vectorized=False`` keeps the serial
    per-client reference loop (bit-identical output, O(n) dispatches).
    Plans past 2^16 VGs (or with ``secure_cfg.master_shards`` set) take
    the hierarchical sharded stage-2 route on both paths — bit-identical
    at any legal shard count.

    ``cohort``: the FULL selected client list — pass it when some
    selected clients dropped mid-round (``client_results`` then holds the
    survivors only). The VG plan and the DP key-fold rows are built over
    the full cohort (clients masked/noised before drops were known), the
    dropped residual is recovered (``repro.core.dropout``), and the round
    aggregates exactly the survivor mean — no abort, bit-identical to a
    clean round over the survivors.

    ``compressor``: optional ``repro.core.sparse.TopKCompressor`` — the
    round's payload becomes the (n, k) shared-support compression of the
    survivors' flat updates (error feedback carried across rounds), fed
    through the same chain; the aggregated k-vector is noised (global DP)
    then scattered back to the dense domain before the strategy."""
    key, round_seed = _round_randomness(key, round_seed, round_idx)

    cids = sorted(client_results)
    protocol_order = sorted(cohort) if cohort is not None else cids
    dropped = [c for c in protocol_order if c not in client_results]
    if len(protocol_order) - len(dropped) != len(cids):
        raise ValueError("client_results must be a subset of cohort")
    plan = make_virtual_groups(protocol_order, vg_size, seed=round_idx)
    n_shards = sa.resolve_master_shards(len(plan.groups), secure_cfg)
    stats: dict = {}

    if compressor is not None:
        flat, unflatten = pe.stack_flat_updates(
            [client_results[c].update for c in cids])
        delta = unflatten(_compressed_secure_mean(
            compressor, flat, cids, protocol_order, plan, round_idx,
            round_seed, key, secure_cfg, dp_cfg, n_shards, stats))
    elif not dropped:
        if secure_cfg.vectorized:
            flat, unflatten = pe.stack_flat_updates(
                [client_results[c].update for c in cids])
            delta = unflatten(pe.aggregate_flat(
                flat, plan, cids, round_seed,
                secure_cfg=secure_cfg, dp_cfg=dp_cfg, key=key,
                n_shards=n_shards, stats=stats))
        else:
            delta = _secure_mean_serial(
                {cid: client_results[cid].update for cid in cids}, plan,
                round_seed, key, secure_cfg, dp_cfg)
    elif secure_cfg.vectorized:
        flat, unflatten = pe.stack_flat_updates(
            [client_results[c].update for c in cids])
        alive = np.asarray([c in client_results for c in protocol_order],
                           bool)
        full = jnp.zeros((len(protocol_order), flat.shape[1]), flat.dtype)
        positions = jnp.asarray(np.nonzero(alive)[0], jnp.int32)
        delta = unflatten(pe.aggregate_flat(
            full.at[positions].set(flat), plan, protocol_order, round_seed,
            secure_cfg=secure_cfg, dp_cfg=dp_cfg, key=key,
            n_shards=n_shards, alive=alive, stats=stats))
    else:
        fold_of = {cid: j for j, cid in enumerate(protocol_order)}
        delta = _secure_mean_survivors(
            {cid: client_results[cid].update for cid in cids}, plan,
            round_seed, key, secure_cfg, dp_cfg, fold_of)

    if dp_cfg.mechanism == "global" and compressor is None:
        # (compressed rounds noise the aggregated k-vector inside
        # _compressed_secure_mean, before the scatter)
        delta = dp_mod.global_dp(delta, dp_cfg, len(cids),
                                 jax.random.fold_in(key, 10_000))

    # DGA-style strategies may re-weight using client metrics; the secure
    # aggregate above is the privacy-preserving uniform mean, so strategies
    # that need per-client weights blend the (non-private) metric weights at
    # the interim level: we apply the strategy on the single cohort mean.
    with tracing.span("server_update", round=round_idx):
        delta = strategy.combine([delta], [1.0],
                                 [avg_metrics(client_results)])
        params, strategy_state = strategy.apply(params, strategy_state,
                                                delta)

    info = RoundInfo(round_idx, len(cids), len(plan.groups),
                     metrics=avg_metrics(client_results),
                     n_shards=n_shards,
                     n_selected=len(protocol_order),
                     n_dropped=len(dropped),
                     recovery_s=stats.get("recovery_s", 0.0),
                     upload_bytes=stats.get("upload_bytes", 0),
                     stage2_route=stats.get("stage2_route", "serial"))
    return params, strategy_state, info


def run_sync_round_stacked(params, strategy, strategy_state,
                           client_ids, stacked_updates, metrics_list=None,
                           *, round_idx: int, vg_size: int,
                           secure_cfg: sa.SecureAggConfig
                           = sa.SecureAggConfig(),
                           dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig(),
                           key=None, round_seed=None, cohort=None,
                           compressor=None):
    """Fused sync round: cohort updates arrive ALREADY STACKED (pytree
    leaves (n_clients, ...)) straight from ``CohortEngine.run_cohort_
    stacked`` — no unstack-to-host, no per-client dict round-trip. Produces
    the same round as :func:`run_sync_round` given the same cohort.

    ``metrics_list``: optional per-client metric dicts (input order) for
    the round's RoundInfo. ``cohort``: the FULL selected client list when
    ``client_ids``/``stacked_updates`` hold only the round's survivors —
    the plan spans the full cohort and the dropped residual is recovered,
    exactly as in :func:`run_sync_round`."""
    key, round_seed = _round_randomness(key, round_seed, round_idx)
    cids = list(client_ids)
    order = sorted(range(len(cids)), key=cids.__getitem__)
    if order != list(range(len(cids))):
        # protocol (and DP key-fold) order is sorted-cid — reorder rows
        # with one gather per leaf rather than per client
        idx = jnp.asarray(order)
        stacked_updates = jax.tree.map(lambda a: a[idx], stacked_updates)
    cids_sorted = [cids[j] for j in order]
    protocol_order = sorted(cohort) if cohort is not None else cids_sorted
    n_dropped = len(protocol_order) - len(cids_sorted)
    cohort_set = set(protocol_order)
    if n_dropped < 0 or any(c not in cohort_set for c in cids_sorted):
        raise ValueError("client_ids must be a subset of cohort")
    plan = make_virtual_groups(protocol_order, vg_size, seed=round_idx)
    n_shards = sa.resolve_master_shards(len(plan.groups), secure_cfg)
    stats: dict = {}

    if compressor is not None:
        flat = pe.ravel_rows(stacked_updates)
        template = jax.tree.map(lambda a: a[0], stacked_updates)
        _, unflatten = raveling.cached_unflatten(template)
        delta = unflatten(_compressed_secure_mean(
            compressor, flat, cids_sorted, protocol_order, plan,
            round_idx, round_seed, key, secure_cfg, dp_cfg, n_shards,
            stats))
    else:
        delta = pe.aggregate_stacked(
            stacked_updates, plan, cids_sorted, round_seed,
            secure_cfg=secure_cfg, dp_cfg=dp_cfg, key=key,
            cohort_order=protocol_order if n_dropped else None,
            stats=stats)
        if dp_cfg.mechanism == "global":
            delta = dp_mod.global_dp(delta, dp_cfg, len(cids),
                                     jax.random.fold_in(key, 10_000))

    metrics = _avg_metric_dicts(metrics_list or [])
    with tracing.span("server_update", round=round_idx):
        delta = strategy.combine([delta], [1.0], [metrics])
        params, strategy_state = strategy.apply(params, strategy_state,
                                                delta)
    info = RoundInfo(round_idx, len(cids), len(plan.groups), metrics=metrics,
                     n_shards=n_shards,
                     n_selected=len(protocol_order), n_dropped=n_dropped,
                     recovery_s=stats.get("recovery_s", 0.0),
                     upload_bytes=stats.get("upload_bytes", 0),
                     stage2_route=stats.get("stage2_route", "serial"))
    return params, strategy_state, info


def execute_cohort(engine, params, client_ids, round_idx: int,
                   *, params_per_client=None) -> dict:
    """Run a whole cohort's local training through a CohortEngine and
    return ``{cid: ClientResult}`` ready for :func:`run_sync_round`.

    ``params_per_client``: optional list of per-client param pytrees
    (clustered-FL branches / mixed-version async) — selects the engine's
    stacked-params path; otherwise ``params`` is shared by every client.
    """
    if params_per_client is not None:
        raw = engine.run_cohort_personalized(
            params_per_client, client_ids, [round_idx] * len(client_ids))
        raw = dict(zip(client_ids, raw))
    else:
        raw = engine.run_cohort(params, client_ids, round_idx)
    return {cid: ClientResult(update=u, n_samples=n, metrics=m)
            for cid, (u, n, m) in raw.items()}


def _avg_metric_dicts(metric_dicts) -> dict:
    keys = set()
    for m in metric_dicts:
        keys |= set(m)
    out = {}
    for k in keys:
        vals = [float(m[k]) for m in metric_dicts if k in m]
        if vals:
            out[k] = sum(vals) / len(vals)
    return out


def avg_metrics(client_results: dict) -> dict:
    return _avg_metric_dicts([r.metrics for r in client_results.values()])


def _dp_pad_len(k: int, buffer_size: int) -> int:
    """Batched-DP pad target for a k-row batch: the next power of two
    below one buffer, whole buffers above — O(log buffer_size) compile
    classes total, <2x padded waste."""
    if k >= buffer_size:
        return -(-k // buffer_size) * buffer_size
    p = 1
    while p < k:
        p <<= 1
    return p


class AsyncServer:
    """Papaya-style async loop (paper §4.3): no VG masking (trusted
    aggregation boundary), staleness-weighted buffer of size K.

    Two entries over the same device-resident FedBuff buffer:

    ``submit``        — the kept serial reference: ravel one update, apply
                        local DP with key ``fold_in(base, counter)``, offer
                        one row, drain on fill.
    ``submit_batch``  — the fused fast path: batched DP over all rows in
                        one jitted call (counters ``start..start+k``, the
                        SAME key-fold order the serial loop uses), rows
                        written per buffer segment with one
                        ``dynamic_update_slice`` each, draining mid-batch
                        whenever the buffer fills — bit-identical to k
                        serial ``submit`` calls in the same order
                        (tests/test_async_fused.py).
    """

    def __init__(self, params, strategy: FedBuff,
                 dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig(), seed: int = 0):
        self.params = params
        self.strategy = strategy
        self.state = strategy.init_state(params)
        self.dp_cfg = dp_cfg
        self._base_key = jax.random.PRNGKey(seed)
        self._n_submissions = 0    # DP key-fold counter, shared by both paths
        self.n_server_steps = 0

    @property
    def model_version(self) -> int:
        return self.state["model_version"]

    def _dp_sigma(self) -> float:
        return float(self.dp_cfg.noise_multiplier * self.dp_cfg.clip_norm) \
            if self.dp_cfg.noise_multiplier > 0 else 0.0

    def _step(self):
        with tracing.span("drain", step=self.n_server_steps,
                          buffer_size=self.strategy.buffer_size):
            self.params, self.state = self.strategy.drain(self.params,
                                                          self.state)
        self.n_server_steps += 1

    def submit(self, result: ClientResult, update_version: int):
        """Client pushes one pseudo-gradient. Returns True if the buffer
        drained (server step happened)."""
        flat = raveling.flat_f32(result.update)
        if self.dp_cfg.mechanism == "local":
            key = jax.random.fold_in(self._base_key, self._n_submissions)
            flat = dp_mod._flat_local_dp_jit(
                flat, key, clip_norm=float(self.dp_cfg.clip_norm),
                sigma=self._dp_sigma())
        self._n_submissions += 1
        full = self.strategy.offer_flat(flat, float(result.n_samples),
                                        update_version, self.model_version)
        if full:
            self._step()
            return True
        return False

    def submit_batch(self, stacked_flat, weights, versions) -> list:
        """Bulk entry: ``stacked_flat`` is (k, size) raveled updates in
        submission order, ``weights``/``versions`` per-row n_samples and
        update versions. Steps the server mid-batch whenever the buffer
        fills (staleness for later rows sees the bumped version, exactly
        like the serial loop). Returns the batch row indices whose
        submission completed a server step ([] if none)."""
        rows = jnp.asarray(stacked_flat, jnp.float32)
        k = rows.shape[0]
        if len(weights) != k or len(versions) != k:
            raise ValueError("weights/versions must match the batch rows")
        if self.dp_cfg.mechanism == "local":
            # pad to a BOUNDED set of shape classes (powers of two below
            # one buffer, whole buffers above) so the batched-DP jit stops
            # recompiling per batch length (the ROADMAP item) while wasted
            # clip+noise work stays < 2x (padding straight to the buffer
            # size would burn up to buffer_size extra rows on a 1-row
            # batch). Pad rows burn key-folds PAST the real counter range
            # (the counter only advances by k) and are dropped before the
            # buffer writes, so serial/batch bit-parity is untouched.
            from repro.core.strategies import _pad_rows
            rows = dp_mod.flat_local_dp_rows(
                _pad_rows(rows, _dp_pad_len(k, self.strategy.buffer_size)),
                self._base_key, self._n_submissions,
                clip_norm=float(self.dp_cfg.clip_norm),
                sigma=self._dp_sigma())
        self._n_submissions += k
        steps, i = [], 0
        while i < k:
            take = min(self.strategy.room(), k - i)
            with tracing.span("buffer_write", k=take):
                full = self.strategy.offer_rows(
                    rows[i:i + take],
                    weights[i:i + take], versions[i:i + take],
                    self.model_version)
            i += take
            if full:
                self._step()
                steps.append(i - 1)
        return steps
