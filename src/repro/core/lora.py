"""Federated LoRA / adapter tuning: make the unit of aggregation small.

Cross-device FL over the repo's multi-hundred-MB configs cannot ship dense
full-model deltas (paper §2's consumer-hardware premise; ROADMAP
"Compressed updates at LLM scale"). LoRA (Hu et al. 2021) factors selected
matrix leaves W into frozen W plus a trainable low-rank delta
``scale * A @ B`` over the TRAILING two dims (A: (..., d_out, r), B:
(..., r, d_in), B zero-initialized so the initial delta is exactly zero;
leading dims broadcast, so scan-stacked layer blocks get an independent
factor per layer). Federated tuning then becomes:

  - the FROZEN BASE is broadcast once (it never changes — clients cache
    it; the task's "model" is the ADAPTERS pytree only);
  - each client trains only its adapters (``lora_spec`` closes the task's
    loss over the frozen base, so ``CohortEngine`` and every execution
    path — serial / vmap / shard_map / waves — run UNCHANGED on the small
    adapter pytree);
  - the flat vector entering ``privacy_engine.aggregate_stacked`` is the
    concatenated adapter delta, so DP clip/noise, quantize, pairwise
    masks, VG sums, limb combine, dropout recovery and streaming waves
    all compose unchanged — orders of magnitude smaller, bit-exactness
    contract intact (the chain never sees the factoring).

Adapters are a plain nested dict keyed by the target leaf's param path
("trunk/layers/3/attn/wq" style), each entry {"A": ..., "B": ...} — a
normal pytree, so checkpointing, serialization and raveling need nothing
new.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LoRAConfig:
    """``rank``: the factor dimension r. ``alpha``: LoRA's scale numerator
    (delta = (alpha / rank) * A @ B). ``min_dim``: only leaves whose
    trailing two dims are both >= this are factored (factoring a tiny
    matrix costs more than shipping it). ``include``: optional
    path-substring allowlist — e.g. ``("attn",)`` restricts adapters to
    attention projections, the classic LoRA recipe; empty = every
    eligible matrix leaf."""
    rank: int = 4
    alpha: float = 8.0
    min_dim: int = 32
    include: tuple = ()

    @property
    def scale(self) -> float:
        return float(self.alpha) / float(self.rank)


def _matrix_dims(shape):
    """The (d_out, d_in) pair a leaf factors over: its TRAILING two dims.
    Leading dims are broadcast — the repo's configs scan-stack layer
    blocks, so an attention projection is (n_layers, d_model, d_model)
    and gets an independent rank-r factor per layer."""
    return shape[-2], shape[-1]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_target(cfg: LoRAConfig, path_s: str, leaf) -> bool:
    shape = jnp.shape(leaf)
    if len(shape) < 2 \
            or min(_matrix_dims(shape)) < max(cfg.min_dim, 2 * cfg.rank):
        return False
    if cfg.include and not any(s in path_s for s in cfg.include):
        return False
    return True


def target_paths(cfg: LoRAConfig, params) -> list:
    """Sorted param paths that get adapters under ``cfg`` (the factoring
    is a pure function of the param STRUCTURE, so client and server agree
    without negotiation)."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    return sorted(_path_str(path) for path, leaf in leaves
                  if _is_target(cfg, _path_str(path), leaf))


def init_adapters(cfg: LoRAConfig, params, key):
    """-> adapters pytree {path: {"A": (d_out, r), "B": (r, d_in)}}.

    A ~ N(0, 1/r) scaled (the standard init), B = 0 — so ``merge`` at
    init returns the base bit-for-bit and the first round's adapter
    delta is a true pseudo-gradient from zero."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    by_path = {_path_str(p): leaf for p, leaf in leaves}
    adapters = {}
    for i, path_s in enumerate(target_paths(cfg, params)):
        shape = jnp.shape(by_path[path_s])
        lead, (d_out, d_in) = shape[:-2], _matrix_dims(shape)
        k = jax.random.fold_in(key, i)
        adapters[path_s] = {
            "A": (jax.random.normal(k, (*lead, d_out, cfg.rank),
                                    jnp.float32) / np.sqrt(cfg.rank)),
            "B": jnp.zeros((*lead, cfg.rank, d_in), jnp.float32),
        }
    if not adapters:
        raise ValueError("no LoRA-eligible leaves: every matrix param is "
                         f"smaller than min_dim={cfg.min_dim} (or the "
                         f"include filter {cfg.include} matched nothing)")
    return adapters


def merge(cfg: LoRAConfig, base_params, adapters):
    """Base + adapters -> effective params (W + scale * A @ B at adapter
    paths, base leaves passed through untouched — gradients w.r.t. the
    adapters flow through the addition, the base stays frozen)."""
    scale = cfg.scale

    def leaf(path, w):
        ab = adapters.get(_path_str(path))
        if ab is None:
            return w
        return (w.astype(jnp.float32)
                + scale * (ab["A"] @ ab["B"])).astype(w.dtype)

    return jax.tree_util.tree_map_with_path(leaf, base_params)


def lora_spec(cfg: LoRAConfig, base_params, loss_fn, optimizer,
              local_steps: int = 1):
    """``LocalTrainSpec`` whose trainable params ARE the adapters pytree:
    the loss closes over the frozen base and merges per call, so
    ``CohortEngine`` (and the whole sync/async/churn machinery behind it)
    runs verbatim on the small adapter tree."""
    from repro.core.cohort_engine import LocalTrainSpec

    def adapter_loss(adapters, batch):
        return loss_fn(merge(cfg, base_params, adapters), batch)

    return LocalTrainSpec(loss_fn=adapter_loss, optimizer=optimizer,
                          local_steps=local_steps)


def n_params(tree) -> int:
    """Total element count of a pytree (the upload-accounting primitive:
    ``4 * n_params(adapters) / (4 * n_params(base))`` is the sync round's
    upload fraction before any top-k on the adapter vector)."""
    return int(sum(int(np.prod(jnp.shape(leaf)) or 1)
                   for leaf in jax.tree.leaves(tree)))


def upload_fraction(cfg: LoRAConfig, params) -> float:
    """Adapter-bytes / dense-bytes for ``params`` under ``cfg`` WITHOUT
    materializing the adapters (works on abstract ShapeDtypeStructs, so
    the <1%-of-model acceptance check runs against the real config's
    shapes for free)."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    dense = adapter = 0
    for path, leaf in leaves:
        shape = jnp.shape(leaf)
        dense += int(np.prod(shape) or 1)
        if _is_target(cfg, _path_str(path), leaf):
            d_out, d_in = _matrix_dims(shape)
            adapter += int(np.prod(shape[:-2]) or 1) \
                * cfg.rank * (d_out + d_in)
    if dense == 0:
        raise ValueError("empty params pytree")
    return adapter / dense
