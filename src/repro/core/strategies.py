"""Master-aggregation strategies (the paper's "user-defined logic", §3.1.3):
FedAvg, FedProx, DGA, plus server momentum, and FedBuff for the async path.

A Strategy consumes per-client (or per-VG-mean) pseudo-gradients and emits
the server model update. Client-side parts (FedProx's proximal term) live in
``repro.optim.fedprox``.

FedBuff buffer layout (the async fast path's device-resident state):

    _rows    : (buffer_size, size) f32 device array — one raveled update
               per row, rows [0, _cursor) valid, written in submission
               order by single-dispatch ``dynamic_update_slice`` (donated,
               so XLA updates in place)
    _weights : (buffer_size,) np.float32 HOST vector — n_samples x
               staleness discount per row (host floats so the serial and
               batched offer paths compute bit-identical weights)
    _cursor  : fill pointer; ``room() == buffer_size - _cursor``

``drain`` is ONE jitted call: mask weights past the cursor, normalize,
weighted-mean the buffer (a single matvec), and axpy the delta onto the
RAVELED params — which are cached across drains (``donate_argnums`` updates
them in place), so the server step never tree-maps over leaves.

Parity contract (the async analogue of the privacy engine's): the serial
per-submission path (``offer``) and the batched path (``offer_rows``) write
bit-identical buffer contents and weights, and both drain through the SAME
jitted function — so N serial submits and one batched submit produce
bit-identical models (tested in tests/test_async_fused.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import raveling


def _tree_scale(t, s):
    return jax.tree.map(lambda a: a * s, t)


def _tree_add(a, b, bs=1.0):
    return jax.tree.map(lambda x, y: x + bs * y, a, b)


def _tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def weighted_mean(updates, weights):
    """updates: list of pytrees; weights: list of float. -> pytree.

    Float stage (f32 normalize + accumulate, list order) — deterministic
    per call but NOT shared-jitted; strategies sit above the secure
    aggregate, outside the protocol's bit-exactness boundary (the sync
    path feeds it a single cohort mean, so order effects are moot)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.clip(jnp.sum(w), 1e-12)
    out = _tree_zeros_like(updates[0])
    for u, wi in zip(updates, list(w)):
        out = _tree_add(out, u, wi)
    return out


@dataclass
class FedAvg:
    """McMahan et al. 2017: sample-count-weighted mean of pseudo-gradients,
    applied with server learning rate (and optional momentum = FedAvgM)."""
    server_lr: float = 1.0
    momentum: float = 0.0
    name: str = "fedavg"

    def init_state(self, params):
        return {"m": _tree_zeros_like(params)} if self.momentum else {}

    def combine(self, updates, weights, client_metrics=None):
        return weighted_mean(updates, weights)

    def apply(self, params, state, delta):
        if self.momentum:
            m = _tree_add(_tree_scale(state["m"], self.momentum), delta)
            state = {"m": m}
            delta = m
        return _tree_add(params, delta, self.server_lr), state


@dataclass
class FedProx(FedAvg):
    """Li et al. 2018: server side == FedAvg; the proximal term
    mu/2 ||w - w_global||^2 is applied in the client optimizer
    (repro.optim.fedprox.proximal_sgd). ``mu`` recorded here for the task
    config."""
    mu: float = 0.01
    name: str = "fedprox"


@dataclass
class DGA(FedAvg):
    """Dynamic Gradient Aggregation (Dimitriadis et al. 2021): re-weight
    client updates by training-loss-derived softmax weights (clients with
    lower loss get larger weight), blended with sample counts."""
    beta: float = 1.0
    name: str = "dga"

    def combine(self, updates, weights, client_metrics=None):
        if not client_metrics:
            return weighted_mean(updates, weights)
        losses = jnp.asarray([m.get("loss", 0.0) for m in client_metrics],
                             jnp.float32)
        dyn = jax.nn.softmax(-self.beta * losses)
        w = jnp.asarray(weights, jnp.float32) * dyn
        return weighted_mean(updates, list(w))


@partial(jax.jit, donate_argnums=(0,))
def _buffer_write(buf, rows, cursor):
    """Write ``rows`` (k, size) into ``buf`` at row ``cursor`` — one
    ``dynamic_update_slice``, buffer donated so XLA writes in place. The
    cursor is traced, so every fill position shares one executable. Used
    by the single-row (serial ``submit``) path, where the shape is always
    (1, size); batched fills go through :func:`_buffer_write_masked`."""
    return jax.lax.dynamic_update_slice(buf, rows, (cursor, 0))


@partial(jax.jit, donate_argnums=(0,))
def _buffer_write_masked(buf, padded, cursor, k):
    """Batched fill with ONE executable for every (cursor, batch-length)
    pair: ``padded`` is the segment padded to the full buffer shape
    (buffer_size, size); buffer row p takes ``padded[p - cursor]`` when
    ``cursor <= p < cursor + k`` and keeps its old value otherwise, so the
    pad rows never land. Killing the per-batch-length recompiles of the
    old exact-shape ``dynamic_update_slice`` route (ROADMAP item) costs a
    full-buffer select per fill — amortized, the same O(buffer) work per
    drain cycle the exact writes did."""
    pos = jnp.arange(buf.shape[0])
    src = jnp.clip(pos - cursor, 0, buf.shape[0] - 1)
    valid = (pos >= cursor) & (pos < cursor + k)
    return jnp.where(valid[:, None], padded[src], buf)


def _pad_rows(rows, target_len: int):
    """(k, size) -> (target_len, size), zero rows appended. Padding is
    DATA-free: pad rows are masked out of the buffer write and weighted 0
    by the masked drain, so batch results stay bit-identical to the
    unpadded (and serial) paths."""
    k, size = rows.shape
    if k == target_len:
        return rows
    return jnp.concatenate(
        [rows, jnp.zeros((target_len - k, size), rows.dtype)])


@partial(jax.jit, static_argnames=("server_lr",), donate_argnums=(0,))
def _drain_apply(params_flat, rows, weights, n_valid, *, server_lr):
    """The one-dispatch server step: staleness-weighted mean of the valid
    buffer rows + axpy onto the raveled params (donated => in-place).
    ``n_valid`` is traced, so partial drains reuse the same executable."""
    w = jnp.where(jnp.arange(rows.shape[0]) < n_valid, weights,
                  jnp.float32(0.0))
    w = w / jnp.clip(jnp.sum(w), 1e-12)
    return params_flat + server_lr * (w @ rows)


@dataclass
class FedBuff:
    """Papaya-style async buffered aggregation (paper §2, §4.3): the server
    updates the model after every ``buffer_size`` received pseudo-gradients,
    discounting by staleness (1 + s)^-0.5. No pairwise masking — the trusted
    aggregation boundary (confidential container / on-pod) replaces it.

    The buffer is a preallocated (buffer_size, size) device array plus a
    host staleness-weight vector and fill cursor (see module docstring for
    the layout and the serial/batched parity contract)."""
    buffer_size: int = 32
    server_lr: float = 1.0
    staleness_exponent: float = 0.5
    name: str = "fedbuff"
    _rows: object = field(default=None, init=False, repr=False)
    _weights: object = field(default=None, init=False, repr=False)
    _cursor: int = field(default=0, init=False, repr=False)
    _params_flat: object = field(default=None, init=False, repr=False)
    _params_ref: object = field(default=None, init=False, repr=False)

    def init_state(self, params):
        return {"model_version": 0}

    def room(self) -> int:
        """Free buffer slots before the next server step (the public form
        of the old ``buffer_size - len(_buffer)`` reach-in)."""
        return self.buffer_size - self._cursor

    def staleness_weight(self, update_version: int, current_version: int):
        s = max(0, current_version - update_version)
        return (1.0 + s) ** (-self.staleness_exponent)

    def _ensure_buffer(self, size: int):
        if self._rows is None:
            self._rows = jnp.zeros((self.buffer_size, size), jnp.float32)
            self._weights = np.zeros(self.buffer_size, np.float32)
        elif self._rows.shape[1] != size:
            raise ValueError(f"update size {size} != buffer row size "
                             f"{self._rows.shape[1]}")

    def offer(self, update, weight: float, update_version: int,
              current_version: int):
        """Add one client update (pytree) to the buffer. Returns True if
        full (caller must ``drain`` before the next offer)."""
        return self.offer_flat(raveling.flat_f32(update), weight,
                               update_version, current_version)

    def offer_flat(self, row, weight: float, update_version: int,
                   current_version: int):
        """``offer`` for an already-raveled (size,) f32 row."""
        row = jnp.asarray(row, jnp.float32)
        return self.offer_rows(row[None, :], [weight], [update_version],
                               current_version)

    def offer_rows(self, rows, weights, update_versions, current_version):
        """Batched offer: write k <= room() raveled rows in ONE dispatch.
        ``weights``/``update_versions`` are per-row; staleness is computed
        in host floats exactly as the one-row path does, so serial and
        batched fills are bit-identical. Returns True if the buffer is now
        full.

        Single rows (the serial ``submit`` reference) keep the exact-shape
        ``dynamic_update_slice``; multi-row segments are padded to the
        buffer size and merged with the masked write, so every batch
        length shares one compiled executable (no per-length recompiles —
        the ROADMAP's padding item)."""
        rows = jnp.asarray(rows, jnp.float32)
        k = rows.shape[0]
        if k > self.room():
            raise ValueError(f"offer of {k} rows exceeds buffer room "
                             f"{self.room()} — drain first")
        self._ensure_buffer(rows.shape[1])
        for j in range(k):
            self._weights[self._cursor + j] = np.float32(
                float(weights[j]) * self.staleness_weight(
                    int(update_versions[j]), current_version))
        if k == 1:
            self._rows = _buffer_write(self._rows, rows,
                                       jnp.asarray(self._cursor, jnp.int32))
        else:
            self._rows = _buffer_write_masked(
                self._rows, _pad_rows(rows, self.buffer_size),
                jnp.asarray(self._cursor, jnp.int32),
                jnp.asarray(k, jnp.int32))
        self._cursor += k
        return self._cursor >= self.buffer_size

    def drain(self, params, state):
        """Apply the buffered aggregate (one jitted weighted-mean + axpy on
        the raveled params); resets the cursor. Rows past the cursor are
        masked to weight 0, so partial drains and pad rows are exact
        no-ops; every caller shares the one ``_drain_apply`` executable,
        which is what keeps serial and batched submit paths bit-identical
        through the float server step."""
        if self._cursor == 0:
            return params, state
        _, unflatten = raveling.cached_unflatten(params)
        if params is self._params_ref and self._params_flat is not None:
            flat = self._params_flat     # cached ravel from the last drain
        else:
            from jax.flatten_util import ravel_pytree
            flat = ravel_pytree(params)[0]
        flat = _drain_apply(flat, self._rows, jnp.asarray(self._weights),
                            jnp.asarray(self._cursor, jnp.int32),
                            server_lr=float(self.server_lr))
        params = unflatten(flat)
        self._params_flat, self._params_ref = flat, params
        self._cursor = 0
        state = dict(state, model_version=state["model_version"] + 1)
        return params, state


STRATEGIES = {
    "fedavg": FedAvg,
    "fedavgm": lambda **kw: FedAvg(momentum=kw.pop("momentum", 0.9), **kw),
    "fedprox": FedProx,
    "dga": DGA,
    "fedbuff": FedBuff,
}


def make_strategy(name: str, **kw):
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}: {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kw)
