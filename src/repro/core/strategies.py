"""Master-aggregation strategies (the paper's "user-defined logic", §3.1.3):
FedAvg, FedProx, DGA, plus server momentum, and FedBuff for the async path.

A Strategy consumes per-client (or per-VG-mean) pseudo-gradients and emits
the server model update. Client-side parts (FedProx's proximal term) live in
``repro.optim.fedprox``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


def _tree_scale(t, s):
    return jax.tree.map(lambda a: a * s, t)


def _tree_add(a, b, bs=1.0):
    return jax.tree.map(lambda x, y: x + bs * y, a, b)


def _tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def weighted_mean(updates, weights):
    """updates: list of pytrees; weights: list of float. -> pytree."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.clip(jnp.sum(w), 1e-12)
    out = _tree_zeros_like(updates[0])
    for u, wi in zip(updates, list(w)):
        out = _tree_add(out, u, wi)
    return out


@dataclass
class FedAvg:
    """McMahan et al. 2017: sample-count-weighted mean of pseudo-gradients,
    applied with server learning rate (and optional momentum = FedAvgM)."""
    server_lr: float = 1.0
    momentum: float = 0.0
    name: str = "fedavg"

    def init_state(self, params):
        return {"m": _tree_zeros_like(params)} if self.momentum else {}

    def combine(self, updates, weights, client_metrics=None):
        return weighted_mean(updates, weights)

    def apply(self, params, state, delta):
        if self.momentum:
            m = _tree_add(_tree_scale(state["m"], self.momentum), delta)
            state = {"m": m}
            delta = m
        return _tree_add(params, delta, self.server_lr), state


@dataclass
class FedProx(FedAvg):
    """Li et al. 2018: server side == FedAvg; the proximal term
    mu/2 ||w - w_global||^2 is applied in the client optimizer
    (repro.optim.fedprox.proximal_sgd). ``mu`` recorded here for the task
    config."""
    mu: float = 0.01
    name: str = "fedprox"


@dataclass
class DGA(FedAvg):
    """Dynamic Gradient Aggregation (Dimitriadis et al. 2021): re-weight
    client updates by training-loss-derived softmax weights (clients with
    lower loss get larger weight), blended with sample counts."""
    beta: float = 1.0
    name: str = "dga"

    def combine(self, updates, weights, client_metrics=None):
        if not client_metrics:
            return weighted_mean(updates, weights)
        losses = jnp.asarray([m.get("loss", 0.0) for m in client_metrics],
                             jnp.float32)
        dyn = jax.nn.softmax(-self.beta * losses)
        w = jnp.asarray(weights, jnp.float32) * dyn
        return weighted_mean(updates, list(w))


@dataclass
class FedBuff:
    """Papaya-style async buffered aggregation (paper §2, §4.3): the server
    updates the model after every ``buffer_size`` received pseudo-gradients,
    discounting by staleness (1 + s)^-0.5. No pairwise masking — the trusted
    aggregation boundary (confidential container / on-pod) replaces it."""
    buffer_size: int = 32
    server_lr: float = 1.0
    staleness_exponent: float = 0.5
    name: str = "fedbuff"
    _buffer: list = field(default_factory=list)

    def init_state(self, params):
        return {"model_version": 0}

    def staleness_weight(self, update_version: int, current_version: int):
        s = max(0, current_version - update_version)
        return (1.0 + s) ** (-self.staleness_exponent)

    def offer(self, update, weight: float, update_version: int,
              current_version: int):
        """Add one client update to the buffer. Returns True if full."""
        w = weight * self.staleness_weight(update_version, current_version)
        self._buffer.append((update, w))
        return len(self._buffer) >= self.buffer_size

    def drain(self, params, state):
        """Apply the buffered aggregate; empties the buffer."""
        if not self._buffer:
            return params, state
        updates, ws = zip(*self._buffer)
        delta = weighted_mean(list(updates), list(ws))
        self._buffer = []
        params = _tree_add(params, delta, self.server_lr)
        state = dict(state, model_version=state["model_version"] + 1)
        return params, state


STRATEGIES = {
    "fedavg": FedAvg,
    "fedavgm": lambda **kw: FedAvg(momentum=kw.pop("momentum", 0.9), **kw),
    "fedprox": FedProx,
    "dga": DGA,
    "fedbuff": FedBuff,
}


def make_strategy(name: str, **kw):
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}: {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kw)
