"""Clustered Federated Learning (Sattler et al. 2019) — the paper's §7
explicitly lists clustered FL among approaches its secure-aggregation
design "leaves limited room for"; we implement it as a beyond-paper
extension compatible with the VG machinery:

Clients are partitioned by the cosine similarity of their (dequantized)
updates; each cluster maintains its own model branch. Privacy note (as the
paper §7 anticipates): clustering needs per-CLUSTER aggregates, so the
secure-aggregation boundary moves from the cohort to the cluster — VGs are
formed within clusters and the server sees per-cluster means only (plus
the similarity statistics used for splitting, computed on VG means, never
single clients).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.strategies import FedAvg, weighted_mean


def _flat(u):
    return np.asarray(ravel_pytree(u)[0], np.float32)


def cosine_similarity_matrix(updates: list) -> np.ndarray:
    vecs = np.stack([_flat(u) for u in updates])
    norms = np.linalg.norm(vecs, axis=1, keepdims=True)
    vecs = vecs / np.clip(norms, 1e-12, None)
    return vecs @ vecs.T


def bipartition(sim: np.ndarray):
    """Sattler-style split: seed with the most dissimilar pair, assign the
    rest to the nearer seed."""
    n = sim.shape[0]
    if n < 2:
        return list(range(n)), []
    i, j = np.unravel_index(np.argmin(sim), sim.shape)
    a, b = [int(i)], [int(j)]
    for k in range(n):
        if k in (i, j):
            continue
        (a if sim[k, i] >= sim[k, j] else b).append(int(k))
    return sorted(a), sorted(b)


@dataclass
class ClusteredFL:
    """Server state: a tree of cluster branches, each with its own model.

    split when: mean intra-cluster similarity of the round's (VG-mean)
    updates drops below ``split_threshold`` and the cluster has seen at
    least ``min_rounds_before_split`` rounds.
    """
    base: FedAvg = field(default_factory=FedAvg)
    split_threshold: float = 0.0
    min_rounds_before_split: int = 2
    max_clusters: int = 4

    def init(self, params):
        return {"clusters": [{"model": params, "members": None,
                              "rounds": 0,
                              "state": self.base.init_state(params)}]}

    def cluster_of(self, state, client_id):
        for idx, c in enumerate(state["clusters"]):
            if c["members"] is None or client_id in c["members"]:
                return idx
        return 0

    def round(self, state, cluster_idx: int, vg_mean_updates: list,
              vg_weights: list, vg_member_lists: list):
        """Apply one round for one cluster given per-VG mean updates (the
        secure-aggregation outputs — never single-client updates)."""
        c = state["clusters"][cluster_idx]
        delta = weighted_mean(vg_mean_updates, vg_weights)
        c["model"], c["state"] = self.base.apply(c["model"], c["state"],
                                                 delta)
        c["rounds"] += 1

        if (len(state["clusters"]) < self.max_clusters
                and c["rounds"] >= self.min_rounds_before_split
                and len(vg_mean_updates) >= 2):
            sim = cosine_similarity_matrix(vg_mean_updates)
            off_diag = sim[~np.eye(len(sim), dtype=bool)]
            if off_diag.size and float(off_diag.mean()) < self.split_threshold:
                a, b = bipartition(sim)
                if a and b:
                    members_a = sorted(m for g in a
                                       for m in vg_member_lists[g])
                    members_b = sorted(m for g in b
                                       for m in vg_member_lists[g])
                    c["members"] = members_a
                    state["clusters"].append({
                        "model": jax.tree.map(jnp.copy, c["model"]),
                        "members": members_b,
                        "rounds": 0,
                        "state": self.base.init_state(c["model"]),
                    })
                    return state, (members_a, members_b)
        return state, None
