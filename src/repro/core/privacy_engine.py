"""Vectorized sync-round privacy pipeline: the paper's whole §4 chain —
per-client DP clip/noise -> quantize -> pairwise mask -> stage-1 VG modular
sums — as ONE jitted computation over the cohort's stacked flat updates.

The serial reference (``secure_agg.secure_aggregate_round`` plus the
per-client DP loop in ``orchestrator.run_sync_round``) dispatches O(n_clients)
python-level jnp calls per round; production FL treats exactly this path as
the server's throughput-critical hot loop. Here the cohort is an
``(n_clients, size)`` array and every stage is vmapped, so the full pipeline
is one XLA program (two at most — see bucketing) regardless of cohort size.

Ragged Virtual-Group plans are handled by SIZE-BUCKETING: ``make_virtual_
groups`` merges a trailing remainder < min_vg_size into the previous group,
so a plan contains at most TWO distinct group sizes — i.e. at most two
compiled shapes. Only the bucket GEOMETRY (group size, group count) is a
static jit argument; the per-round client permutation and group ids are
traced arrays, so successive rounds (which reshuffle clients) reuse the
same compiled program. Within a bucket,
masking reuses ``masking.net_mask_traced`` via ``protect_cohort_grouped``
(pure-jnp path) or the batched Pallas kernel ``kernels.ops.mask_apply_cohort``
(``use_kernels=True``).

Bit-exactness contract (hypothesis-tested in tests/test_privacy_engine.py):
the engine's output is bit-identical to the serial reference. The integer
stages (quantize codes, masks, wrapping sums, stage-2 limb states) are exact
by construction; the float stages (DP rows, the stage-2 dequantize tail) are
shared JITTED functions on both paths, because XLA FMA-contracts the
clip/noise and dequantize chains — an eager reference would differ from any
jitted pipeline by ulps. The big jit therefore returns exact integer
per-shard limb states and the final dequantize runs in the same standalone
``secure_agg._finalize_jit`` executable the serial master uses.

Stage 2 is the hierarchical limb-state combine of ``repro.core.quantize``:
the cohort's VGs split into disjoint pod shards, each folded to a canonical
base-2^16 limb state inside the big jit (exact for < 2^16 VGs per shard),
merged exactly across < 2^16 shards, then dequantized once — lifting the
old single-tier 2^16-VG cap to ~2^32 VGs with bit-identical results at any
shard count (``SecureAggConfig.limbs=4`` adds a 2^48 lane for plans past
that). (The pre-PR-2 master summed interims in raw uint32 and silently
wrapped once bits + ceil(log2(total_cohort)) > 32.)

CHURN: ``aggregate_flat(alive=...)`` / ``aggregate_stacked(cohort_order=
...)`` run the same pipeline when part of the selected cohort dropped
mid-round — survivor-only group sums (payloads still carry FULL masks),
one batched mask-recovery call (``repro.core.dropout``), then the shared
combine over |S|. Bit-identical to a clean round over the survivors.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import dp as dp_mod
from repro.core import masking
from repro.core import raveling
from repro.core.kdf import U32
from repro.core.quantize import (check_headroom, check_master_headroom,
                                 check_shard_headroom, interim_limb_state,
                                 quantize, shard_limb_states)
from repro.core.secure_agg import (AggregationRefused, SecureAggConfig,
                                   _shard_limbs_jit, combine_limb_states,
                                   group_seed, resolve_master_shards)
from repro import tracing  # stdlib-only; safe for core to depend on


@dataclass(frozen=True)
class BucketSpec:
    """Host-side layout of all virtual groups sharing one size. Only
    (g, n_groups) reaches jit as a static; rows/vg_ids are shipped as
    traced arrays so per-round reshuffles don't recompile.

    ``rows[m * g + i]`` is the stack-row of member ``i`` of the bucket's
    ``m``-th group (protocol order within the group)."""
    g: int          # group size
    vg_ids: tuple   # plan vg_ids of the bucket's groups, plan order
    rows: tuple     # flat row indices into the (n_clients, size) stack

    @property
    def n_groups(self) -> int:
        return len(self.vg_ids)


def plan_buckets(plan, client_order) -> tuple:
    """Bucket a VGPlan's groups by size against a stack ordering.

    ``client_order``: the client ids of the stacked update rows, row order.
    The merge rule in ``make_virtual_groups`` yields at most two distinct
    sizes, so this returns at most two buckets (sorted by size)."""
    row_of = {cid: j for j, cid in enumerate(client_order)}
    if len(row_of) != len(client_order):
        raise ValueError("duplicate client ids in stacked cohort")
    by_size: dict = {}
    for grp in plan.groups:
        by_size.setdefault(len(grp.members), []).append(grp)
    buckets = []
    for g in sorted(by_size):
        groups = by_size[g]
        buckets.append(BucketSpec(
            g=g,
            vg_ids=tuple(grp.vg_id for grp in groups),
            rows=tuple(row_of[cid] for grp in groups
                       for cid in grp.members)))
    return tuple(buckets)


def _interims_body(flat, round_seed, key, rows_t, vgs_t, alive,
                   bucket_shapes, secure_cfg, dp_cfg):
    """Shared trace body: (n, size) f32 stacked updates -> (G, size)
    uint32 per-VG wrapping sums, bucket order.

    ``alive``: None (every row submits — the churn-free path compiles
    with no extra ops) or a traced (n,) bool row mask: each SURVIVOR's
    payload still carries its FULL net mask (clients masked before drops
    were known), dropped rows are zeroed before the group sums, and the
    caller repairs the non-cancelling residual via
    ``dropout.recover_interims``. DP/quantize run on every row either
    way, so a survivor's code — key-folded at its FULL-cohort row — is
    bit-identical whether or not anyone else dropped."""
    n = flat.shape[0]
    flat = flat.astype(jnp.float32)

    # per-client DP, vmapped over the client axis; key folding follows the
    # row order (== sorted-cid order in the orchestrator), matching the
    # serial reference's fold_in(key, j) exactly.
    if dp_cfg.mechanism == "local":
        sigma = float(dp_cfg.noise_multiplier * dp_cfg.clip_norm) \
            if dp_cfg.noise_multiplier > 0 else 0.0
        keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(
            jnp.arange(n, dtype=jnp.uint32))
        flat = jax.vmap(partial(dp_mod.flat_local_dp,
                                clip_norm=float(dp_cfg.clip_norm),
                                sigma=sigma))(flat, keys)
    elif dp_cfg.mechanism == "global":
        # clip here; the server-side noise is added to the combined mean by
        # the orchestrator (it is one draw, not a per-client stage)
        flat = jax.vmap(partial(dp_mod.flat_clip,
                                clip_norm=float(dp_cfg.clip_norm)))(flat)

    qs = quantize(flat, secure_cfg.clip, secure_cfg.bits)   # (n, size) u32

    interims = []
    for (g, m), rows, vgs in zip(bucket_shapes, rows_t, vgs_t):
        qb = qs[rows]                                       # (m*g, size)
        gseeds = jnp.repeat(
            jax.vmap(lambda v: group_seed(round_seed, v))(vgs),
            g, axis=0)                                      # (m*g, 2)
        idxs = jnp.tile(jnp.arange(g, dtype=U32), m)
        if secure_cfg.use_kernels:
            from repro.kernels import ops
            masked = ops.mask_apply_cohort(qb, idxs, gseeds, g)
        else:
            masked = masking.protect_cohort_grouped(qb, idxs, gseeds, g)
        if alive is not None:
            masked = jnp.where(alive[rows][:, None], masked,
                               jnp.zeros((), U32))
        interims.append(masking.vg_sums(masked, g))         # (m, size)
    return jnp.concatenate(interims, axis=0)                # (G, size)


@partial(jax.jit,
         static_argnames=("bucket_shapes", "n_shards", "secure_cfg",
                          "dp_cfg"))
def _cohort_interims(flat, round_seed, key, rows_t, vgs_t, *,
                     bucket_shapes, n_shards, secure_cfg, dp_cfg):
    """The one compiled call: (n, size) f32 stacked updates -> exact
    (n_shards, n_limbs, size) uint32 per-shard stage-2 limb states
    (``quantize.interim_limb_state`` over disjoint VG shards, bucket
    order; zero-row padding on the last shard is a no-op in the integer
    sums).

    ``bucket_shapes``: tuple of (g, n_groups) per bucket — with
    ``n_shards`` the only plan-dependent statics; the per-round
    permutation (``rows_t`` row indices, ``vgs_t`` group ids) is traced,
    so rounds with the same cohort/bucket geometry hit the jit cache even
    though ``make_virtual_groups`` reshuffles clients every round."""
    stacked = _interims_body(flat, round_seed, key, rows_t, vgs_t, None,
                             bucket_shapes, secure_cfg, dp_cfg)
    # pod-shard axis: fold each disjoint VG shard into its limb state
    # INSIDE this jit (tier 1, exact); the cross-shard merge + float tail
    # run in the shared executables outside (aggregate_flat).
    return shard_limb_states(stacked, n_shards, secure_cfg.limbs)


@partial(jax.jit,
         static_argnames=("bucket_shapes", "secure_cfg", "dp_cfg"))
def _cohort_interims_churn(flat, round_seed, key, rows_t, vgs_t, alive, *,
                           bucket_shapes, secure_cfg, dp_cfg):
    """Churn twin of :func:`_cohort_interims`: survivor-only group sums
    returned RAW (G, size) — mask recovery scatter-adds onto them before
    the limb fold, so the fold runs outside this jit. ``alive`` is a
    traced row mask; rounds that only differ in WHO dropped reuse the
    executable."""
    return _interims_body(flat, round_seed, key, rows_t, vgs_t, alive,
                          bucket_shapes, secure_cfg, dp_cfg)


@partial(jax.jit, static_argnames=("g", "secure_cfg", "dp_cfg"))
def _wave_limb_state(wave_flat, row_ids, round_seed, key, vgs, real, *,
                     g, secure_cfg, dp_cfg):
    """One streaming wave: a fixed-width chunk of whole virtual groups ->
    its exact stage-2 limb state. The wave scheduler's compiled unit.

    ``wave_flat``: (m*g, size) f32 — the wave's rows gathered group-major
    on the host; ``row_ids``: (m*g,) uint32 GLOBAL stack rows — the DP key
    folds at the same ``fold_in(key, row)`` values as the single-dispatch
    ``_interims_body``, so a client's noised row is bit-identical in any
    wave; ``vgs``: (m,) uint32 plan group ids; ``real``: (m,) bool — the
    last wave pads to the fixed width by repeating its final group, and
    pad groups' interims are zeroed before the limb fold (zero rows are
    exact no-ops in the integer sums), so one compiled shape serves every
    wave. The per-stage math is the ``_interims_body`` chain verbatim;
    limb digits are shard-layout independent, so stacking wave states and
    merging through the shared executables is bit-identical to the
    whole-cohort dispatch."""
    m = vgs.shape[0]
    flat = wave_flat.astype(jnp.float32)
    if dp_cfg.mechanism == "local":
        sigma = float(dp_cfg.noise_multiplier * dp_cfg.clip_norm) \
            if dp_cfg.noise_multiplier > 0 else 0.0
        keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(row_ids)
        flat = jax.vmap(partial(dp_mod.flat_local_dp,
                                clip_norm=float(dp_cfg.clip_norm),
                                sigma=sigma))(flat, keys)
    elif dp_cfg.mechanism == "global":
        flat = jax.vmap(partial(dp_mod.flat_clip,
                                clip_norm=float(dp_cfg.clip_norm)))(flat)
    qs = quantize(flat, secure_cfg.clip, secure_cfg.bits)
    gseeds = jnp.repeat(
        jax.vmap(lambda v: group_seed(round_seed, v))(vgs), g, axis=0)
    idxs = jnp.tile(jnp.arange(g, dtype=U32), m)
    if secure_cfg.use_kernels:
        from repro.kernels import ops
        masked = ops.mask_apply_cohort(qs, idxs, gseeds, g)
    else:
        masked = masking.protect_cohort_grouped(qs, idxs, gseeds, g)
    interims = masking.vg_sums(masked, g)                   # (m, size)
    interims = jnp.where(real[:, None], interims, jnp.zeros((), U32))
    return interim_limb_state(interims, secure_cfg.limbs)


def _waved_states(flat, buckets, round_seed, key, wave, secure_cfg, dp_cfg):
    """Stream the cohort through ~``wave``-client compiled waves of whole
    virtual groups -> (n_waves, n_limbs, size) exact per-wave limb states.

    ``flat`` stays on the HOST; only one wave's rows transfer per dispatch
    — the OOM posture that lets a 65k-client cohort run through a
    4096-wide executable. At most one compiled shape per bucket (two per
    plan, like the single-dispatch path)."""
    flat = np.asarray(flat, np.float32)
    states = []
    for b in buckets:
        m_w = max(1, wave // b.g)          # whole groups per wave
        check_master_headroom(m_w)
        rows = np.asarray(b.rows, np.int64).reshape(b.n_groups, b.g)
        vgs = np.asarray(b.vg_ids, np.uint32)
        for s in range(0, b.n_groups, m_w):
            chunk = rows[s:s + m_w]
            cv = vgs[s:s + m_w]
            m_real = chunk.shape[0]
            if m_real < m_w:               # pad to the fixed wave shape
                pad = m_w - m_real
                chunk = np.concatenate([chunk,
                                        np.repeat(chunk[-1:], pad, axis=0)])
                cv = np.concatenate([cv, np.repeat(cv[-1:], pad)])
            with tracing.span("wave", wave=len(states), g=b.g,
                              n_groups=m_real) \
                    .mark_fused("dp", "quantize", "mask", "vg_sum"):
                states.append(_wave_limb_state(
                    jnp.asarray(flat[chunk.ravel()]),
                    jnp.asarray(chunk.ravel().astype(np.uint32)),
                    round_seed, key, jnp.asarray(cv),
                    jnp.asarray(np.arange(m_w) < m_real),
                    g=b.g, secure_cfg=secure_cfg, dp_cfg=dp_cfg))
    return jnp.stack(states)


@jax.jit
def ravel_rows(stacked_updates):
    """Stacked pytree (leaves (n, ...)) -> (n, size) f32, in-jit (the fused
    entries — sync cohort and async buffer — never unstack to host)."""
    return jax.vmap(
        lambda t: ravel_pytree(t)[0].astype(jnp.float32))(stacked_updates)


def stack_flat_updates(updates):
    """[update pytree, ...] -> ((n, size) device array, unflatten fn).

    Host-side np staging (one transfer, not n_leaves * n transfers) for the
    orchestrator path whose inputs are per-client host pytrees. The
    unflatten closure is cached by treedef+shapes (``repro.core.raveling``)
    instead of being rebuilt — with a throwaway data ravel — every round."""
    rows = []
    for u in updates:
        rows.append(np.concatenate(
            [np.asarray(leaf, np.float32).ravel()
             for leaf in jax.tree.leaves(u)]))
    _, unflatten = raveling.cached_unflatten(updates[0])
    return jnp.asarray(np.stack(rows)), unflatten


def _check_plan(buckets, secure_cfg, n_shards=None) -> int:
    """Headroom guards for a bucketed plan; returns the resolved stage-2
    shard count (tier-1 per-shard and tier-2 cross-shard bounds both
    enforced by ``resolve_master_shards``)."""
    for b in buckets:
        check_headroom(secure_cfg.bits, b.g)
    return resolve_master_shards(sum(b.n_groups for b in buckets),
                                 secure_cfg, n_shards)


def aggregate_flat(flat, plan, client_order, round_seed, *,
                   secure_cfg: SecureAggConfig = SecureAggConfig(),
                   dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig(),
                   key=None, n_shards=None, alive=None, stats=None):
    """Full pipeline over pre-flattened rows -> (size,) f32 cohort mean.

    ``n_shards`` (or ``secure_cfg.master_shards``) shards the stage-2
    combine across per-pod limb-state accumulators — required past 2^16
    VGs, bit-identical at any legal count (auto-resolved by default).

    ``alive``: optional (n,) host bool array — the churn path. False rows
    are clients that were SELECTED into the plan (their peers' payloads
    carry mask terms for them) but never submitted; their rows in ``flat``
    are ignored (feed zeros). Survivor group sums are repaired by
    ``dropout.recover_interims`` and the mean divides by |S| — the guards
    and the dequantize retarget to the survivor count, and the result is
    bit-identical to a clean round over the survivors (same DP key-fold
    rows). ``stats``: optional dict, receives ``n_dropped``/``recovery_s``
    from the recovery step."""
    buckets = plan_buckets(plan, client_order)
    n_shards = _check_plan(buckets, secure_cfg, n_shards)
    n = flat.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    round_seed = jnp.asarray(round_seed, U32)
    rows_t = tuple(jnp.asarray(b.rows, jnp.int32) for b in buckets)
    vgs_t = tuple(jnp.asarray(b.vg_ids, U32) for b in buckets)
    bucket_shapes = tuple((b.g, b.n_groups) for b in buckets)
    if alive is None:
        wave = int(getattr(secure_cfg, "wave_clients", 0))
        if 0 < wave < n:
            # streaming-wave route: same per-row math, fixed-width
            # compiled waves, exact partial limb folds (bit-identical —
            # limb digits are layout-independent and the float tail is
            # the same shared executable)
            if stats is not None:
                stats["stage2_route"] = "waved"
            with tracing.span("secure_agg", route="waved", n=n,
                              n_shards=n_shards):
                states = _waved_states(flat, buckets, round_seed, key,
                                       wave, secure_cfg, dp_cfg)
                check_shard_headroom(states.shape[0])
                with tracing.span("limb_combine",
                                  n_states=int(states.shape[0])):
                    return combine_limb_states(states, n, secure_cfg)
        if stats is not None:
            stats["stage2_route"] = "single_dispatch"
        with tracing.span("secure_agg", route="single_dispatch", n=n,
                          n_shards=n_shards):
            with tracing.span("cohort_interims", n=n) \
                    .mark_fused("dp", "quantize", "mask", "vg_sum"):
                states = _cohort_interims(
                    jnp.asarray(flat), round_seed, key, rows_t, vgs_t,
                    bucket_shapes=bucket_shapes, n_shards=n_shards,
                    secure_cfg=secure_cfg, dp_cfg=dp_cfg)
            with tracing.span("limb_combine", n_shards=n_shards):
                return combine_limb_states(states, n, secure_cfg)

    from repro.core import dropout
    alive = np.asarray(alive, bool)
    if alive.shape[0] != n:
        raise ValueError(f"alive mask has {alive.shape[0]} rows for "
                         f"{n} clients")
    if not alive.any():
        raise AggregationRefused(
            "no survivors: every selected client dropped — nothing to "
            "aggregate")
    # min-survivor refusal (mirrors the serial loop's `continue`): a group
    # whose survivor count drops below the threshold is VOIDED by marking
    # its remaining rows dead — a fully-dead group's recovered interim is
    # an exact-zero row, so voiding here is bit-identical to skipping the
    # group serially, and the mean's divisor shrinks with it.
    min_surv = int(getattr(secure_cfg, "min_survivors_per_vg", 1))
    n_voided_groups = 0
    if min_surv > 1 and not alive.all():
        alive = alive.copy()
        for b in buckets:
            rows_m = np.asarray(b.rows, np.int64).reshape(b.n_groups, b.g)
            counts = alive[rows_m].sum(axis=1)
            void = (counts > 0) & (counts < min_surv)
            if void.any():
                n_voided_groups += int(void.sum())
                alive[rows_m[void].ravel()] = False
        if not alive.any():
            raise AggregationRefused(
                "round refused: every surviving virtual group fell below "
                f"min_survivors_per_vg={min_surv}")
    if stats is not None:
        stats["n_voided_groups"] = n_voided_groups
        stats["stage2_route"] = "churn_recovery"
    n_survivors = int(alive.sum())
    with tracing.span("secure_agg", route="churn_recovery", n=n,
                      n_survivors=n_survivors, n_shards=n_shards):
        with tracing.span("cohort_interims", n=n, churn=True) \
                .mark_fused("dp", "quantize", "mask", "vg_sum"):
            interims = _cohort_interims_churn(
                jnp.asarray(flat), round_seed, key, rows_t, vgs_t,
                jnp.asarray(alive), bucket_shapes=bucket_shapes,
                secure_cfg=secure_cfg, dp_cfg=dp_cfg)
        with tracing.span("mask_recovery",
                          n_dropped=n - n_survivors):
            interims = dropout.recover_interims(interims, buckets, alive,
                                                round_seed, stats=stats)
        with tracing.span("limb_combine", n_shards=n_shards):
            states = _shard_limbs_jit(interims, n_shards, secure_cfg.limbs)
            return combine_limb_states(states, n_survivors, secure_cfg)


def aggregate_stacked(stacked_updates, plan, client_order, round_seed, *,
                      secure_cfg: SecureAggConfig = SecureAggConfig(),
                      dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig(),
                      key=None, cohort_order=None, stats=None):
    """Fused entry: consume a CohortEngine's already-stacked cohort output
    (leaves (n, ...)) directly — no unstack-to-host, no per-client dicts.
    Returns the cohort-mean update pytree.

    ``cohort_order``: the churn path — the FULL selected cohort in
    protocol (row) order, a superset of ``client_order`` (the survivors
    whose rows ``stacked_updates`` holds). Survivor rows scatter into
    their full-cohort positions (zeros at dropped rows, which the alive
    mask excludes), so each survivor keeps the DP key-fold of its
    selection-time row and the recovered mean is bit-identical to a clean
    round over the survivors."""
    flat = ravel_rows(stacked_updates)
    template = jax.tree.map(lambda a: a[0], stacked_updates)
    _, unflatten = raveling.cached_unflatten(template)
    alive = None
    if cohort_order is not None and list(cohort_order) != list(client_order):
        cohort_order = list(cohort_order)
        pos_of = {cid: j for j, cid in enumerate(cohort_order)}
        positions = jnp.asarray([pos_of[c] for c in client_order],
                                jnp.int32)
        full = jnp.zeros((len(cohort_order), flat.shape[1]), flat.dtype)
        flat = full.at[positions].set(flat)
        alive = np.zeros(len(cohort_order), bool)
        alive[np.asarray(positions)] = True
        client_order = cohort_order
    mean_flat = aggregate_flat(flat, plan, client_order, round_seed,
                               secure_cfg=secure_cfg, dp_cfg=dp_cfg,
                               key=key, alive=alive, stats=stats)
    return unflatten(mean_flat)


class PrivacyEngine:
    """Config-bound facade over the pipeline (the object the service layer
    and simulator thread through; jit caches are module-global, so engines
    are free to construct per round)."""

    def __init__(self, secure_cfg: SecureAggConfig = SecureAggConfig(),
                 dp_cfg: dp_mod.DPConfig = dp_mod.DPConfig()):
        self.secure_cfg = secure_cfg
        self.dp_cfg = dp_cfg

    def aggregate_flat(self, flat, plan, client_order, round_seed, key=None):
        return aggregate_flat(flat, plan, client_order, round_seed,
                              secure_cfg=self.secure_cfg,
                              dp_cfg=self.dp_cfg, key=key)

    def aggregate_stacked(self, stacked_updates, plan, client_order,
                          round_seed, key=None):
        return aggregate_stacked(stacked_updates, plan, client_order,
                                 round_seed, secure_cfg=self.secure_cfg,
                                 dp_cfg=self.dp_cfg, key=key)

    def aggregate_updates(self, updates, plan, round_seed, key=None):
        """Dict path convenience: {cid: update pytree} (sorted-cid row
        order, like the serial reference)."""
        cids = sorted(updates)
        flat, unflatten = stack_flat_updates([updates[c] for c in cids])
        return unflatten(self.aggregate_flat(flat, plan, cids, round_seed,
                                             key=key))
