"""Pallas kernel: stage-1 modular (wrapping uint32) sum over the client axis.

Input (n_clients, rows, 128) masked payloads -> (rows, 128) interim VG
aggregate. Grid is (row_blocks, n_clients) with the client axis innermost;
the output block is revisited across the client axis and accumulated in VMEM
(classic reduction pattern), so each payload word is read from HBM exactly
once and the interim result written once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, ROW_BLOCK, interpret_mode


def _secure_sum_kernel(x_ref, out_ref):
    i_client = pl.program_id(1)

    @pl.when(i_client == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += x_ref[0]


def secure_sum_tiled(payloads, *, interpret=None):
    """payloads: (n, rows, 128) uint32 -> (rows, 128) uint32 wrapping sum."""
    n, rows, lanes = payloads.shape
    assert lanes == LANES and rows % ROW_BLOCK == 0
    interpret = interpret_mode() if interpret is None else interpret
    return pl.pallas_call(
        _secure_sum_kernel,
        grid=(rows // ROW_BLOCK, n),
        in_specs=[pl.BlockSpec((1, ROW_BLOCK, LANES),
                               lambda r, c: (c, r, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, LANES), lambda r, c: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
        interpret=interpret,
    )(payloads)
