"""Public jit'd wrappers for the secure-aggregation Pallas kernels.

Each op takes flat (N,) payload vectors, handles (rows, 128) tiling/padding,
and dispatches to the kernel. ``repro.kernels.ref`` holds the pure-jnp
oracles with identical signatures; tests sweep shapes/dtypes and
assert_allclose (bit-equality for the integer ops) between the two.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kdf import U32, pair_seed
from repro.core.quantize import DEFAULT_BITS, DEFAULT_CLIP
from repro.kernels import dp_noise as _dp
from repro.kernels import mask_gen as _mg
from repro.kernels import quantize as _qz
from repro.kernels import secure_sum as _ss
from repro.kernels.common import LANES, ROW_BLOCK, pad_to_tiles, unpad


def build_pair_seeds(i: int, n: int, round_seed):
    """(n-1, 3) uint32 rows [k0, k1, sign_pos] for client i's peers."""
    rows = []
    for v in range(n):
        if v == i:
            continue
        u, w = min(i, v), max(i, v)
        s = pair_seed(round_seed, u, w)
        rows.append(jnp.concatenate([s, jnp.asarray([1 if i < v else 0],
                                                    U32)]))
    if not rows:
        return jnp.zeros((0, 3), U32)
    return jnp.stack(rows)


@partial(jax.jit, static_argnums=(1, 2))
def mask_apply(q_flat, i: int, n: int, round_seed, offset: int = 0):
    """Kernel-path equivalent of ``core.masking.apply_mask``."""
    if n <= 1:
        return q_flat
    seeds = build_pair_seeds(i, n, round_seed)
    tiled, size = pad_to_tiles(q_flat)
    out = _mg.mask_apply_tiled(tiled, seeds, base_offset=offset)
    return unpad(out, size)


def build_pair_seeds_traced(i, g: int, group_seed):
    """Traced-index twin of ``build_pair_seeds`` for whole-cohort batching:
    (g-1, 3) uint32 rows [k0, k1, sign_pos] for client ``i`` (traced
    within-group index) of a group of static size ``g``."""
    j = jnp.arange(g - 1, dtype=U32)
    peer = jnp.where(j >= jnp.asarray(i, U32), j + U32(1), j)  # skip self
    i_arr = jnp.full_like(peer, i)
    lo = jnp.minimum(i_arr, peer)
    hi = jnp.maximum(i_arr, peer)
    ks = jax.vmap(lambda u, v: pair_seed(group_seed, u, v))(lo, hi)
    sign = (i_arr < peer).astype(U32)
    return jnp.concatenate([ks, sign[:, None]], axis=1)


@partial(jax.jit, static_argnums=(3,))
def mask_apply_cohort(qs, idxs, group_seeds, g: int, offset: int = 0):
    """Whole-cohort batched masking: ONE kernel launch for every client of a
    uniform-group-size bucket (the privacy engine's ``use_kernels`` path).

    qs: (n, size) uint32 quantized updates; idxs: (n,) uint32 within-group
    indices; group_seeds: (n, 2) uint32 per-client group seeds; ``g`` the
    bucket's group size. Bit-identical to the per-client
    ``core.masking.apply_mask`` (wrapping-add order-independence)."""
    if g <= 1:
        return qs
    n, size = qs.shape
    seeds = jax.vmap(lambda i, s: build_pair_seeds_traced(i, g, s))(
        idxs, group_seeds)
    per_block = ROW_BLOCK * LANES
    padded = -(-size // per_block) * per_block
    tiled = jnp.pad(qs, ((0, 0), (0, padded - size))).reshape(
        n, -1, LANES)
    out = _mg.mask_apply_batched_tiled(tiled, seeds, base_offset=offset)
    return out.reshape(n, -1)[:, :size]


@partial(jax.jit, static_argnums=(1, 2))
def quantize(x_flat, clip: float = DEFAULT_CLIP, bits: int = DEFAULT_BITS):
    tiled, size = pad_to_tiles(x_flat.astype(jnp.float32))
    return unpad(_qz.quantize_tiled(tiled, clip, bits), size)


@partial(jax.jit, static_argnums=(1, 2, 3))
def dequantize_sum(q_flat, n: int, clip: float = DEFAULT_CLIP,
                   bits: int = DEFAULT_BITS):
    tiled, size = pad_to_tiles(q_flat)
    return unpad(_qz.dequantize_sum_tiled(tiled, n, clip, bits), size)


@jax.jit
def secure_sum(payloads):
    """payloads (n, N) uint32 -> (N,) wrapping modular sum."""
    n = payloads.shape[0]
    tiled0, size = pad_to_tiles(payloads[0])
    stacked = jnp.stack([pad_to_tiles(payloads[j])[0] for j in range(n)])
    return unpad(_ss.secure_sum_tiled(stacked), size)


@partial(jax.jit, static_argnums=(2,))
def dp_clip_noise(x_flat, clip_factor, sigma: float, seed):
    tiled, size = pad_to_tiles(x_flat.astype(jnp.float32))
    return unpad(_dp.dp_clip_noise_tiled(tiled, clip_factor, sigma, seed),
                 size)
