"""Shared Pallas kernel helpers: tiling geometry + in-kernel KDF rounds.

TPU geometry: lanes are 128-wide, the VPU operates on (8, 128) uint32 tiles,
so every kernel here works on payloads reshaped to (rows, 128) with row
blocks that are multiples of 8. ``pad_to_tiles`` / ``unpad`` handle arbitrary
flat payload sizes at the ops.py boundary.

The in-kernel ``kdf_u32`` is bit-identical to ``repro.core.kdf.kdf_u32``
(pure uint32 ARX ops — the same jnp code runs inside the kernel body).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kdf import kdf_u32  # bit-identical inside kernel bodies

LANES = 128
ROW_BLOCK = 256        # (256, 128) uint32 = 128 KiB per operand block in VMEM


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def pad_to_tiles(flat, block_rows=ROW_BLOCK):
    """flat (N,) -> (rows, 128) with rows % block_rows == 0. Returns
    (tiled, original_n)."""
    n = flat.shape[0]
    per_block = block_rows * LANES
    padded = (n + per_block - 1) // per_block * per_block
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANES), n


def unpad(tiled, n):
    return tiled.reshape(-1)[:n]


def global_index(pid, block_rows=ROW_BLOCK):
    """uint32 flat element indices for grid cell ``pid``: (block_rows, 128)."""
    base = (pid * block_rows * LANES).astype(jnp.uint32)
    row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANES), 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANES), 1)
    return base + row * jnp.uint32(LANES) + lane
