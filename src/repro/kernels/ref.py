"""Pure-jnp oracles for every Pallas kernel (same signatures as ops.py).

These delegate to ``repro.core`` where the reference math already lives —
the kernels must match them bit-exactly for the integer ops and to float
rounding for the f32 ops.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import masking
from repro.core.kdf import kdf_u32
from repro.core.quantize import (DEFAULT_BITS, DEFAULT_CLIP, dequantize_sum
                                 as _dequantize_sum, quantize as _quantize)


def mask_apply(q_flat, i: int, n: int, round_seed, offset: int = 0):
    return masking.apply_mask(q_flat, i, n, round_seed, offset)


def mask_apply_cohort(qs, idxs, group_seeds, g: int, offset: int = 0):
    return masking.protect_cohort_grouped(qs, idxs, group_seeds, g, offset)


def quantize(x_flat, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    return _quantize(x_flat, clip, bits)


def dequantize_sum(q_flat, n, clip=DEFAULT_CLIP, bits=DEFAULT_BITS):
    return _dequantize_sum(q_flat, n, clip, bits)


def secure_sum(payloads):
    return masking.modular_sum(payloads)


def dp_clip_noise(x_flat, clip_factor, sigma: float, seed):
    """Bit-matches the kernel's in-lane Box–Muller draw."""
    seed = jnp.asarray(seed, jnp.uint32)
    ctr = jnp.arange(x_flat.shape[0], dtype=jnp.uint32)
    b1 = kdf_u32(seed[0], seed[1], ctr * jnp.uint32(2))
    b2 = kdf_u32(seed[0], seed[1], ctr * jnp.uint32(2) + jnp.uint32(1))
    u1 = (b1.astype(jnp.float32) + 1.0) * (1.0 / 4294967296.0)
    u2 = b2.astype(jnp.float32) * (1.0 / 4294967296.0)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(6.2831853071795864 * u2)
    return (x_flat.astype(jnp.float32) * jnp.asarray(clip_factor, jnp.float32)
            + jnp.float32(sigma) * z)
