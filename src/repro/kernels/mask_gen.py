"""Pallas kernel: fused pairwise-mask generation + application.

The secure-aggregation hot loop (paper §4.1): client i must expand one KDF
mask stream per VG peer over the FULL update vector and fold them into its
quantized payload — O(P * (g-1)) integer ops, the dominant client-side
secure-agg cost (this is what makes the MPC protocol O(n^2) per VG and why
VGs exist).

Kernel layout: payload tiled (rows, 128) uint32; grid over row blocks; per
block, a ``fori_loop`` over the g-1 peers generates the (ROW_BLOCK, 128)
mask tile from the pair seed + global element counter (counter mode — no
cross-block state) and accumulates it signed into the quantized payload.
Mask words never round-trip to HBM: HBM traffic is exactly read-q + write-y,
while compute is (g-1) KDF rounds per element — arithmetic intensity scales
with VG size, which is why this is a kernel and not jnp.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANES, ROW_BLOCK, global_index,
                                  interpret_mode, kdf_u32)


def _mask_apply_kernel(seeds_ref, q_ref, out_ref, *, n_pairs, base_offset):
    pid = pl.program_id(0)
    ctr = global_index(pid) + jnp.uint32(base_offset)

    def body(j, acc):
        k0 = seeds_ref[j, 0]
        k1 = seeds_ref[j, 1]
        sign_pos = seeds_ref[j, 2]  # 1 -> add mask, 0 -> subtract (mod 2^32)
        m = kdf_u32(k0, k1, ctr)
        return acc + jnp.where(sign_pos == jnp.uint32(1), m,
                               jnp.uint32(0) - m)

    out_ref[...] = jax.lax.fori_loop(0, n_pairs, body, q_ref[...])


def _mask_apply_batched_kernel(seeds_ref, q_ref, out_ref, *, n_pairs,
                               base_offset):
    # grid (clients, row blocks): axis 0 picks the client's seed rows, axis 1
    # the payload tile. The element counter depends only on the tile — every
    # client's mask stream is addressed from the same base offset, exactly
    # as in the serial per-client protocol.
    pid = pl.program_id(1)
    ctr = global_index(pid) + jnp.uint32(base_offset)

    def body(j, acc):
        k0 = seeds_ref[0, j, 0]
        k1 = seeds_ref[0, j, 1]
        sign_pos = seeds_ref[0, j, 2]
        m = kdf_u32(k0, k1, ctr)
        return acc + jnp.where(sign_pos == jnp.uint32(1), m,
                               jnp.uint32(0) - m)

    out_ref[0, :, :] = jax.lax.fori_loop(0, n_pairs, body, q_ref[0, :, :])


def mask_apply_batched_tiled(q_tiled, seeds_signs, base_offset=0, *,
                             interpret=None):
    """Whole-cohort fused mask expansion: one kernel launch for all clients.

    q_tiled: (n_clients, rows, 128) uint32; seeds_signs: (n_clients,
    n_pairs, 3) uint32 [k0, k1, sign_pos] per client. Returns masked
    payloads, same shape as ``q_tiled``. Same HBM traffic as n_clients
    serial launches (read-q + write-y; masks never round-trip) but a single
    dispatch with a (clients, row-blocks) grid — the batched hot path the
    vectorized privacy engine routes through when ``use_kernels=True``."""
    n_clients, rows, lanes = q_tiled.shape
    assert rows % ROW_BLOCK == 0 and lanes == LANES
    n_pairs = seeds_signs.shape[1]
    assert seeds_signs.shape == (n_clients, n_pairs, 3)
    interpret = interpret_mode() if interpret is None else interpret
    return pl.pallas_call(
        partial(_mask_apply_batched_kernel, n_pairs=n_pairs,
                base_offset=base_offset),
        grid=(n_clients, rows // ROW_BLOCK),
        in_specs=[
            pl.BlockSpec((1, n_pairs, 3), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, ROW_BLOCK, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, ROW_BLOCK, LANES), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q_tiled.shape, jnp.uint32),
        interpret=interpret,
    )(seeds_signs, q_tiled)


def mask_apply_tiled(q_tiled, seeds_signs, base_offset=0, *, interpret=None):
    """q_tiled: (rows, 128) uint32; seeds_signs: (n_pairs, 3) uint32
    [k0, k1, sign_pos]. Returns masked payload, same shape."""
    rows = q_tiled.shape[0]
    assert rows % ROW_BLOCK == 0 and q_tiled.shape[1] == LANES
    n_pairs = seeds_signs.shape[0]
    interpret = interpret_mode() if interpret is None else interpret
    return pl.pallas_call(
        partial(_mask_apply_kernel, n_pairs=n_pairs,
                base_offset=base_offset),
        grid=(rows // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((n_pairs, 3), lambda i: (0, 0)),   # seeds: replicated
            pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q_tiled.shape, jnp.uint32),
        interpret=interpret,
    )(seeds_signs, q_tiled)
