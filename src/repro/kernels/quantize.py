"""Pallas kernels: affine fixed-point quantize / dequantize (paper §4.1).

Elementwise, VPU-bound; tiled (ROW_BLOCK, 128). Matches
``repro.core.quantize`` bit-exactly (same f32 rounding sequence).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, ROW_BLOCK, interpret_mode


def _quantize_kernel(x_ref, out_ref, *, clip, bits):
    lv = jnp.float32((1 << bits) - 1)
    xf = jnp.clip(x_ref[...].astype(jnp.float32), -clip, clip)
    q = jnp.round((xf + clip) / (2.0 * clip) * lv)
    out_ref[...] = q.astype(jnp.uint32)


def _dequantize_kernel(q_ref, out_ref, *, clip, bits, n):
    # same op sequence as core.quantize.dequantize_sum (bit-exact)
    lv = jnp.float32((1 << bits) - 1)
    mean_code = q_ref[...].astype(jnp.float32) / jnp.float32(n)
    out_ref[...] = (mean_code / lv) * (2.0 * clip) - clip


def _elementwise_call(kernel, x, out_dtype, interpret):
    rows = x.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(rows // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, out_dtype),
        interpret=interpret,
    )(x)


def quantize_tiled(x_tiled, clip, bits, *, interpret=None):
    """x_tiled: (rows, 128) f32 -> (rows, 128) uint32 codes."""
    interpret = interpret_mode() if interpret is None else interpret
    return _elementwise_call(
        partial(_quantize_kernel, clip=float(clip), bits=int(bits)),
        x_tiled, jnp.uint32, interpret)


def dequantize_sum_tiled(q_tiled, n, clip, bits, *, interpret=None):
    """(rows,128) uint32 aggregate-sum codes -> f32 cohort-mean values."""
    interpret = interpret_mode() if interpret is None else interpret
    return _elementwise_call(
        partial(_dequantize_kernel, clip=float(clip), bits=int(bits),
                n=int(n)),
        q_tiled, jnp.float32, interpret)
