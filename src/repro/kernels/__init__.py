"""Pallas TPU kernels for the secure-aggregation hot path.

mask_gen    — fused pairwise-mask generation + application (the O(n^2) MPC cost)
quantize    — fixed-point quantize / dequantize for modular masking
secure_sum  — stage-1 wrapping uint32 reduction over the client axis
dp_noise    — fused DP clip-scale + in-kernel Gaussian noise

ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
Kernels run in interpret mode on CPU (this container) and compile for TPU.
EXAMPLE.md retained from the scaffold.
"""
