"""Pallas kernel: fused DP clip-scale + Gaussian noise (paper §4.2).

Given the precomputed clip factor (min(1, C/||u||), a scalar — the global
norm is a cheap separate reduction), this fuses the rescale and the Gaussian
noise draw into one pass over the update vector. Noise is generated in-kernel
from the counter KDF via Box–Muller, so (as with masks) random words never
touch HBM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANES, ROW_BLOCK, global_index,
                                  interpret_mode, kdf_u32)

TWO_PI = 6.2831853071795864


def _box_muller(k0, k1, ctr):
    """Two KDF words -> one standard normal (f32). Bit-matched in ref.py."""
    b1 = kdf_u32(k0, k1, ctr * jnp.uint32(2))
    b2 = kdf_u32(k0, k1, ctr * jnp.uint32(2) + jnp.uint32(1))
    # u1 in (0, 1]: (b1 + 1) / 2^32 ; u2 in [0, 1)
    u1 = (b1.astype(jnp.float32) + 1.0) * (1.0 / 4294967296.0)
    u2 = b2.astype(jnp.float32) * (1.0 / 4294967296.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(TWO_PI * u2)


def _dp_noise_kernel(scale_ref, seed_ref, x_ref, out_ref, *, sigma):
    pid = pl.program_id(0)
    ctr = global_index(pid)
    z = _box_muller(seed_ref[0, 0], seed_ref[0, 1], ctr)
    out_ref[...] = x_ref[...] * scale_ref[0, 0] + jnp.float32(sigma) * z


def dp_clip_noise_tiled(x_tiled, clip_factor, sigma, seed, *, interpret=None):
    """x_tiled (rows,128) f32; clip_factor scalar; seed (2,) uint32."""
    rows = x_tiled.shape[0]
    assert rows % ROW_BLOCK == 0 and x_tiled.shape[1] == LANES
    interpret = interpret_mode() if interpret is None else interpret
    scale = jnp.asarray(clip_factor, jnp.float32).reshape(1, 1)
    seed = jnp.asarray(seed, jnp.uint32).reshape(1, 2)
    return pl.pallas_call(
        partial(_dp_noise_kernel, sigma=float(sigma)),
        grid=(rows // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_tiled.shape, jnp.float32),
        interpret=interpret,
    )(scale, seed, x_tiled)
