"""Synthetic datasets.

``spam_dataset`` is the stand-in for SetFit/enron-spam (paper §5.1): a
two-class token-sequence classification problem where class-conditional
token distributions overlap partially — learnable but not trivial, so
federated accuracy curves behave like Fig. 11 (left). Offline container =
no HuggingFace Hub; the *experiment protocol* (100 equal splits, 20% of a
split per round, batch 8, AdamW 5e-4) is reproduced exactly in
``benchmarks/bench_spam.py``.

``lm_dataset`` provides next-token-prediction streams (a planted bigram
process, so the loss floor is below the unigram entropy) for the federated
LLM fine-tuning example and per-arch smoke tests.
"""
from __future__ import annotations

import numpy as np


def spam_dataset(n_samples=4000, seq_len=32, vocab_size=8192, seed=0,
                 signal_tokens=64, signal_rate=0.35):
    """-> dict(tokens (N,S) int32, label (N,) int32, mask (N,S) f32).

    Class 1 ("spam") draws ``signal_rate`` of its tokens from a small
    spam-vocabulary block; class 0 avoids it. Both share a common background
    distribution. Bayes accuracy ~1; random init ~0.5.
    """
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 2, n_samples).astype(np.int32)
    background = rng.zipf(1.5, size=(n_samples, seq_len))
    background = (background % (vocab_size - signal_tokens)
                  ) + signal_tokens
    spam_block = rng.randint(1, signal_tokens, size=(n_samples, seq_len))
    use_signal = (rng.rand(n_samples, seq_len) < signal_rate) \
        & (labels[:, None] == 1)
    tokens = np.where(use_signal, spam_block, background).astype(np.int32)
    lengths = rng.randint(seq_len // 2, seq_len + 1, n_samples)
    mask = (np.arange(seq_len)[None, :] < lengths[:, None]).astype(np.float32)
    tokens = tokens * mask.astype(np.int32)
    return {"tokens": tokens, "label": labels, "mask": mask}


def lm_dataset(n_tokens=200_000, vocab_size=512, seed=0, order=1):
    """Planted-bigram language stream -> (tokens,) int32."""
    rng = np.random.RandomState(seed)
    # sparse random bigram table: each token has ~8 likely successors
    succ = rng.randint(0, vocab_size, size=(vocab_size, 8))
    out = np.empty(n_tokens, np.int32)
    t = rng.randint(vocab_size)
    for i in range(n_tokens):
        out[i] = t
        if rng.rand() < 0.85:
            t = int(succ[t, rng.randint(8)])
        else:
            t = int(rng.randint(vocab_size))
    return out


def lm_batches(stream, batch_size, seq_len, seed=0):
    """Infinite iterator of {"tokens","targets","mask"} batches."""
    rng = np.random.RandomState(seed)
    n = len(stream) - seq_len - 1
    while True:
        starts = rng.randint(0, n, batch_size)
        toks = np.stack([stream[s:s + seq_len] for s in starts])
        tgts = np.stack([stream[s + 1:s + seq_len + 1] for s in starts])
        yield {"tokens": toks.astype(np.int32),
               "targets": tgts.astype(np.int32),
               "mask": np.ones_like(toks, np.float32)}
