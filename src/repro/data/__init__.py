from repro.data.federated import (ClientDataAccess, batches, dirichlet_splits,
                                  equal_splits, take)
from repro.data.synthetic import lm_batches, lm_dataset, spam_dataset

__all__ = ["ClientDataAccess", "batches", "dirichlet_splits", "equal_splits",
           "take", "lm_batches", "lm_dataset", "spam_dataset"]
