"""Federated partitioning: split a dataset across clients.

The paper's spam experiment uses 100 equal random splits with each client
picking a split at random per round (§5.1) — ``equal_splits`` +
``ClientDataAccess``. ``dirichlet_splits`` adds the standard non-IID
label-skew partitioner for heterogeneity studies.
"""
from __future__ import annotations

import numpy as np


def equal_splits(dataset: dict, n_splits: int, seed: int = 0):
    """Random permutation -> n equal splits (list of index arrays)."""
    n = len(next(iter(dataset.values())))
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    return np.array_split(perm, n_splits)


def dirichlet_splits(labels: np.ndarray, n_clients: int, alpha: float = 0.5,
                     seed: int = 0):
    """Label-skewed non-IID partition (Dirichlet over class proportions)."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    idx_by_class = {c: rng.permutation(np.where(labels == c)[0])
                    for c in classes}
    client_indices = [[] for _ in range(n_clients)]
    for c in classes:
        props = rng.dirichlet([alpha] * n_clients)
        counts = (props * len(idx_by_class[c])).astype(int)
        counts[-1] = len(idx_by_class[c]) - counts[:-1].sum()
        start = 0
        for i, cnt in enumerate(counts):
            client_indices[i].extend(idx_by_class[c][start:start + cnt])
            start += cnt
    return [np.asarray(sorted(ix)) for ix in client_indices]


def take(dataset: dict, indices) -> dict:
    return {k: v[indices] for k, v in dataset.items()}


class ClientDataAccess:
    """Paper §5.1 protocol: 'each client accesses one of the 100 splits at
    random, and uses 20% of the data in the split to update the model'."""

    def __init__(self, dataset: dict, n_splits: int = 100, frac: float = 0.2,
                 seed: int = 0):
        self.dataset = dataset
        self.splits = equal_splits(dataset, n_splits, seed)
        self.frac = frac
        self._rng = np.random.RandomState(seed + 1)

    def sample(self, client_seed: int) -> dict:
        rng = np.random.RandomState(client_seed)
        split = self.splits[rng.randint(len(self.splits))]
        k = max(1, int(len(split) * self.frac))
        picked = rng.choice(split, size=k, replace=False)
        return take(self.dataset, picked)


def batches(data: dict, batch_size: int, seed: int = 0, drop_last=False):
    """Single-epoch minibatch iterator over a dict dataset."""
    n = len(next(iter(data.values())))
    order = np.random.RandomState(seed).permutation(n)
    for start in range(0, n, batch_size):
        idx = order[start:start + batch_size]
        if drop_last and len(idx) < batch_size:
            return
        yield take(data, idx)
