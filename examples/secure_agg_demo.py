"""Secure-aggregation walkthrough (paper §4.1 / Fig. 2): shows the pairwise
masks, that single payloads are unreadable, that the VG modular sum cancels
masks bit-exactly, and the two-stage master aggregation — through both the
jnp reference path and the Pallas kernel path.

    PYTHONPATH=src python examples/secure_agg_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (SecureAggConfig, make_virtual_groups, quantize,
                        secure_aggregate_round)
from repro.core.masking import apply_mask, modular_sum
from repro.kernels import ops

rng = np.random.RandomState(0)
round_seed = jnp.asarray([2024, 7], jnp.uint32)

print("== 1. one virtual group, 4 clients, 8-element updates ==")
n, size = 4, 8
xs = [rng.uniform(-1, 1, size).astype(np.float32) for _ in range(n)]
qs = [quantize(jnp.asarray(x)) for x in xs]
print("client 0 update (f32):", np.round(xs[0], 3))
print("client 0 quantized   :", np.asarray(qs[0]))
y0 = apply_mask(qs[0], 0, n, round_seed)
print("client 0 MASKED      :", np.asarray(y0), "(unreadable by server)")

masked = jnp.stack([apply_mask(qs[i], i, n, round_seed) for i in range(n)])
plain = jnp.stack(qs)
print("sum(masked) == sum(plain):",
      bool(jnp.array_equal(modular_sum(masked), modular_sum(plain))))

print("\n== 2. kernel path (Pallas, interpret on CPU) gives identical bits ==")
yk = ops.mask_apply(qs[0], 0, n, round_seed)
print("kernel == reference:", bool(jnp.array_equal(yk, y0)))

print("\n== 3. two-stage aggregation over a 12-client cohort, VGs of 4 ==")
updates = {i: {"w": jnp.asarray(rng.uniform(-0.5, 0.5, (3, 4)),
                                jnp.float32)} for i in range(12)}
plan = make_virtual_groups(list(updates), vg_size=4, seed=1)
for g in plan.groups:
    print(f"  VG {g.vg_id}: members {g.members}")
agg = secure_aggregate_round(updates, plan, round_seed, SecureAggConfig())
true = np.mean([np.asarray(u["w"]) for u in updates.values()], axis=0)
print("max |secure_agg - true_mean| =",
      float(np.max(np.abs(np.asarray(agg["w"]) - true))),
      "(quantization resolution:", 2 / (2**20 - 1), ")")
