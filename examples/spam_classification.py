"""Paper §5.1 end-to-end: federated spam classification with the exact
experiment protocol — 32 clients/round from the AzureML-simulator-style
pool, 100 data splits @ 20% per round, batch 8, AdamW 5e-4, 10 iterations —
plus the DP variant (clip 0.5) with the RDP accountant's epsilon.

    PYTHONPATH=src python examples/spam_classification.py [--dp] [--rounds N]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import SpamWorld  # noqa: E402
from repro.core.dp import DPConfig  # noqa: E402
from repro.fl import ManagementService, TaskConfig  # noqa: E402
from repro.fl.simulator import (make_heterogeneous_clients,  # noqa: E402
                                run_sync_simulation)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dp", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients-per-round", type=int, default=32)
    args = ap.parse_args()

    world = SpamWorld()  # §5.1 protocol defaults
    dp = (DPConfig(mechanism="local", clip_norm=0.5, noise_multiplier=0.16)
          if args.dp else DPConfig())
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig("spam-561", "spam-app", "train",
                   clients_per_round=args.clients_per_round,
                   n_rounds=args.rounds, vg_size=8, dp=dp),
        world.model0)
    clients = make_heterogeneous_clients(args.clients_per_round * 2,
                                         world.make_trainer)
    res = run_sync_simulation(svc, tid, clients,
                              eval_fn=world.test_accuracy)
    for i, h in enumerate(res.metrics_history):
        print(f"iteration {i + 1:2d}: accuracy={h['eval_accuracy']:.3f} "
              f"duration={res.round_durations[i]:.2f}s")
    if args.dp:
        print(f"privacy: epsilon={svc.epsilon(tid):.2f} at "
              f"delta={dp.delta}")


if __name__ == "__main__":
    main()
