"""Quickstart — the paper's Fig. 4 Jupyter demo, console edition: 15 clients
federatedly training the spam classifier through the Florida SDK, with
per-client status panes printed each round.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import SpamWorld  # noqa: E402
from repro.fl import (ManagementService, SimClient, TaskConfig,  # noqa: E402
                      run_sync_simulation)

N_CLIENTS = 15
ROUNDS = 5


def pane_line(cid, status, extra=""):
    return f"| {cid:<12} {status:<10} {extra:<24}|"


def main():
    world = SpamWorld(n_train=3000, n_splits=20, frac=0.5)
    svc = ManagementService()
    task_id = svc.create_task(
        TaskConfig(task_name="spam-quickstart",
                   app_name="python-app",          # paper Fig. 3 names
                   workflow_name="python-workflow",
                   clients_per_round=10, n_rounds=ROUNDS, vg_size=5),
        world.model0)
    clients = {f"client-{i:02d}": SimClient(f"client-{i:02d}",
                                            world.make_trainer(i))
               for i in range(N_CLIENTS)}

    print("+" + "-" * 49 + "+")
    print(pane_line("client", "status", "".ljust(0)))
    print("+" + "-" * 49 + "+")

    def eval_and_report(model):
        acc = world.test_accuracy(model)
        task = svc.get_task(task_id)
        statuses = svc.selection.statuses(task)
        for cid in sorted(clients):
            st = statuses.get(cid, "idle")
            print(pane_line(cid, st, f"round={task.round_idx} "
                                     f"acc={acc:.3f}"))
        print("+" + "-" * 49 + "+")
        return acc

    res = run_sync_simulation(svc, task_id, clients,
                              eval_fn=eval_and_report)
    accs = [h["eval_accuracy"] for h in res.metrics_history]
    print(f"\nfinal accuracy after {ROUNDS} rounds: {accs[-1]:.3f} "
          f"(from {accs[0]:.3f})")
    print(f"simulated wall time: {res.total_time:.1f}s; "
          f"iteration durations: {[round(d, 2) for d in res.round_durations]}")


if __name__ == "__main__":
    main()
