"""Cross-silo federated LLM fine-tuning — the production fl_round from
``repro.launch.fl_step`` (quantize -> mask -> two-stage secure aggregation
-> server AdamW) running REAL steps on a reduced assigned architecture.

This is the on-pod path the dry-run lowers at full scale; here it trains a
2-layer yi-9b-family model on the synthetic LM stream and shows the loss
falling with the full secure-aggregation pipeline in the loop.

    PYTHONPATH=src python examples/federated_llm_finetune.py \
        [--arch yi-9b] [--steps 25]
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=25)
    args = ap.parse_args()
    loss = train_mod.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--global-batch", "8", "--seq-len", "64",
        "--server-lr", "3e-3",
    ])
    print(f"[example] final loss {loss:.3f} — secure FL round trains "
          f"an assigned architecture end to end")


if __name__ == "__main__":
    main()
