"""Clustered FL (beyond-paper; the paper's §7 lists it as future work):
two client populations with OPPOSITE label conventions — a single global
model stalls near chance, while ClusteredFL detects the divergence from
per-VG update similarity, splits, and both clusters learn.

    PYTHONPATH=src python examples/clustered_fl.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import SpamWorld  # noqa: E402
from repro.core.clustered import ClusteredFL  # noqa: E402
from repro.core.strategies import FedAvg  # noqa: E402

ROUNDS = 6
CLIENTS_PER_POP = 4


def main():
    world = SpamWorld(vocab=1024, d_model=64, n_train=3000, n_splits=10,
                      frac=1.0)
    flipped = dict(world.train)
    flipped["label"] = 1 - flipped["label"]
    flipped_test = dict(world.test)
    flipped_test["label"] = 1 - flipped_test["label"]

    def trainer_for(i, flip):
        base = world.make_trainer(i)
        if not flip:
            return base
        saved = world.access.dataset
        def trainer(blob, rnd):
            world.access.dataset = flipped
            try:
                return base(blob, rnd)
            finally:
                world.access.dataset = saved
        return trainer

    from repro.checkpoint import serialize_pytree
    cfl = ClusteredFL(base=FedAvg(server_lr=1.0), split_threshold=0.2,
                      min_rounds_before_split=1, max_clusters=2)
    state = cfl.init(world.model0)
    cids = ([("normal", i, False) for i in range(CLIENTS_PER_POP)]
            + [("flipped", i, True) for i in range(CLIENTS_PER_POP)])

    def acc(model, flip):
        batch = {k: jnp.asarray(v) for k, v in
                 (flipped_test if flip else world.test).items()}
        return float(world._acc(model, batch))

    for rnd in range(ROUNDS):
        # group clients by their current cluster, run per-cluster rounds
        by_cluster = {}
        for kind, i, flip in cids:
            cl = cfl.cluster_of(state, f"{kind}-{i}")
            by_cluster.setdefault(cl, []).append((kind, i, flip))
        for cl, members in sorted(by_cluster.items()):
            blob = serialize_pytree(state["clusters"][cl]["model"])
            # VG = pair of clients (secure agg boundary = cluster)
            vg_means, vg_weights, vg_lists = [], [], []
            for g in range(0, len(members), 2):
                group = members[g:g + 2]
                ups = []
                for kind, i, flip in group:
                    u, n, _ = trainer_for(i, flip)(blob, rnd)
                    ups.append(u)
                vg_means.append(jax.tree.map(
                    lambda *xs: np.mean(xs, axis=0), *ups))
                vg_weights.append(float(len(group)))
                vg_lists.append([f"{k}-{i}" for k, i, _ in group])
            state, split = cfl.round(state, cl, vg_means, vg_weights,
                                     vg_lists)
            if split:
                print(f"round {rnd}: cluster {cl} SPLIT -> "
                      f"{len(state['clusters'])} clusters")
        accs = [
            (acc(state["clusters"][cfl.cluster_of(state, "normal-0")]["model"],
                 False),
             acc(state["clusters"][cfl.cluster_of(state, "flipped-0")]["model"],
                 True))]
        print(f"round {rnd}: acc(normal pop)={accs[0][0]:.3f} "
              f"acc(flipped pop)={accs[0][1]:.3f} "
              f"clusters={len(state['clusters'])}")


if __name__ == "__main__":
    main()
