"""Sync vs async FL (paper §4.3 + Fig. 11 center): with heterogeneous
clients and stragglers, async buffered aggregation (Papaya-style FedBuff)
cuts per-iteration wall time because no round waits for the slowest device.

    PYTHONPATH=src python examples/async_vs_sync.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import SpamWorld  # noqa: E402
from repro.fl import ManagementService, TaskConfig  # noqa: E402
from repro.fl.simulator import (make_heterogeneous_clients,  # noqa: E402
                                run_async_simulation, run_sync_simulation)

ROUNDS, COHORT = 6, 16


def main():
    world = SpamWorld(n_train=4000)

    svc = ManagementService()
    tid = svc.create_task(TaskConfig("sync", "app", "wf",
                                     clients_per_round=COHORT,
                                     n_rounds=ROUNDS, vg_size=8),
                          world.model0)
    sync = run_sync_simulation(
        svc, tid, make_heterogeneous_clients(COHORT, world.make_trainer,
                                             straggler_frac=0.25),
        eval_fn=world.test_accuracy)

    svc = ManagementService()
    tid = svc.create_task(TaskConfig("async", "app", "wf",
                                     clients_per_round=COHORT,
                                     n_rounds=ROUNDS, mode="async",
                                     buffer_size=COHORT), world.model0)
    asyn = run_async_simulation(
        svc, tid, make_heterogeneous_clients(COHORT, world.make_trainer,
                                             straggler_frac=0.25),
        eval_fn=world.test_accuracy)

    print(f"{'':>10} {'mean iter (s)':>14} {'final acc':>10}")
    print(f"{'sync':>10} {np.mean(sync.round_durations):>14.2f} "
          f"{sync.metrics_history[-1]['eval_accuracy']:>10.3f}")
    print(f"{'async':>10} {np.mean(asyn.round_durations):>14.2f} "
          f"{asyn.metrics_history[-1]['eval_accuracy']:>10.3f}")
    print(f"\nasync speedup: "
          f"{np.mean(sync.round_durations) / np.mean(asyn.round_durations):.2f}x"
          f" (stragglers contribute stale updates instead of blocking)")


if __name__ == "__main__":
    main()
