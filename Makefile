# Tier-1 verification (ROADMAP.md): full test suite, dev deps included so
# the hypothesis property tests actually run (they importorskip otherwise).
PY ?= python

.PHONY: verify test deps bench-cohort

deps:
	$(PY) -m pip install -r requirements-dev.txt

verify: deps test

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-cohort:
	PYTHONPATH=src $(PY) -m benchmarks.bench_cohort
