# Tier-1 verification (ROADMAP.md): full test suite, dev deps included so
# the hypothesis property tests actually run (they importorskip otherwise),
# plus a tiny-scale secure-agg bench smoke so the vectorized privacy
# pipeline (serial/vectorized/kernels) is exercised end to end.
PY ?= python

.PHONY: verify test deps bench-cohort bench-secureagg-smoke

deps:
	$(PY) -m pip install -r requirements-dev.txt

verify: deps test bench-secureagg-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-cohort:
	PYTHONPATH=src $(PY) -m benchmarks.bench_cohort

bench-secureagg-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_secureagg --quick
