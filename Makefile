# Tier-1 verification (ROADMAP.md): full test suite, dev deps included so
# the hypothesis property tests actually run (they importorskip otherwise),
# plus tiny-scale bench smokes so the vectorized privacy pipeline
# (serial/vectorized/kernels), the fused async FedBuff path
# (batched DP + device buffer + one-dispatch drain), and the churn path
# (dropout recovery) are exercised end to end.
PY ?= python

.PHONY: verify test test-cov deps docs-check bench bench-cohort \
	bench-secureagg-smoke bench-async-smoke bench-dropout-smoke \
	bench-multitask-smoke bench-fleet-smoke bench-compression-smoke \
	bench-trace-smoke

# Ratcheted line-coverage floor for the privacy-critical core
# (src/repro/core/). Raise it as coverage grows; never lower it.
COV_FLOOR ?= 80

deps:
	$(PY) -m pip install -r requirements-dev.txt

verify: deps test-cov docs-check bench-secureagg-smoke bench-async-smoke \
	bench-dropout-smoke bench-multitask-smoke bench-fleet-smoke \
	bench-compression-smoke bench-trace-smoke

# the full suite: every figure/claim bench, results persisted to
# benchmarks/results/BENCH_<suite>.json (host info + git rev included)
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

docs-check:
	$(PY) tools/check_docs.py

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# the suite under coverage, gated on the core/ floor; degrades to the
# plain run when pytest-cov isn't installed (`make deps` installs it)
test-cov:
	@if $(PY) -c "import importlib.util, sys; \
	    sys.exit(0 if importlib.util.find_spec('pytest_cov') else 1)"; then \
	  PYTHONPATH=src $(PY) -m pytest -x -q --cov=repro.core \
	    --cov-report=term-missing:skip-covered \
	    --cov-fail-under=$(COV_FLOOR); \
	else \
	  echo "pytest-cov not installed; running without coverage gate"; \
	  PYTHONPATH=src $(PY) -m pytest -x -q; \
	fi

bench-cohort:
	PYTHONPATH=src $(PY) -m benchmarks.bench_cohort

bench-secureagg-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_secureagg --quick

bench-async-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_async --quick

bench-dropout-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dropout --quick

bench-multitask-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_multitask --quick

bench-fleet-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_fleet --quick

bench-compression-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_compression --quick

bench-trace-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_trace --quick
