# Tier-1 verification (ROADMAP.md): full test suite, dev deps included so
# the hypothesis property tests actually run (they importorskip otherwise),
# plus tiny-scale bench smokes so the vectorized privacy pipeline
# (serial/vectorized/kernels), the fused async FedBuff path
# (batched DP + device buffer + one-dispatch drain), and the churn path
# (dropout recovery) are exercised end to end.
PY ?= python

.PHONY: verify test deps docs-check bench bench-cohort \
	bench-secureagg-smoke bench-async-smoke bench-dropout-smoke \
	bench-multitask-smoke bench-fleet-smoke

deps:
	$(PY) -m pip install -r requirements-dev.txt

verify: deps test docs-check bench-secureagg-smoke bench-async-smoke \
	bench-dropout-smoke bench-multitask-smoke bench-fleet-smoke

# the full suite: every figure/claim bench, results persisted to
# benchmarks/results/BENCH_<suite>.json (host info + git rev included)
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

docs-check:
	$(PY) tools/check_docs.py

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-cohort:
	PYTHONPATH=src $(PY) -m benchmarks.bench_cohort

bench-secureagg-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_secureagg --quick

bench-async-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_async --quick

bench-dropout-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dropout --quick

bench-multitask-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_multitask --quick

bench-fleet-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_fleet --quick
