"""The production fl_round (launch layer) on the host mesh: the secure path
must match the insecure (plain-mean) path to quantization resolution, and
training must reduce loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_reduced_config
from repro.configs.shapes import InputShape
from repro.launch.fl_step import (leaf_net_mask, leaf_offsets,
                                  make_fl_train_step)
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim import adamw


def _setup(arch="yi-9b", vocab=512):
    cfg = get_reduced_config(arch)
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw().init(params)
    return cfg, mesh, params, opt_state


def _batch(cfg, n_silos, b, s, seed=0):
    rng = np.random.RandomState(seed)
    return {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size,
                                          (n_silos, b, s)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size,
                                           (n_silos, b, s)), jnp.int32),
        "mask": jnp.ones((n_silos, b, s), jnp.float32),
    }


def test_secure_matches_insecure_within_quantization():
    cfg, mesh, params, opt_state = _setup()
    with compat.set_mesh(mesh):
        seed = jnp.asarray([3, 4], jnp.uint32)
        batch = _batch(cfg, 1, 4, 16)
        sec, _ = make_fl_train_step(cfg, mesh, secure=True, bits=24,
                                    clip=0.5, microbatches=1)
        insec, _ = make_fl_train_step(cfg, mesh, secure=False,
                                      microbatches=1)
        p_s, _, loss_s = jax.jit(sec)(params, opt_state, batch, seed)
        p_i, _, loss_i = jax.jit(insec)(params, opt_state, batch, seed)
    np.testing.assert_allclose(float(loss_s), float(loss_i), rtol=1e-5)
    # server update from secure-agg'd grads ~= update from exact grads
    for a, b in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_i)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3)


def test_fl_round_reduces_loss():
    cfg, mesh, params, opt_state = _setup()
    with compat.set_mesh(mesh):
        step, meta = make_fl_train_step(cfg, mesh, secure=True,
                                        microbatches=1, server_lr=5e-3)
        step = jax.jit(step)
        batch = _batch(cfg, 1, 4, 16)
        losses = []
        for i in range(8):
            seed = jnp.asarray([i, i + 1], jnp.uint32)
            params, opt_state, loss = step(params, opt_state, batch, seed)
            losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_microbatched_grad_matches_single():
    cfg, mesh, params, opt_state = _setup()
    with compat.set_mesh(mesh):
        batch = _batch(cfg, 1, 4, 16)
        seed = jnp.asarray([1, 2], jnp.uint32)
        one, _ = make_fl_train_step(cfg, mesh, secure=False, microbatches=1)
        four, _ = make_fl_train_step(cfg, mesh, secure=False, microbatches=4)
        p1, _, l1 = jax.jit(one)(params, opt_state, batch, seed)
        p4, _, l4 = jax.jit(four)(params, opt_state, batch, seed)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_leaf_masks_cancel_within_vg():
    """sum over a VG of per-leaf net masks == 0 (mod 2^32), any shape."""
    seed = jnp.asarray([7, 8], jnp.uint32)
    for shape, offset in [((8,), 0), ((3, 5), 1000), ((2, 4, 6), 4_294_967_000)]:
        g = 4
        total = jnp.zeros(shape, jnp.uint32)
        for i in range(g):
            total = total + leaf_net_mask(jnp.uint32(i), jnp.uint32(0), g,
                                          seed, shape, offset)
        assert not total.any(), (shape, offset)


def test_leaf_offsets_disjoint():
    struct = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros(7),
                                            "d": jnp.zeros((2, 2))}}
    offs = leaf_offsets(struct)
    flat = sorted(jax.tree.leaves(offs))
    assert flat == [0, 12, 19]


def test_packed_aggregation_matches_unpacked():
    """Beyond-paper packed modular aggregation (2x13-bit per uint32) must be
    bit-identical to the unpacked path at the same bits."""
    cfg, mesh, params, opt_state = _setup()
    with compat.set_mesh(mesh):
        batch = _batch(cfg, 1, 4, 16)
        seed = jnp.asarray([3, 4], jnp.uint32)
        plain, _ = make_fl_train_step(cfg, mesh, secure=True, bits=13,
                                      microbatches=1)
        packed, _ = make_fl_train_step(cfg, mesh, secure=True, packed=True,
                                       microbatches=1)
        p1, _, l1 = jax.jit(plain)(params, opt_state, batch, seed)
        p2, _, l2 = jax.jit(packed)(params, opt_state, batch, seed)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack2_roundtrip():
    from repro.core.quantize import pack2, unpack2_sum
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randint(0, 2**13, (3, 8), dtype=np.uint32))
    packed = pack2(q)
    assert packed.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(unpack2_sum(packed)),
                                  np.asarray(q))


def test_hierarchical_master_combine_matches_reference():
    """Stage 2 is the shared limb-state tree: any shard count of the VG
    axis is bit-identical, and the value equals the plain mean of
    dequantized VG means to f32 resolution."""
    from repro.core.quantize import dequantize_sum
    from repro.launch.fl_step import hierarchical_master_combine
    rng = np.random.RandomState(2)
    n_vgs, g, bits, clip = 12, 4, 18, 0.05
    interim = jnp.asarray(
        rng.randint(0, g * ((1 << bits) - 1), (n_vgs, 3, 5),
                    dtype=np.int64).astype(np.uint32))
    ref = hierarchical_master_combine(interim, n_vgs * g, clip, bits)
    for shards in [2, 3, 5, 6, 7, 12]:   # incl. non-dividing (zero-pad)
        out = hierarchical_master_combine(interim, n_vgs * g, clip, bits,
                                          n_shards=shards)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    naive = np.asarray(dequantize_sum(interim, g, clip, bits),
                       np.float32).mean(axis=0)
    np.testing.assert_allclose(np.asarray(ref), naive, atol=1e-6)


def test_hierarchical_combine_shard_map_pod_route():
    """The per_pod route: per-pod limb states under compat.shard_map with
    a uint32 psum merge — same numbers as the unsharded form."""
    from repro.launch.fl_step import hierarchical_master_combine
    mesh = compat.make_mesh((1, 1, 1), ("pod", "data", "model"))
    rng = np.random.RandomState(3)
    interim = jnp.asarray(
        rng.randint(0, 1 << 22, (8, 6), dtype=np.int64).astype(np.uint32))
    plain = hierarchical_master_combine(interim, 32, 0.05, 18)
    podded = hierarchical_master_combine(interim, 32, 0.05, 18,
                                         pod_axis="pod", mesh=mesh)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(podded),
                               rtol=1e-6, atol=1e-7)


def test_stage2_route_reported_dividing_and_not():
    """``meta["stage2_route"]`` is the build-time record of which stage-2
    lowering won. Dividing pod/VG axes under per_pod -> the explicit
    shard_map route; a pod count that does NOT divide n_vgs (or a
    non-per_pod scheme) -> the zero-padded GSPMD fallback. The route is a
    pure function of (cfg, mesh.shape), so the non-dividing case runs
    in-process against a shape stub — no multi-device mesh needed."""
    import logging
    import types

    cfg = get_reduced_config("deepseek-67b")
    assert cfg.fl_scheme == "per_pod"
    mesh = compat.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with compat.set_mesh(mesh):
        _, meta = make_fl_train_step(cfg, mesh, microbatches=1)
    assert meta["stage2_route"] == "shard_map_pod"
    assert meta["stage2_pod_axis"] == "pod"

    # 3 pods, n_vgs = 1 -> 1 % 3 != 0: the shard_map route must be
    # demoted to the bit-identical zero-padded form, and say so
    fake = types.SimpleNamespace(shape={"pod": 3, "data": 1, "model": 1})
    logger = logging.getLogger("repro.launch.fl_step")
    records = []
    handler = logging.Handler()
    handler.emit = records.append
    logger.addHandler(handler)
    old_level = logger.level
    logger.setLevel(logging.INFO)
    try:
        _, meta = make_fl_train_step(cfg, fake, microbatches=1)
    finally:
        logger.setLevel(old_level)
        logger.removeHandler(handler)
    assert meta["stage2_route"] == "zero_padded_shards"
    assert meta["stage2_pod_axis"] is None
    assert any("zero_padded_shards" in r.getMessage() for r in records)

    # non-per_pod scheme on the same mesh: fallback route too
    cfg_silo = get_reduced_config("yi-9b")
    assert cfg_silo.fl_scheme != "per_pod"
    with compat.set_mesh(mesh):
        _, meta = make_fl_train_step(cfg_silo, mesh, microbatches=1)
    assert meta["stage2_route"] == "zero_padded_shards"


def test_per_pod_round_uses_shard_map_combine():
    """End-to-end per_pod fl_round on a pod mesh: the stage-2 combine runs
    under shard_map over the pod axis and the round still trains."""
    cfg = get_reduced_config("deepseek-67b")
    assert cfg.fl_scheme == "per_pod"
    mesh = compat.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with compat.set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw().init(params)
        step, meta = make_fl_train_step(cfg, mesh, secure=True,
                                        microbatches=1, server_lr=5e-3)
        assert meta["stage2_pod_axis"] == "pod"
        assert meta["stage2_shards"] == 1
        assert meta["stage2_route"] == "shard_map_pod"
        batch = _batch(cfg, meta["n_silos"], 4, 16)
        step = jax.jit(step)
        losses = []
        for i in range(4):
            seed = jnp.asarray([i, i + 1], jnp.uint32)
            params, opt_state, loss = step(params, opt_state, batch, seed)
            losses.append(float(loss))
    assert losses[-1] < losses[0], losses
