"""Differential privacy: clipping, mechanism, and the RDP accountant."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DPConfig, RdpAccountant, compute_rdp, get_privacy_spent
from repro.core.dp import (add_gaussian_noise, clip_by_global_norm, global_dp,
                           local_dp)


def test_clip_by_global_norm():
    u = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, norm = clip_by_global_norm(u, 1.0)
    flat = jnp.concatenate([clipped["a"], clipped["b"]])
    np.testing.assert_allclose(float(jnp.linalg.norm(flat)), 1.0, rtol=1e-5)
    # below-threshold updates unchanged
    small = {"a": jnp.ones((4,)) * 0.01}
    c2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01)


def test_local_dp_noise_scale():
    cfg = DPConfig(mechanism="local", clip_norm=0.5, noise_multiplier=2.0)
    u = {"w": jnp.zeros((100_000,))}
    out = local_dp(u, cfg, jax.random.PRNGKey(0))
    assert abs(float(jnp.std(out["w"])) - 1.0) < 0.02  # z * clip = 1.0


def test_global_dp_sensitivity_scaling():
    cfg = DPConfig(mechanism="global", clip_norm=1.0, noise_multiplier=1.0)
    u = {"w": jnp.zeros((100_000,))}
    out = global_dp(u, cfg, n_clients=10, key=jax.random.PRNGKey(0))
    assert abs(float(jnp.std(out["w"])) - 0.1) < 0.01


def test_rdp_full_batch_matches_closed_form():
    """q=1: RDP(alpha) = alpha / (2 z^2) exactly."""
    z = 1.3
    orders = (2, 4, 8)
    rdp = compute_rdp(1.0, z, steps=1, orders=orders)
    for a, r in zip(orders, rdp):
        np.testing.assert_allclose(r, a / (2 * z * z), rtol=1e-9)


def test_accountant_monotone_and_subsampling_helps():
    eps_full, _ = get_privacy_spent(compute_rdp(1.0, 1.0, 10), 1e-5)
    eps_sub, _ = get_privacy_spent(compute_rdp(0.1, 1.0, 10), 1e-5)
    assert eps_sub < eps_full
    eps_5, _ = get_privacy_spent(compute_rdp(0.1, 1.0, 5), 1e-5)
    assert eps_5 < eps_sub


@settings(deadline=None, max_examples=20)
@given(q=st.floats(0.01, 1.0), z=st.floats(0.3, 5.0),
       steps=st.integers(1, 50))
def test_epsilon_positive_finite(q, z, steps):
    eps, order = get_privacy_spent(compute_rdp(q, z, steps), 1e-5)
    assert eps > 0 and math.isfinite(eps) and order is not None


def test_accountant_tracks_rounds():
    acc = RdpAccountant(DPConfig(mechanism="local", noise_multiplier=1.0),
                        sample_rate=0.32)
    acc.step(5)
    e5 = acc.epsilon()
    acc.step(5)
    assert acc.epsilon() > e5
