"""min_survivors_per_vg x 4-limb stage 2 x dropout recovery (ISSUE 9
satellite): partial voiding (some groups below threshold, some healthy)
is bit-identical between the serial survivor loop and the vectorized
recovery path — with ``SecureAggConfig(limbs=4)`` carrying the extra
headroom lane — and a full refusal VOIDS the service round WITHOUT
consuming the round index, on both the serial and vectorized paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import _secure_mean_survivors
from repro.core.secure_agg import AggregationRefused
from repro.core.virtual_groups import make_virtual_groups
from repro.fl.auth import AttestationAuthority
from repro.fl.server import ManagementService
from repro.fl.task import TaskConfig
from repro.core.secure_agg import SecureAggConfig


def _round_inputs(n=12, size=25, seed=4):
    rng = np.random.RandomState(seed)
    cids = [f"c{i:03d}" for i in range(n)]
    flat = jnp.asarray(rng.uniform(-1, 1, (n, size)), jnp.float32)
    return cids, flat


@pytest.mark.parametrize("limbs", [3, 4])
@pytest.mark.parametrize("mech", ["off", "local"])
def test_partial_voiding_parity_serial_vs_vectorized(limbs, mech):
    """Kill one member of one VG (group survives, recovery runs) and all
    but one of another (group voided, its mass excluded, the divisor
    shrinks): serial and vectorized agree bitwise at 3 AND 4 limbs."""
    cids, flat = _round_inputs()
    plan = make_virtual_groups(cids, 4, seed=0)     # 3 groups of 4
    rs = jnp.asarray([5, 13], jnp.uint32)
    key = jax.random.PRNGKey(3)
    scfg = sa.SecureAggConfig(limbs=limbs, min_survivors_per_vg=2)
    dcfg = dp_mod.DPConfig(
        mechanism=mech, clip_norm=0.5,
        noise_multiplier=0.6 if mech != "off" else 0.0)
    groups = [list(g.members) for g in plan.groups]
    dead = set(groups[0][:1] + groups[1][:3])       # recover vs void
    alive = np.asarray([c not in dead for c in cids], bool)

    stats: dict = {}
    vect = pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg,
                             dp_cfg=dcfg, key=key, alive=alive,
                             stats=stats)
    assert stats["n_voided_groups"] == 1
    fold_of = {cid: j for j, cid in enumerate(cids)}
    survivors = {c: flat[j] for j, c in enumerate(cids) if alive[j]}
    serial = _secure_mean_survivors(survivors, plan, rs, key, scfg, dcfg,
                                    fold_of)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))


def test_limbs4_clean_round_matches_limbs3():
    """The 4th lane is pure headroom: on a cohort where 3 lanes are exact
    the extra limb must not change a single bit of the result."""
    cids, flat = _round_inputs(n=8)
    plan = make_virtual_groups(cids, 4, seed=1)
    rs = jnp.asarray([7, 2], jnp.uint32)
    key = jax.random.PRNGKey(0)
    l3 = pe.aggregate_flat(flat, plan, cids, rs,
                           secure_cfg=sa.SecureAggConfig(limbs=3), key=key)
    l4 = pe.aggregate_flat(flat, plan, cids, rs,
                           secure_cfg=sa.SecureAggConfig(limbs=4), key=key)
    np.testing.assert_array_equal(np.asarray(l3), np.asarray(l4))


def test_total_refusal_raises_on_both_paths():
    """Every group below min_survivors_per_vg -> AggregationRefused from
    BOTH the vectorized recovery and the serial survivor loop."""
    cids, flat = _round_inputs(n=8)
    plan = make_virtual_groups(cids, 4, seed=2)
    rs = jnp.asarray([1, 1], jnp.uint32)
    key = jax.random.PRNGKey(1)
    scfg = sa.SecureAggConfig(limbs=4, min_survivors_per_vg=2)
    groups = [list(g.members) for g in plan.groups]
    dead = set(groups[0][1:]) | set(groups[1][1:])  # 1 survivor per VG
    alive = np.asarray([c not in dead for c in cids], bool)
    with pytest.raises(AggregationRefused):
        pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg, key=key,
                          alive=alive)
    fold_of = {cid: j for j, cid in enumerate(cids)}
    survivors = {c: flat[j] for j, c in enumerate(cids) if alive[j]}
    with pytest.raises(AggregationRefused):
        _secure_mean_survivors(survivors, plan, rs, key, scfg,
                               dp_mod.DPConfig(), fold_of)


def _refusal_service_round(vectorized):
    """Drive a real service round into total refusal; return (record,
    metrics store rows)."""
    svc = ManagementService(seed=0)
    cfg = TaskConfig(
        "t", "a", "w", clients_per_round=8, n_rounds=4, vg_size=4,
        secure_agg=SecureAggConfig(vectorized=vectorized, limbs=4,
                                   min_survivors_per_vg=2))
    model = {"w": jnp.zeros((6, 4), jnp.float32)}
    tid = svc.create_task(cfg, model)
    auth = AttestationAuthority()
    for i in range(8):
        assert svc.register_client(
            tid, f"c{i}", {"os": "linux", "n_samples": 10, "battery": 0.9},
            auth.issue(f"c{i}"))
    round_idx, cohort = svc.begin_round(tid)
    plan = make_virtual_groups(sorted(cohort), 4, seed=round_idx)
    groups = [list(g.members) for g in plan.groups]
    dead = set(groups[0][1:]) | set(groups[1][1:])
    rng = np.random.default_rng(0)
    for cid in sorted(cohort):
        if cid in dead:
            svc.report_dropout(tid, cid)
    closed = False
    for cid in sorted(cohort):
        if cid in dead:
            continue
        closed |= svc.submit_update(
            tid, cid, {"w": jnp.asarray(rng.normal(size=(6, 4)),
                                        jnp.float32)}, n_samples=10)
    rec = svc.get_task(tid)
    voided = svc.metrics.series(tid, "round_voided")
    return closed, round_idx, rec, voided, model


@pytest.mark.parametrize("vectorized", [True, False])
def test_refusal_voids_round_without_consuming_index(vectorized):
    """ISSUE 9 acceptance: a refused aggregate VOIDS the round — model
    untouched, round index NOT consumed (the next begin_round re-selects
    the same index), voiding telemetry logged — identically on the
    serial and vectorized paths."""
    closed, round_idx, rec, voided, model0 = _refusal_service_round(
        vectorized)
    assert closed                       # the round did close (voided)
    assert rec.round_idx == round_idx   # ... but the index was not spent
    np.testing.assert_array_equal(np.asarray(rec.model["w"]),
                                  np.asarray(model0["w"]))
    assert rec.history == []            # no aggregated round recorded
    assert voided, "round_voided telemetry missing"
