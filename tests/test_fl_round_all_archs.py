"""One full secure FL round (quantize -> mask -> two-stage agg -> server
AdamW) for EVERY assigned architecture (reduced), on the host mesh —
finite loss, finite+changed params."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import ASSIGNED, get_reduced_config
from repro.launch.fl_step import make_fl_train_step
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim import adamw


def _silo_batch(cfg, n_silos=1, b=2, s=32, seed=0):
    rng = np.random.RandomState(seed)

    def toks(length):
        return jnp.asarray(rng.randint(0, cfg.vocab_size,
                                       (n_silos, b, length)), jnp.int32)

    if cfg.encoder_decoder:
        sd = 16
        return {"frames": jnp.asarray(
                    rng.randn(n_silos, b, s, cfg.d_model) * 0.02,
                    jnp.float32),
                "tokens": toks(sd), "targets": toks(sd),
                "mask": jnp.ones((n_silos, b, sd), jnp.float32)}
    if cfg.frontend == "vision_stub":
        st = s - cfg.num_patch_tokens
        return {"patches": jnp.asarray(
                    rng.randn(n_silos, b, cfg.num_patch_tokens, cfg.d_model)
                    * 0.02, jnp.float32),
                "tokens": toks(st), "targets": toks(st),
                "mask": jnp.ones((n_silos, b, st), jnp.float32)}
    return {"tokens": toks(s), "targets": toks(s),
            "mask": jnp.ones((n_silos, b, s), jnp.float32)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_secure_fl_round(arch):
    cfg = get_reduced_config(arch)
    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw().init(params)
        step, meta = make_fl_train_step(cfg, mesh, secure=True,
                                        microbatches=1, server_lr=1e-2)
        batch = _silo_batch(cfg, n_silos=meta["n_silos"])
        seed = jnp.asarray([5, 6], jnp.uint32)
        new_params, _, loss = jax.jit(step)(params, opt_state, batch, seed)
    assert jnp.isfinite(loss), arch
    changed = False
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert jnp.all(jnp.isfinite(b)), arch
        changed |= not jnp.array_equal(a, b)
    assert changed, arch
