"""Aggregation strategies: FedAvg/FedAvgM/FedProx/DGA/FedBuff."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import (DGA, FedAvg, FedBuff, make_strategy,
                                   weighted_mean)
from repro.optim import proximal_sgd


def test_weighted_mean():
    ups = [{"w": jnp.ones(3) * 1.0}, {"w": jnp.ones(3) * 3.0}]
    out = weighted_mean(ups, [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)


def test_fedavg_apply_and_momentum():
    s = FedAvg(server_lr=0.5)
    params = {"w": jnp.zeros(2)}
    st = s.init_state(params)
    p1, st = s.apply(params, st, {"w": jnp.ones(2)})
    np.testing.assert_allclose(np.asarray(p1["w"]), 0.5)

    sm = FedAvg(server_lr=1.0, momentum=0.9)
    st = sm.init_state(params)
    p, st = sm.apply(params, st, {"w": jnp.ones(2)})
    p, st = sm.apply(p, st, {"w": jnp.ones(2)})
    np.testing.assert_allclose(np.asarray(p["w"]), 1.0 + 1.9)


def test_dga_downweights_high_loss_clients():
    s = DGA(beta=5.0)
    good = {"w": jnp.asarray([1.0])}
    bad = {"w": jnp.asarray([-1.0])}
    out = s.combine([good, bad], [1.0, 1.0],
                    [{"loss": 0.1}, {"loss": 3.0}])
    assert float(out["w"][0]) > 0.9  # bad client nearly ignored


def test_fedbuff_staleness_and_drain():
    s = FedBuff(buffer_size=3, server_lr=1.0)
    params = {"w": jnp.zeros(1)}
    st = s.init_state(params)
    assert s.staleness_weight(0, 0) == 1.0
    assert s.staleness_weight(0, 3) == pytest.approx(0.5)
    for v in range(2):
        assert not s.offer({"w": jnp.ones(1)}, 1.0, 0, 0)
    assert s.offer({"w": jnp.ones(1)}, 1.0, 0, 0)
    params, st = s.drain(params, st)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0)
    assert st["model_version"] == 1
    # drain on empty buffer is a no-op
    p2, st2 = s.drain(params, st)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0)


def test_fedprox_pulls_towards_anchor():
    opt = proximal_sgd(lr=0.1, mu=10.0)
    params = {"w": jnp.asarray([5.0])}
    state = opt.init({"w": jnp.asarray([0.0])})  # anchor at 0
    upd, state = opt.update({"w": jnp.asarray([0.0])}, state, params)
    assert float(upd["w"][0]) < 0  # proximal term pulls toward anchor


def test_make_strategy_registry():
    assert make_strategy("fedavg").name == "fedavg"
    assert make_strategy("dga", beta=2.0).beta == 2.0
    with pytest.raises(KeyError):
        make_strategy("nope")
