"""Fused async FedBuff path (ISSUE 3): device-resident buffer, batched DP,
one-dispatch drain — bit-exact parity of ``AsyncServer.submit_batch`` with
the kept serial ``submit`` reference, the ``FedBuff.room()`` API, the bulk
service route, and the cached-unflatten raveling helper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import raveling
from repro.core.dp import DPConfig
from repro.core.orchestrator import AsyncServer, ClientResult
from repro.core.strategies import FedBuff

SIZE = 24


def _params():
    return {"a": jnp.zeros((3, 4), jnp.float32),
            "b": jnp.ones(12, jnp.float32) * 0.5}


def _mk_server(buffer_size=4, dp="off", seed=0, lr=0.7):
    cfg = DPConfig(mechanism=dp, clip_norm=0.5,
                   noise_multiplier=1.0 if dp == "local" else 0.0)
    return AsyncServer(_params(), FedBuff(buffer_size=buffer_size,
                                          server_lr=lr), cfg, seed=seed)


def _rows(n, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(-1, 1, (n, SIZE)), jnp.float32)


def _flat(tree):
    return np.asarray(ravel_pytree(tree)[0])


def _unflatten_row(row):
    _, unflatten = ravel_pytree(_params())
    return unflatten(jnp.asarray(row))


def _serial_feed(server, rows, weights, versions):
    stepped = []
    for j in range(rows.shape[0]):
        full = server.submit(
            ClientResult(update=_unflatten_row(rows[j]),
                         n_samples=weights[j]), versions[j])
        if full:
            stepped.append(j)
    return stepped


def _assert_same_server_state(s1, s2):
    assert s1.n_server_steps == s2.n_server_steps
    assert s1.model_version == s2.model_version
    assert s1._n_submissions == s2._n_submissions
    assert s1.strategy._cursor == s2.strategy._cursor
    np.testing.assert_array_equal(np.asarray(s1.strategy._weights),
                                  np.asarray(s2.strategy._weights))
    c = s1.strategy._cursor
    if c:
        np.testing.assert_array_equal(np.asarray(s1.strategy._rows)[:c],
                                      np.asarray(s2.strategy._rows)[:c])
    np.testing.assert_array_equal(_flat(s1.params), _flat(s2.params))


class TestSubmitBatchParity:
    @pytest.mark.parametrize("dp", ["off", "local"])
    def test_batch_matches_serial_with_mid_batch_steps(self, dp):
        """10 rows into a buffer of 4: the buffer fills mid-batch twice
        (rows 3 and 7) and the staleness of rows after each fill sees the
        bumped model version — bit-identical to 10 serial submits."""
        rows = _rows(10)
        versions = [0, 0, 1, 0, 2, 1, 0, 3, 1, 2]   # mixed staleness
        weights = [1, 2, 1, 3, 1, 1, 2, 1, 1, 1]
        s_serial, s_batch = _mk_server(4, dp), _mk_server(4, dp)
        steps_serial = _serial_feed(s_serial, rows, weights, versions)
        steps_batch = s_batch.submit_batch(rows, [float(w) for w in weights],
                                           versions)
        assert steps_serial == steps_batch == [3, 7]
        _assert_same_server_state(s_serial, s_batch)

    @pytest.mark.parametrize("dp", ["off", "local"])
    def test_fill_at_row_k_with_prefilled_buffer(self, dp):
        """Mid-batch step boundary: 2 serial submits pre-fill the buffer,
        then a batch of 5 fills it at row 1 < batch size."""
        rows = _rows(7, seed=3)
        versions = [0, 0, 0, 1, 0, 1, 1]
        weights = [1.0] * 7
        s_serial, s_batch = _mk_server(4, dp), _mk_server(4, dp)
        _serial_feed(s_serial, rows[:2], weights[:2], versions[:2])
        _serial_feed(s_batch, rows[:2], weights[:2], versions[:2])
        steps_serial = _serial_feed(s_serial, rows[2:], weights[2:],
                                    versions[2:])
        steps_batch = s_batch.submit_batch(rows[2:], weights[2:],
                                           versions[2:])
        assert steps_serial == steps_batch == [1]
        assert s_batch.strategy._cursor == 3
        _assert_same_server_state(s_serial, s_batch)

    def test_dp_keys_follow_global_submission_counter(self):
        """Interleaving serial submits and batches consumes the same DP key
        sequence as an all-serial feed — model bits stay identical."""
        rows = _rows(9, seed=5)
        versions = [0] * 9
        weights = [1.0] * 9
        s_serial, s_mixed = _mk_server(3, "local"), _mk_server(3, "local")
        _serial_feed(s_serial, rows, weights, versions)
        _serial_feed(s_mixed, rows[:2], weights[:2], versions[:2])
        s_mixed.submit_batch(rows[2:6], weights[2:6], versions[2:6])
        _serial_feed(s_mixed, rows[6:7], weights[6:7], versions[6:7])
        s_mixed.submit_batch(rows[7:], weights[7:], versions[7:])
        _assert_same_server_state(s_serial, s_mixed)


class TestFedBuff:
    def test_room_tracks_cursor_and_resets_on_drain(self):
        s = FedBuff(buffer_size=3)
        params = {"w": jnp.zeros(4, jnp.float32)}
        st = s.init_state(params)
        assert s.room() == 3
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        assert s.room() == 2
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        assert s.room() == 0
        _, st = s.drain(params, st)
        assert s.room() == 3

    def test_offer_beyond_room_raises(self):
        s = FedBuff(buffer_size=2)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        with pytest.raises(ValueError, match="drain first"):
            s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)

    def test_partial_drain_masks_stale_rows(self):
        """A partial drain must see only rows [0, cursor) — rows left over
        from the previous fill are weight-masked, and a reference
        weighted mean reproduces the result."""
        s = FedBuff(buffer_size=4, server_lr=1.0)
        params = {"w": jnp.zeros(4, jnp.float32)}
        st = s.init_state(params)
        rng = np.random.RandomState(0)
        first = rng.uniform(-1, 1, (4, 4)).astype(np.float32)
        for r in first:
            s.offer({"w": jnp.asarray(r)}, 1.0, 0, 0)
        params, st = s.drain(params, st)
        second = rng.uniform(-1, 1, (2, 4)).astype(np.float32)
        s.offer({"w": jnp.asarray(second[0])}, 2.0, 0, 1)
        s.offer({"w": jnp.asarray(second[1])}, 1.0, 1, 1)
        params, st = s.drain(params, st)
        w = np.asarray([2.0 * (1 + 1) ** -0.5, 1.0], np.float32)
        ref = first.mean(axis=0) + (w / w.sum()) @ second
        np.testing.assert_allclose(np.asarray(params["w"]), ref, atol=1e-6)
        assert st["model_version"] == 2

    def test_drain_caches_raveled_params(self):
        """Between drains the params stay raveled — the second drain must
        reuse the cached flat vector instead of re-raveling the pytree."""
        s = FedBuff(buffer_size=2)
        params = {"w": jnp.zeros(4, jnp.float32)}
        st = s.init_state(params)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 0)
        params, st = s.drain(params, st)
        assert s._params_ref is params and s._params_flat is not None
        cached = s._params_flat
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 1)
        s.offer({"w": jnp.ones(4)}, 1.0, 0, 1)
        params2, st = s.drain(params, st)
        assert s._params_flat is not cached   # advanced, not re-raveled
        np.testing.assert_allclose(np.asarray(params2["w"]), 2.0, atol=1e-6)


class TestServiceBulkRoute:
    def _mk_task(self, n_rounds=2, buffer_size=3):
        from repro.fl import (AttestationAuthority, ManagementService,
                              TaskConfig)
        svc = ManagementService()
        model = {"w": jnp.zeros(8, jnp.float32)}
        cfg = TaskConfig("t", "app", "wf", clients_per_round=4,
                         n_rounds=n_rounds, mode="async",
                         buffer_size=buffer_size, vg_size=2)
        tid = svc.create_task(cfg, model)
        auth = AttestationAuthority()
        for i in range(6):
            assert svc.register_client(tid, f"c{i}",
                                       {"os": "linux", "n_samples": 10,
                                        "battery": 0.9}, auth.issue(f"c{i}"))
        return svc, tid

    def test_bulk_matches_per_client_submits(self):
        rng = np.random.RandomState(1)
        ups = rng.uniform(-0.3, 0.3, (6, 8)).astype(np.float32)
        versions = [0, 0, 0, 1, 1, 1]   # serial default: round_idx at submit
        svc_a, tid_a = self._mk_task()
        for j in range(6):
            svc_a.submit_update(tid_a, f"c{j}", {"w": jnp.asarray(ups[j])},
                                10, update_version=versions[j])
        svc_b, tid_b = self._mk_task()
        steps = svc_b.submit_updates_async(
            tid_b, [f"c{j}" for j in range(6)],
            {"w": jnp.asarray(ups)}, [10] * 6, versions)
        assert steps == [2, 5]
        ta, tb = svc_a.get_task(tid_a), svc_b.get_task(tid_b)
        np.testing.assert_array_equal(np.asarray(ta.model["w"]),
                                      np.asarray(tb.model["w"]))
        assert ta.round_idx == tb.round_idx == 2
        assert ta.status == tb.status
        assert [h["n"] for h in ta.history] == [h["n"] for h in tb.history]

    def test_bulk_truncates_at_completion_like_serial(self):
        """Rows past the task's final server step must be dropped exactly
        as the serial loop rejects them once the task COMPLETES."""
        rng = np.random.RandomState(2)
        ups = rng.uniform(-0.3, 0.3, (9, 8)).astype(np.float32)
        svc_a, tid_a = self._mk_task(n_rounds=2, buffer_size=3)
        for j in range(9):   # submissions 6..8 rejected (COMPLETED)
            svc_a.submit_update(tid_a, f"c{j % 6}",
                                {"w": jnp.asarray(ups[j])}, 10,
                                update_version=0)
        svc_b, tid_b = self._mk_task(n_rounds=2, buffer_size=3)
        steps = svc_b.submit_updates_async(
            tid_b, [f"c{j % 6}" for j in range(9)],
            {"w": jnp.asarray(ups)}, [10] * 9, [0] * 9)
        assert steps == [2, 5]
        np.testing.assert_array_equal(
            np.asarray(svc_a.get_task(tid_a).model["w"]),
            np.asarray(svc_b.get_task(tid_b).model["w"]))

    def test_async_buffer_room_uses_room_api(self):
        svc, tid = self._mk_task(buffer_size=3)
        assert svc.async_buffer_room(tid) == 3
        svc.submit_update(tid, "c0", {"w": jnp.ones(8)}, 1,
                          update_version=0)
        assert svc.async_buffer_room(tid) == 2
        assert not hasattr(svc._async[tid].strategy, "_buffer")


class TestRavelingCache:
    def test_unflatten_closure_is_cached_by_signature(self):
        t1 = {"a": jnp.zeros((2, 3)), "b": jnp.ones(4)}
        t2 = {"a": jnp.ones((2, 3)) * 7, "b": jnp.zeros(4)}
        s1, u1 = raveling.cached_unflatten(t1)
        s2, u2 = raveling.cached_unflatten(t2)
        assert s1 == s2 == 10
        assert u1 is u2                       # same structure -> same closure
        s3, u3 = raveling.cached_unflatten({"a": jnp.zeros((3, 2)),
                                            "b": jnp.ones(4)})
        assert u3 is not u1                   # shape change -> new closure
        rebuilt = u1(ravel_pytree(t2)[0])
        np.testing.assert_array_equal(np.asarray(rebuilt["a"]),
                                      np.asarray(t2["a"]))

    def test_stack_flat_updates_roundtrip(self):
        from repro.core.privacy_engine import stack_flat_updates
        ups = [{"w": jnp.ones(3) * j, "v": jnp.zeros((2, 2))}
               for j in range(3)]
        flat, unflatten = stack_flat_updates(ups)
        assert flat.shape == (3, 7)
        back = unflatten(flat[2])
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(ups[2]["w"]))


class TestPaddedBatchCompiles:
    """ISSUE 4 satellite: ``submit_batch`` pads DP rows to whole buffers and
    buffer fills to the buffer shape, so varying batch lengths reuse ONE
    compiled executable per jit (the ROADMAP's per-batch-length recompile
    item) — while staying bit-identical to the serial reference."""

    def test_no_per_length_recompiles(self):
        from repro.core import dp as dp_mod
        from repro.core import strategies as strat_mod
        server = _mk_server(buffer_size=8, dp="local")
        # warm each DP SHAPE CLASS once (powers of two below one buffer,
        # whole buffers above: {1, 2, 4, 8, 16} here); the masked buffer
        # write and the 1-row write each have exactly one shape.
        for j, k in enumerate([1, 2, 3, 5, 9]):
            server.submit_batch(_rows(k, seed=1 + j), [1.0] * k, [0] * k)
        dp0 = dp_mod._flat_local_dp_rows_jit._cache_size()
        wr0 = strat_mod._buffer_write_masked._cache_size()
        # every batch length up to two buffers reuses those executables —
        # the pre-padding code compiled one DP program and one write
        # program PER DISTINCT LENGTH
        for j, k in enumerate([5, 2, 7, 6, 4, 8, 1, 5, 12, 16, 3, 10]):
            server.submit_batch(_rows(k, seed=10 + j),
                                [1.0] * k, [0] * k)
        assert dp_mod._flat_local_dp_rows_jit._cache_size() == dp0
        assert strat_mod._buffer_write_masked._cache_size() == wr0

    def test_padded_batches_bit_identical_to_serial(self):
        """Lengths chosen to hit pad amounts 0..B-1 and mid-batch drains."""
        rows = _rows(23, seed=7)
        versions = [j % 3 for j in range(23)]
        weights = [1.0 + (j % 4) for j in range(23)]
        s_serial, s_batch = _mk_server(5, "local"), _mk_server(5, "local")
        _serial_feed(s_serial, rows, weights, versions)
        i = 0
        for k in [4, 6, 1, 5, 7]:
            s_batch.submit_batch(rows[i:i + k], weights[i:i + k],
                                 versions[i:i + k])
            i += k
        _assert_same_server_state(s_serial, s_batch)
