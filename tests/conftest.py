import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py overrides it, and
# tests that need a multi-device host (the multihost emulation lane) must
# isolate themselves in a subprocess with XLA_FLAGS set in its env.


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multihost: subprocess-isolated multi-device host emulation "
        "(spawns python with XLA_FLAGS=--xla_force_host_platform_"
        "device_count=8; slower than the in-process suite)")


@pytest.fixture
def rng():
    return np.random.RandomState(0)
