import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py overrides it.


@pytest.fixture
def rng():
    return np.random.RandomState(0)
