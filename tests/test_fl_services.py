"""Service layer: attestation, selection criteria, task lifecycle,
permissions, async FedBuff server behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import ClientResult
from repro.fl import (AttestationAuthority, AuthenticationService,
                      ManagementService, SelectionCriteria, TaskConfig,
                      TaskStatus)
from repro.fl.server import PermissionError_


def _mk_service_task(mode="sync", n_rounds=3, cpr=4, **task_kw):
    svc = ManagementService()
    model = {"w": jnp.zeros(8, jnp.float32)}
    cfg = TaskConfig("t", "app", "wf", clients_per_round=cpr,
                     n_rounds=n_rounds, mode=mode, vg_size=2, **task_kw)
    tid = svc.create_task(cfg, model)
    return svc, tid, model


def _register(svc, tid, n=6, os="linux"):
    auth = AttestationAuthority()
    for i in range(n):
        cert = auth.issue(f"c{i}", os=os)
        assert svc.register_client(tid, f"c{i}",
                                   {"os": os, "n_samples": 10,
                                    "battery": 0.9}, cert)


class TestAuth:
    def test_valid_and_tampered(self):
        auth = AttestationAuthority()
        svc = AuthenticationService()
        cert = auth.issue("dev1")
        assert svc.verify(cert)
        bad = {"body": dict(cert["body"], verdict="MEETS_STRONG_INTEGRITY"),
               "signature": cert["signature"]}
        assert not svc.verify(bad)          # signature no longer matches
        assert svc.rejections == 1

    def test_low_integrity_rejected(self):
        auth = AttestationAuthority()
        svc = AuthenticationService()
        cert = auth.issue("dev1", verdict="NO_INTEGRITY")
        assert not svc.verify(cert)

    def test_wrong_authority_key(self):
        rogue = AttestationAuthority(key=b"rogue")
        svc = AuthenticationService()
        assert not svc.verify(rogue.issue("dev1"))


class TestSelection:
    def test_criteria_gate(self):
        svc, tid, _ = _mk_service_task()
        task = svc.get_task(tid)
        task.config.selection = SelectionCriteria(allowed_os=("android",),
                                                  min_samples=5)
        auth = AttestationAuthority()
        ok = svc.register_client(tid, "a", {"os": "android", "n_samples": 9,
                                            "battery": 1.0},
                                 auth.issue("a", os="android"))
        assert ok
        assert not svc.register_client(
            tid, "b", {"os": "linux", "n_samples": 9, "battery": 1.0},
            auth.issue("b"))
        assert not svc.register_client(
            tid, "c", {"os": "android", "n_samples": 1, "battery": 1.0},
            auth.issue("c", os="android"))

    def test_attestation_required(self):
        svc, tid, _ = _mk_service_task()
        assert not svc.register_client(tid, "x", {"os": "linux",
                                                  "n_samples": 10})

    def test_cohort_selection_size(self):
        svc, tid, _ = _mk_service_task(cpr=4)
        _register(svc, tid, n=10)
        _, cohort = svc.begin_round(tid)
        assert len(cohort) == 4
        assert len(set(cohort)) == 4


class TestLifecycle:
    def test_sync_rounds_to_completion(self):
        svc, tid, model = _mk_service_task(n_rounds=2, cpr=3)
        _register(svc, tid, n=5)
        for _ in range(2):
            _, cohort = svc.begin_round(tid)
            for cid in cohort:
                svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10,
                                  {"loss": 1.0})
        task = svc.get_task(tid)
        assert task.status is TaskStatus.COMPLETED
        assert task.round_idx == 2
        np.testing.assert_allclose(np.asarray(task.model["w"]), 0.2,
                                   atol=1e-4)

    def test_pause_cancel_permissions(self):
        svc, tid, _ = _mk_service_task()
        with pytest.raises(PermissionError_):
            svc.pause_task(tid, user="intruder")
        svc.pause_task(tid)  # owner
        assert svc.get_task(tid).status is TaskStatus.PAUSED
        svc.resume_task(tid)
        svc.cancel_task(tid)
        assert svc.get_task(tid).status is TaskStatus.CANCELLED

    def test_shared_permissions(self):
        svc, tid, _ = _mk_service_task(permissions=("alice",))
        svc.pause_task(tid, user="alice")  # granted via task permissions


class TestAsync:
    def test_fedbuff_steps_on_buffer_fill(self):
        svc, tid, _ = _mk_service_task(mode="async", n_rounds=2,
                                       buffer_size=3)
        _register(svc, tid, n=4)
        stepped = []
        for i in range(6):
            stepped.append(svc.submit_update(
                tid, f"c{i % 4}", {"w": jnp.ones(8)}, 1))
        assert stepped == [False, False, True, False, False, True]
        assert svc.get_task(tid).status is TaskStatus.COMPLETED

    def test_metrics_and_accountant(self):
        from repro.core.dp import DPConfig
        svc, tid, _ = _mk_service_task(
            n_rounds=1, cpr=2,
            dp=DPConfig(mechanism="local", clip_norm=0.5,
                        noise_multiplier=1.0))
        _register(svc, tid, n=4)
        _, cohort = svc.begin_round(tid)
        for cid in cohort:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 5,
                              {"loss": 2.0})
        eps = svc.epsilon(tid)
        assert eps is not None and eps > 0
        rounds, vals = svc.metrics.series(tid, "loss")
        assert vals == [2.0]

    def test_async_accountant_uses_buffer_rate(self):
        """Async privacy accounting must compose at q = buffer_size / pool
        (the K clients per FedBuff server step), not the sync path's
        clients_per_round / pool — the pre-fix code used the latter for
        every mode. Epsilon must equal a hand-computed composition."""
        from repro.core.dp import (DPConfig, compute_rdp,
                                   get_privacy_spent)
        dp = DPConfig(mechanism="local", clip_norm=0.5,
                      noise_multiplier=1.0)
        # clients_per_round (4) deliberately differs from buffer_size (3)
        svc, tid, _ = _mk_service_task(mode="async", n_rounds=2, cpr=4,
                                       buffer_size=3, dp=dp)
        _register(svc, tid, n=6)   # pool = 6
        for i in range(6):         # two server steps of 3 submissions each
            svc.submit_update(tid, f"c{i % 6}", {"w": jnp.ones(8)}, 1)
        expected_q = 3 / 6
        rdp = compute_rdp(expected_q, 1.0, steps=2)
        expected_eps, _ = get_privacy_spent(rdp, dp.delta)
        assert svc.epsilon(tid) == pytest.approx(expected_eps, rel=1e-9)
        # and it is NOT the (wrong) sync-rate composition
        wrong_rdp = compute_rdp(4 / 6, 1.0, steps=2)
        wrong_eps, _ = get_privacy_spent(wrong_rdp, dp.delta)
        assert abs(svc.epsilon(tid) - wrong_eps) > 1e-6


class TestSelectionLifecycle:
    def test_two_round_status_cycle(self):
        """Cohort members go selected -> done on submission, and return to
        'registered' when the next round begins (pre-fix they stayed
        'selected' forever — mark was never called)."""
        svc, tid, _ = _mk_service_task(n_rounds=3, cpr=3)
        _register(svc, tid, n=6)
        task = svc.get_task(tid)

        _, cohort1 = svc.begin_round(tid)
        statuses = svc.selection.statuses(task)
        assert all(statuses[c] == "selected" for c in cohort1)
        assert all(statuses[c] == "registered" for c in statuses
                   if c not in cohort1)
        for cid in cohort1:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        statuses = svc.selection.statuses(task)
        assert all(statuses[c] == "done" for c in cohort1)

        _, cohort2 = svc.begin_round(tid)
        statuses = svc.selection.statuses(task)
        # everyone not selected this round is back to 'registered'
        assert all(statuses[c] == "selected" for c in cohort2)
        assert all(statuses[c] == "registered" for c in statuses
                   if c not in cohort2)

    def test_bulk_submission_marks_done(self):
        svc, tid, _ = _mk_service_task(n_rounds=2, cpr=4)
        _register(svc, tid, n=6)
        task = svc.get_task(tid)
        _, cohort = svc.begin_round(tid)
        stacked = {"w": jnp.tile(jnp.ones(8) * 0.1, (len(cohort), 1))}
        assert svc.submit_cohort(tid, cohort, stacked, 10,
                                 [{"loss": 1.0}] * len(cohort))
        statuses = svc.selection.statuses(task)
        assert all(statuses[c] == "done" for c in cohort)


class TestBulkSubmission:
    def test_submit_cohort_matches_per_client_rounds(self):
        """The fused bulk path produces the same model as per-client
        submissions for the same cohort and round."""
        import numpy as np
        results = {}
        for path in ("per-client", "bulk"):
            svc, tid, _ = _mk_service_task(n_rounds=1, cpr=4)
            _register(svc, tid, n=6)
            _, cohort = svc.begin_round(tid)
            rng = np.random.RandomState(0)
            ups = {c: jnp.asarray(rng.uniform(-0.2, 0.2, 8), jnp.float32)
                   for c in cohort}
            if path == "per-client":
                for cid in cohort:
                    svc.submit_update(tid, cid, {"w": ups[cid]}, 10,
                                      {"loss": 1.0})
            else:
                stacked = {"w": jnp.stack([ups[c] for c in cohort])}
                assert svc.submit_cohort(tid, cohort, stacked, 10,
                                         [{"loss": 1.0}] * len(cohort))
            results[path] = np.asarray(svc.get_task(tid).model["w"])
        np.testing.assert_array_equal(results["per-client"], results["bulk"])

    def test_submit_cohort_rejects_wrong_cohort(self):
        svc, tid, _ = _mk_service_task(n_rounds=1, cpr=3)
        _register(svc, tid, n=6)
        _, cohort = svc.begin_round(tid)
        stacked = {"w": jnp.zeros((2, 8), jnp.float32)}
        assert not svc.submit_cohort(tid, cohort[:2], stacked, 10)
