"""Secure aggregation invariants (paper §4.1): exact mask cancellation,
two-stage correctness, headroom enforcement — property-tested."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (SecureAggConfig, check_headroom, dequantize_sum,
                        make_virtual_groups, quantize,
                        secure_aggregate_round)
from repro.core.masking import apply_mask, modular_sum, net_mask
from repro.core.masking import net_mask_traced


@settings(deadline=None, max_examples=25)
@given(n=st.integers(2, 9), size=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1))
def test_mask_cancellation_exact(n, size, seed):
    """sum of masked payloads == sum of plain payloads, bit-exact."""
    rng = np.random.RandomState(seed % 10_000)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    qs = jnp.asarray(rng.randint(0, 2**32, (n, size), dtype=np.uint32))
    payloads = jnp.stack([apply_mask(qs[i], i, n, round_seed)
                          for i in range(n)])
    assert jnp.array_equal(modular_sum(payloads), modular_sum(qs))


@settings(deadline=None, max_examples=10)
@given(n=st.integers(2, 8), seed=st.integers(0, 1000))
def test_single_payload_is_masked(n, seed):
    """an individual masked payload must differ from the plain update
    (privacy: the server cannot read a single client's update)."""
    rng = np.random.RandomState(seed)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    q = jnp.asarray(rng.randint(0, 2**20, 256, dtype=np.uint32))
    y = apply_mask(q, 0, n, round_seed)
    assert not jnp.array_equal(y, q)
    # and the mask looks high-entropy: most words differ
    assert float(jnp.mean((y != q).astype(jnp.float32))) > 0.99


def test_net_mask_traced_matches_untraced():
    seed = jnp.asarray([5, 6], jnp.uint32)
    n, size = 6, 128
    for i in range(n):
        a = net_mask(i, n, seed, size)
        b = net_mask_traced(jnp.uint32(i), jnp.uint32(0), n, seed, size)
        assert jnp.array_equal(a, b), i


def test_two_stage_recovers_cohort_mean(rng):
    updates = {i: {"w": jnp.asarray(rng.uniform(-0.4, 0.4, (8, 3)),
                                    jnp.float32)}
               for i in range(12)}
    plan = make_virtual_groups(list(updates), vg_size=4, seed=0)
    assert len(plan.groups) == 3
    agg = secure_aggregate_round(updates, plan,
                                 jnp.asarray([1, 2], jnp.uint32))
    true = np.mean([np.asarray(u["w"]) for u in updates.values()], axis=0)
    np.testing.assert_allclose(np.asarray(agg["w"]), true, atol=1e-5)


def test_kernel_path_matches_reference_path(rng):
    updates = {i: {"w": jnp.asarray(rng.uniform(-0.4, 0.4, 300), jnp.float32)}
               for i in range(6)}
    plan = make_virtual_groups(list(updates), vg_size=3, seed=0)
    seed = jnp.asarray([9, 9], jnp.uint32)
    a = secure_aggregate_round(updates, plan, seed,
                               SecureAggConfig(use_kernels=False))
    b = secure_aggregate_round(updates, plan, seed,
                               SecureAggConfig(use_kernels=True))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))


def test_headroom_guard():
    check_headroom(20, 4096)
    with pytest.raises(ValueError):
        check_headroom(20, 8192)
    with pytest.raises(ValueError):
        check_headroom(31, 3)


@settings(deadline=None, max_examples=20)
@given(bits=st.integers(8, 24), n=st.integers(1, 64),
       seed=st.integers(0, 10_000))
def test_quantized_aggregate_error_bound(bits, n, seed):
    """|dequantized cohort mean - true mean| <= quantization resolution."""
    rng = np.random.RandomState(seed)
    clip = 1.0
    xs = rng.uniform(-clip, clip, (n, 64)).astype(np.float32)
    qs = jnp.stack([quantize(jnp.asarray(x), clip, bits) for x in xs])
    s = modular_sum(qs)
    mean = dequantize_sum(s, n, clip, bits)
    res = 2 * clip / (2**bits - 1)
    assert np.max(np.abs(np.asarray(mean) - xs.mean(0))) <= res
