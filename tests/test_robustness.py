"""Robustness behaviours: client dropouts (VGs formed from the surviving
cohort, so masks still cancel), and DGA down-weighting corrupted clients."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientResult, FedAvg, make_strategy, run_sync_round
from repro.core.strategies import DGA
from repro.fl import ManagementService, TaskConfig, TaskStatus


def _results(updates, losses=None):
    return {i: ClientResult(update={"w": jnp.asarray(u, jnp.float32)},
                            n_samples=10,
                            metrics={"loss": (losses or {}).get(i, 1.0)})
            for i, u in updates.items()}


def test_round_completes_with_dropouts():
    """VGs are formed from the clients that actually submitted — a dropout
    never leaves an unmatched mask in the aggregate."""
    params = {"w": jnp.zeros(4)}
    strat = FedAvg()
    state = strat.init_state(params)
    # 5 of an intended 8 clients submitted
    res = _results({i: [0.1 * (i + 1)] * 4 for i in range(5)})
    params, state, info = run_sync_round(params, strat, state, res,
                                         round_idx=0, vg_size=4)
    assert info.n_participants == 5
    expected = np.mean([[0.1 * (i + 1)] * 4 for i in range(5)], axis=0)
    np.testing.assert_allclose(np.asarray(params["w"]), expected, atol=1e-4)


def test_server_ignores_unselected_submission():
    svc = ManagementService()
    tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=2,
                                     n_rounds=1, vg_size=2),
                          {"w": jnp.zeros(2)})
    from repro.fl import AttestationAuthority
    auth = AttestationAuthority()
    for i in range(4):
        svc.register_client(tid, f"c{i}", {"os": "linux", "n_samples": 5,
                                           "battery": 1.0},
                            auth.issue(f"c{i}"))
    _, cohort = svc.begin_round(tid)
    outsider = next(f"c{i}" for i in range(4) if f"c{i}" not in cohort)
    assert not svc.submit_update(tid, outsider, {"w": jnp.ones(2)}, 5)
    for cid in cohort:
        svc.submit_update(tid, cid, {"w": jnp.ones(2)}, 5)
    assert svc.get_task(tid).status is TaskStatus.COMPLETED


def test_dga_resists_corrupted_clients_better_than_fedavg():
    """a corrupted (high-loss, garbage-update) client: DGA's loss-softmax
    weighting suppresses it, FedAvg averages it in."""
    good = {"w": jnp.asarray([1.0, 1.0])}
    bad = {"w": jnp.asarray([-50.0, 50.0])}
    ups = [good, good, good, bad]
    weights = [1.0, 1.0, 1.0, 1.0]
    metrics = [{"loss": 0.2}, {"loss": 0.25}, {"loss": 0.22},
               {"loss": 8.0}]
    avg = FedAvg().combine(ups, weights, metrics)
    dga = DGA(beta=2.0).combine(ups, weights, metrics)
    err_avg = float(jnp.linalg.norm(avg["w"] - jnp.asarray([1.0, 1.0])))
    err_dga = float(jnp.linalg.norm(dga["w"] - jnp.asarray([1.0, 1.0])))
    assert err_dga < err_avg / 10, (err_avg, err_dga)


def test_strategy_registry_fedavgm():
    s = make_strategy("fedavgm")
    assert s.momentum == 0.9
