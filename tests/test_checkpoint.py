import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (deserialize_pytree, load_checkpoint,
                              save_checkpoint, serialize_pytree)


def test_round_trip_nested(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": (jnp.ones(3), jnp.zeros(1, jnp.uint32))}}
    blob = serialize_pytree(tree)
    restored = deserialize_pytree(blob, like=tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_structure_mismatch_raises():
    blob = serialize_pytree({"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        deserialize_pytree(blob, like={"b": jnp.ones(2)})


def test_save_load_with_step(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree, step=17)
    restored, step = load_checkpoint(p, like=tree, with_step=True)
    assert step == 17
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.ones((4, 4)))
