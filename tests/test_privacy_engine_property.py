"""Hypothesis property: the vectorized privacy engine is bit-identical to
the serial `secure_aggregate_round` reference across random cohort sizes
(including ragged/merged virtual groups), vg_size, bits, and DP mechanisms
off/local/global — the ISSUE 2 acceptance criterion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import _secure_mean_serial
from repro.core.virtual_groups import make_virtual_groups


@settings(deadline=None, max_examples=40)
@given(n=st.integers(1, 21), vg_size=st.integers(2, 7),
       bits=st.integers(8, 24), size=st.integers(1, 90),
       mech=st.sampled_from(["off", "local", "global"]),
       noise=st.sampled_from([0.0, 0.8]),
       seed=st.integers(0, 10_000))
def test_vectorized_bit_identical_to_serial(n, vg_size, bits, size, mech,
                                            noise, seed):
    rng = np.random.RandomState(seed)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, size).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), vg_size, seed=seed)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    key = jax.random.PRNGKey(seed)
    scfg = sa.SecureAggConfig(bits=bits)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=noise)
    serial = _secure_mean_serial(dict(sorted(updates.items())), plan,
                                 round_seed, key,
                                 sa.SecureAggConfig(bits=bits), dcfg)
    vect = pe.PrivacyEngine(scfg, dcfg).aggregate_updates(
        updates, plan, round_seed, key=key)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))


@settings(deadline=None, max_examples=10)
@given(n=st.integers(2, 17), seed=st.integers(0, 1000))
def test_kernel_path_bit_identical(n, seed):
    rng = np.random.RandomState(seed)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1, 1, 40).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), 4, seed=seed)
    round_seed = jnp.asarray([seed, seed ^ 31], jnp.uint32)
    ref = pe.PrivacyEngine(sa.SecureAggConfig()).aggregate_updates(
        updates, plan, round_seed)
    kern = pe.PrivacyEngine(sa.SecureAggConfig(use_kernels=True)) \
        .aggregate_updates(updates, plan, round_seed)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(kern))


@settings(deadline=None, max_examples=25)
@given(n=st.integers(2, 24), vg_size=st.integers(2, 6),
       bits=st.integers(10, 24), size=st.integers(1, 70),
       shards=st.integers(1, 9),
       mech=st.sampled_from(["off", "local"]),
       seed=st.integers(0, 10_000))
def test_sharded_combine_bit_identical_across_shard_counts(
        n, vg_size, bits, size, shards, mech, seed):
    """ISSUE 4 tentpole acceptance: the hierarchical stage-2 combine is
    bit-identical to the serial reference for EVERY shard count, across
    random cohorts, ragged/merged plans, bits, and DP."""
    rng = np.random.RandomState(seed)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, size).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), vg_size, seed=seed)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    key = jax.random.PRNGKey(seed)
    scfg = sa.SecureAggConfig(bits=bits)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=0.6 if mech == "local" else 0.0)
    serial = _secure_mean_serial(dict(sorted(updates.items())), plan,
                                 round_seed, key, scfg, dcfg)
    cids = sorted(updates)
    flat = jnp.stack([updates[c] for c in cids])
    sharded = pe.aggregate_flat(flat, plan, cids, round_seed,
                                secure_cfg=scfg, dp_cfg=dcfg, key=key,
                                n_shards=shards)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(sharded))
