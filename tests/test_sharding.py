"""Sharding rules: divisibility, expert-parallel placement, scheme
differences, silo counts."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import input_specs as ispec
from repro.launch import sharding as shd
from repro.launch.fl_step import n_silos_for


class FakeMesh:
    shape = {"data": 16, "model": 16}


class FakePodMesh:
    shape = {"pod": 2, "data": 16, "model": 16}


def _pspec_of(cfg, mesh, name_fragment, scheme=None):
    params = ispec.abstract_params(cfg)
    specs = shd.params_pspecs(cfg, params, mesh, scheme=scheme)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    shapes = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for (pa, sp), (_, leaf) in zip(flat, shapes):
        out[jax.tree_util.keystr(pa)] = (sp, leaf.shape)
    hits = {k: v for k, v in out.items() if name_fragment in k}
    assert hits, (name_fragment, list(out)[:5])
    return hits


def test_expert_weights_on_model_axis():
    cfg = get_config("qwen3-moe-235b-a22b")
    for k, (spec, shape) in _pspec_of(cfg, FakeMesh(), "w_in").items():
        if len(shape) == 4:  # stacked (L, E, D, F)
            assert spec[1] == "model", (k, spec, shape)


def test_per_silo_params_replicated_over_data():
    cfg = get_config("gemma2-27b")
    assert cfg.fl_scheme == "per_silo"
    for k, (spec, shape) in _pspec_of(cfg, FakeMesh(), "wq").items():
        assert "data" not in jax.tree.leaves(tuple(spec)), (k, spec)


def test_per_pod_params_fsdp_over_data():
    cfg = get_config("deepseek-67b")
    found_data = False
    for k, (spec, shape) in _pspec_of(cfg, FakeMesh(), "w_in").items():
        found_data |= "data" in [s for s in spec if isinstance(s, str)]
    assert found_data


def test_indivisible_dims_not_sharded():
    cfg = get_config("yi-9b")  # kv=4 heads, kv_dim=512: 512/16=32 ok
    # d_ff=11008: 11008 % 16 == 0 -> sharded; check a small norm leaf
    params = ispec.abstract_params(cfg)
    specs = shd.params_pspecs(cfg, params, FakeMesh())
    for (pa, sp), (_, leaf) in zip(
            jax.tree_util.tree_flatten_with_path(specs)[0],
            jax.tree_util.tree_flatten_with_path(params)[0]):
        for axis_idx, s in enumerate(sp):
            if s is None:
                continue
            names = s if isinstance(s, tuple) else (s,)
            size = 1
            for n in names:
                size *= FakeMesh.shape[n]
            assert leaf.shape[axis_idx] % size == 0, \
                (jax.tree_util.keystr(pa), sp, leaf.shape)


def test_n_silos_by_scheme():
    assert n_silos_for(get_config("gemma2-27b"), FakeMesh()) == 16
    assert n_silos_for(get_config("gemma2-27b"), FakePodMesh()) == 32
    assert n_silos_for(get_config("deepseek-67b"), FakeMesh()) == 1
    assert n_silos_for(get_config("deepseek-67b"), FakePodMesh()) == 2


def test_batch_pspec_small_batch_replicates():
    cfg = get_config("yi-9b")
    import jax.numpy as jnp
    struct = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    spec = shd.batch_pspecs(cfg, struct, FakeMesh(), silo_blocked=False)
    assert spec["tokens"] == P(None, None)
    struct = {"tokens": jax.ShapeDtypeStruct((128, 1), jnp.int32)}
    spec = shd.batch_pspecs(cfg, struct, FakeMesh(), silo_blocked=False)
    assert spec["tokens"][0] in ("data", ("data",))  # P normalizes 1-tuples
