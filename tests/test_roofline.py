"""Roofline HLO analyzer: shape parsing, dot flops, while-trip recursion."""
import textwrap

from repro.launch.roofline import (analyze_hlo, parse_module, shape_bytes)

TOY = textwrap.dedent("""\
    HloModule jit_f, entry_computation_layout={(f32[8,16])->f32[8,16]}

    %body.1 (param.0: (s32[], f32[8,16], f32[4,16,16])) -> (s32[], f32[8,16], f32[4,16,16]) {
      %param.0 = (s32[], f32[8,16], f32[4,16,16]) parameter(0)
      %gte.0 = s32[] get-tuple-element(%param.0), index=0
      %gte.1 = f32[8,16]{1,0} get-tuple-element(%param.0), index=1
      %gte.2 = f32[4,16,16]{2,1,0} get-tuple-element(%param.0), index=2
      %ds = f32[1,16,16]{2,1,0} dynamic-slice(%gte.2, %gte.0), dynamic_slice_sizes={1,16,16}
      %w = f32[16,16]{1,0} bitcast(%ds)
      %dot.1 = f32[8,16]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}
      ROOT %tup = (s32[], f32[8,16], f32[4,16,16]) tuple(%gte.0, %ar, %gte.2)
    }

    %cond.1 (param.1: (s32[], f32[8,16], f32[4,16,16])) -> pred[] {
      %param.1 = (s32[], f32[8,16], f32[4,16,16]) parameter(0)
      %gte.3 = s32[] get-tuple-element(%param.1), index=0
      %c4 = s32[] constant(4)
      ROOT %lt = pred[] compare(%gte.3, %c4), direction=LT
    }

    ENTRY %main (p0: f32[8,16], p1: f32[4,16,16]) -> f32[8,16] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %p1 = f32[4,16,16]{2,1,0} parameter(1)
      %c0 = s32[] constant(0)
      %t = (s32[], f32[8,16], f32[4,16,16]) tuple(%c0, %p0, %p1)
      %w.1 = (s32[], f32[8,16], f32[4,16,16]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"4"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w.1), index=1
    }
    """)


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], u32[2,2])") == 16 + 16
    assert shape_bytes("pred[]") == 1


def test_parse_module_structure():
    mod = parse_module(TOY)
    assert mod["entry"] == "main"
    assert set(mod["computations"]) == {"body.1", "cond.1", "main"}
    body = mod["computations"]["body.1"]
    assert any(op.opcode == "dot" for op in body.ops)


def test_while_trip_multiplication():
    stats = analyze_hlo(TOY)
    # dot flops = 2*8*16*16 = 4096, x4 trips
    assert stats["flops"] == 4 * 4096
    # all-reduce operand f32[8,16] = 512B, x4 trips
    assert stats["collective_bytes"] == 4 * 512
    assert stats["n_collectives"] == 4
    # dynamic-slice counted slice-sized (2 x 1KiB), not operand-sized (4KiB)
    assert stats["memory_bytes"] < 4 * (10 * 4096)


def test_collective_kinds():
    stats = analyze_hlo(TOY)
    assert stats["collective_by_kind"] == {"all-reduce": 4 * 512}
