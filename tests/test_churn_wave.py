"""Churn x streaming-wave contract (ISSUE 9 satellite): the wave route is
DOCUMENTED to apply only to clean rounds (``aggregate_flat`` takes it iff
``alive is None``) — a churn round with ``wave_clients`` set must silently
fall back to the recovery path and still produce bits identical to the
same round without waves. Deterministic (no hypothesis): the contract is a
branch condition, not a distribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import _secure_mean_survivors
from repro.core.virtual_groups import make_virtual_groups


def _cohort(n=12, size=30, seed=5):
    rng = np.random.RandomState(seed)
    cids = [f"c{i:03d}" for i in range(n)]
    flat = jnp.asarray(rng.uniform(-1, 1, (n, size)), jnp.float32)
    return cids, flat


def test_wave_route_skipped_under_churn(monkeypatch):
    """Contract enforcement: with an ``alive`` mask the wave scheduler is
    never invoked (a poisoned ``_wave_limb_state`` proves it), while the
    same config WITHOUT churn does take the wave route."""
    cids, flat = _cohort()
    plan = make_virtual_groups(cids, 4, seed=1)
    rs = jnp.asarray([3, 9], jnp.uint32)
    key = jax.random.PRNGKey(0)
    scfg = sa.SecureAggConfig(wave_clients=4)
    alive = np.ones(len(cids), bool)
    alive[[2, 7]] = False

    calls = []
    real = pe._wave_limb_state

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(pe, "_wave_limb_state", spy)
    pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg, key=key,
                      alive=alive)
    assert not calls, "wave scheduler ran under churn"
    pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg, key=key)
    assert calls, "clean round with wave_clients did not take the waves"


@pytest.mark.parametrize("mech", ["off", "local"])
def test_churn_with_wave_config_bit_identical_to_unwaved(mech):
    """The fallback is EXACT: wave_clients set + alive mask == the plain
    churn path == the serial survivor reference, bit for bit, across DP
    modes and recovery."""
    cids, flat = _cohort()
    plan = make_virtual_groups(cids, 4, seed=2)
    rs = jnp.asarray([11, 17], jnp.uint32)
    key = jax.random.PRNGKey(1)
    dcfg = dp_mod.DPConfig(
        mechanism=mech, clip_norm=0.5,
        noise_multiplier=0.7 if mech != "off" else 0.0)
    alive = np.ones(len(cids), bool)
    alive[[0, 5, 6]] = False

    waved_cfg = sa.SecureAggConfig(wave_clients=4)
    plain_cfg = sa.SecureAggConfig()
    out_waved = pe.aggregate_flat(flat, plan, cids, rs,
                                  secure_cfg=waved_cfg, dp_cfg=dcfg,
                                  key=key, alive=alive)
    out_plain = pe.aggregate_flat(flat, plan, cids, rs,
                                  secure_cfg=plain_cfg, dp_cfg=dcfg,
                                  key=key, alive=alive)
    np.testing.assert_array_equal(np.asarray(out_waved),
                                  np.asarray(out_plain))
    # ... and both equal the serial survivor loop (fold rows = selection-
    # time positions)
    fold_of = {cid: j for j, cid in enumerate(cids)}
    survivors = {cid: flat[j] for j, cid in enumerate(cids) if alive[j]}
    serial = _secure_mean_survivors(survivors, plan, rs, key, plain_cfg,
                                    dcfg, fold_of)
    np.testing.assert_array_equal(np.asarray(serial),
                                  np.asarray(out_waved))


def test_wave_config_with_full_alive_mask_matches_clean_round():
    """Edge of the contract: an all-True alive mask is still the churn
    path (mask present = churn semantics), and its result must equal the
    clean round's — the two branches implement the same mean."""
    cids, flat = _cohort(n=8)
    plan = make_virtual_groups(cids, 4, seed=3)
    rs = jnp.asarray([21, 2], jnp.uint32)
    key = jax.random.PRNGKey(2)
    scfg = sa.SecureAggConfig(wave_clients=3)
    clean = pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg,
                              key=key)
    masked = pe.aggregate_flat(flat, plan, cids, rs, secure_cfg=scfg,
                               key=key, alive=np.ones(8, bool))
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(masked))
