"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family (2 layers, d_model<=512, <=4 experts) runs a forward/train
step on CPU — output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_reduced_config
from repro.models import (decode_step, init_cache, init_params, loss_fn)
from repro.optim import adamw
from repro.optim.adamw import apply_updates


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.encoder_decoder:
        sd = 16
        return {
            "frames": jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.02,
                                  jnp.float32),
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, sd))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, sd))),
            "mask": jnp.ones((B, sd), jnp.float32),
        }
    if cfg.frontend == "vision_stub":
        st = S - cfg.num_patch_tokens
        return {
            "patches": jnp.asarray(
                rng.randn(B, cfg.num_patch_tokens, cfg.d_model) * 0.02,
                jnp.float32),
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, st))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, st))),
            "mask": jnp.ones((B, st), jnp.float32),
        }
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
            "mask": jnp.ones((B, S), jnp.float32)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_reduced_config(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    opt = adamw(lr=1e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(lambda p_: loss_fn(cfg, p_, b))(p)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    p1, state, loss1 = step(params, state, batch)
    p2, state, loss2 = step(p1, state, batch)
    assert jnp.isfinite(loss1) and jnp.isfinite(loss2)
    assert float(loss2) < float(loss1)  # same batch twice: must improve
    for leaf in jax.tree.leaves(p2):
        assert jnp.all(jnp.isfinite(leaf))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if a != "whisper-medium"])
def test_reduced_decode_step(arch):
    cfg = get_reduced_config(arch)
    B = 2
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, 16)
    toks = jnp.ones((B, 1), jnp.int32)
    logits, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, toks)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
    assert int(cache["index"]) == 1
