"""Hypothesis property (the churn ISSUE acceptance criterion): for random
VG plans, dropped subsets D (possibly empty, possibly whole groups, down
to a single survivor), bits, update sizes, and DP modes, the recovered
survivor aggregate — on BOTH the serial survivor protocol and the
vectorized churn engine — is bit-identical to the maskless clean reference
over the survivors alone, with every survivor's DP key folded at its
full-cohort row."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import _secure_mean_survivors
from repro.core.virtual_groups import make_virtual_groups

from test_churn import clean_survivor_reference


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 18), vg_size=st.integers(2, 6),
       bits=st.integers(10, 24), size=st.integers(1, 60),
       mech=st.sampled_from(["off", "local", "global"]),
       noise=st.sampled_from([0.0, 0.8]),
       drop_bits=st.integers(0, (1 << 18) - 1),
       seed=st.integers(0, 10_000))
def test_recovered_aggregate_bit_identical(n, vg_size, bits, size, mech,
                                           noise, drop_bits, seed):
    rng = np.random.RandomState(seed)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, size).astype(np.float32)) for i in range(n)}
    cohort = sorted(updates)
    plan = make_virtual_groups(cohort, vg_size, seed=seed)
    # dropped set from the bitmask; force >= 1 survivor
    dropped = {cohort[j] for j in range(n) if (drop_bits >> j) & 1}
    if len(dropped) == n:
        dropped.discard(cohort[seed % n])
    survivors = [c for c in cohort if c not in dropped]
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    key = jax.random.PRNGKey(seed)
    # the property quantifies over ALL drop patterns (incl. single-survivor
    # groups), so the min_survivors_per_vg privacy floor is disabled here
    scfg = sa.SecureAggConfig(bits=bits, min_survivors_per_vg=1)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=noise)

    ref = clean_survivor_reference(updates, cohort, plan, dropped, key,
                                   scfg, dcfg)

    fold_of = {c: j for j, c in enumerate(cohort)}
    serial = _secure_mean_survivors({c: updates[c] for c in survivors},
                                    plan, round_seed, key, scfg, dcfg,
                                    fold_of)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(serial))

    alive = np.asarray([c not in dropped for c in cohort])
    flat = jnp.stack([updates[c] if alive[j]
                      else jnp.zeros(size, jnp.float32)
                      for j, c in enumerate(cohort)])
    stats = {}
    vect = pe.aggregate_flat(flat, plan, cohort, round_seed,
                             secure_cfg=scfg, dp_cfg=dcfg, key=key,
                             alive=alive, stats=stats)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(vect))
    assert stats["n_dropped"] == len(dropped)


@settings(deadline=None, max_examples=15)
@given(n=st.integers(3, 14), vg_size=st.integers(2, 5),
       drop_bits=st.integers(1, (1 << 14) - 1), seed=st.integers(0, 1000))
def test_residual_never_cancels_silently(n, vg_size, drop_bits, seed):
    """Complement of the parity property: whenever a group with >= 2
    survivors loses a member, the UNRECOVERED survivor sum differs from
    the clean survivor sum (the residual mask is non-zero) — i.e. the
    recovery step is doing real work, not a no-op."""
    rng = np.random.RandomState(seed)
    cohort = [f"c{i:03d}" for i in range(n)]
    plan = make_virtual_groups(cohort, vg_size, seed=seed)
    dropped = {cohort[j] for j in range(n) if (drop_bits >> j) & 1}
    scfg = sa.SecureAggConfig()
    rs = jnp.asarray([seed, seed ^ 977], jnp.uint32)
    size = 8
    for grp in plan.groups:
        g = len(grp.members)
        surv = [i for i, c in enumerate(grp.members) if c not in dropped]
        drop = [i for i in range(g) if i not in surv]
        if not drop or len(surv) < 2:
            continue
        gseed = sa.group_seed(rs, grp.vg_id)
        qs = [jnp.full(size, 7 * (i + 1), jnp.uint32) for i in range(g)]
        from repro.core.masking import apply_mask
        payloads = [apply_mask(qs[i], i, g, gseed) for i in range(g)]
        clean = sum(np.asarray(qs[i], np.uint64) for i in surv) % (1 << 32)
        naive = sum(np.asarray(payloads[i], np.uint64)
                    for i in surv) % (1 << 32)
        assert not np.array_equal(naive, clean)
        from repro.core.dropout import dropped_net_mask
        corr = dropped_net_mask(drop, surv, g, gseed, size)
        fixed = (naive + np.asarray(corr, np.uint64)) % (1 << 32)
        np.testing.assert_array_equal(fixed, clean)
