"""Vectorized whole-cohort masking (protect_cohort / vg_sums) and the
scaling-benchmark protocol invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.masking import (apply_mask, modular_sum, protect_cohort,
                                vg_sums)
from repro.core.quantize import dequantize_sum, quantize


@settings(deadline=None, max_examples=15)
@given(n_vgs=st.integers(1, 4), g=st.integers(2, 6),
       size=st.integers(1, 64), seed=st.integers(0, 999))
def test_protect_cohort_masks_cancel_per_vg(n_vgs, g, size, seed):
    rng = np.random.RandomState(seed)
    n = n_vgs * g
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    qs = jnp.asarray(rng.randint(0, 2**20, (n, size), dtype=np.uint32))
    payloads = protect_cohort(qs, g, round_seed)
    got = vg_sums(payloads, g)
    want = vg_sums(qs, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # individual payloads are masked whenever the client has peers
    if g > 1 and size >= 16:
        assert not np.array_equal(np.asarray(payloads[0]), np.asarray(qs[0]))


def test_protect_cohort_matches_per_client_path():
    rng = np.random.RandomState(3)
    n, g, size = 8, 4, 100
    seed = jnp.asarray([11, 13], jnp.uint32)
    qs = jnp.asarray(rng.randint(0, 2**18, (n, size), dtype=np.uint32))
    vec = protect_cohort(qs, g, seed)
    # per-client reference: client i is member i%g of VG i//g, with GLOBAL
    # ids — matches net_mask_traced semantics used in protect_cohort
    from repro.core.masking import net_mask_traced
    for i in range(n):
        ref = qs[i] + net_mask_traced(jnp.uint32(i), jnp.uint32(i // g), g,
                                      seed, size)
        np.testing.assert_array_equal(np.asarray(vec[i]), np.asarray(ref))


def test_dummy_task_end_to_end():
    """The Fig. 11-right protocol: all-ones size-5 arrays, aggregate."""
    n, g = 64, 8
    seed = jnp.asarray([1, 2], jnp.uint32)
    xs = jnp.ones((n, 5), jnp.float32)
    qs = quantize(xs, 1.0, 16)
    payloads = protect_cohort(qs, g, seed)
    total = jnp.sum(vg_sums(payloads, g), axis=0, dtype=jnp.uint32)
    mean = dequantize_sum(total, n, 1.0, 16)
    np.testing.assert_allclose(np.asarray(mean), 1.0, atol=1e-3)
