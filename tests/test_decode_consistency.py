"""Decode-with-cache must reproduce full-forward logits, per family.

MoE archs use a high capacity factor here: Switch-style capacity dispatch
drops overflow tokens in full-sequence mode (a documented train/infer
difference), which high capacity removes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.models import decode_step, init_cache, init_params
from repro.models.layers import unembed
from repro.models.model import forward_hidden

ARCHS = ["yi-9b", "gemma2-27b", "rwkv6-7b", "jamba-v0.1-52b",
         "qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b",
         "command-r-35b", "deepseek-67b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch).replace(capacity_factor=8.0)
    B, S = 2, 12
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _, _, _ = forward_hidden(cfg, params, {"tokens": toks}, remat=False)
    full_logits = unembed(cfg, params["embed"], h)
    cache = init_cache(cfg, B, S)
    errs = []
    for t in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-2, errs


def test_sliding_window_decode_consistency():
    """mistral-style window: decode must match windowed forward."""
    cfg = get_reduced_config("llava-next-mistral-7b").replace(
        sliding_window=4, num_patch_tokens=0, frontend="")
    B, S = 1, 10
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _, _, _ = forward_hidden(cfg, params, {"tokens": toks}, remat=False)
    full_logits = unembed(cfg, params["embed"], h)
    cache = init_cache(cfg, B, S)
    for t in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        err = float(jnp.max(jnp.abs(lg - full_logits[:, t])))
        assert err < 2e-2, (t, err)
