"""Vectorized privacy engine (repro.core.privacy_engine): bit-exact parity
with the serial reference across ragged plans, bits, and DP mechanisms;
the stage-2 overflow regression; bucket planning; the fused stacked entry;
and the batched kernel path. (No hypothesis dependency — the wider random
sweep lives in test_privacy_engine_property.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import (ClientResult, _secure_mean_serial,
                                     run_sync_round, run_sync_round_stacked)
from repro.core.strategies import FedAvg
from repro.core.virtual_groups import make_virtual_groups, pairwise_cost


def _updates(rng, n, shape=(6, 3)):
    return {f"c{i:03d}": {"w": jnp.asarray(
        rng.uniform(-0.6, 0.6, shape).astype(np.float32))}
        for i in range(n)}


def _both(updates, plan, seed, key, scfg, dcfg):
    serial = _secure_mean_serial(dict(sorted(updates.items())), plan, seed,
                                 key, sa.SecureAggConfig(bits=scfg.bits,
                                                         clip=scfg.clip),
                                 dcfg)
    vect = pe.PrivacyEngine(scfg, dcfg).aggregate_updates(
        updates, plan, seed, key=key)
    return serial, vect


# ---------------------------------------------------------------------------
# stage-2 overflow regression (ISSUE satellite 1)
# ---------------------------------------------------------------------------

def test_master_aggregate_no_stage2_overflow():
    """Stage-2 overflow regression: bits=28, vg=8, cohort=32 passes the
    per-group headroom check (28 + 3 = 31 <= 32) but the cohort TOTAL needs
    28 + 5 = 33 bits — the pre-fix master summed interims in uint32 and
    silently wrapped mod 2^32 (this exact case dequantized to 0.0 instead
    of 1.0). The split-limb combine keeps it exact."""
    bits, g, n = 28, 8, 32
    cfg = sa.SecureAggConfig(bits=bits)
    updates = {i: jnp.full(16, 1.0, jnp.float32) for i in range(n)}  # +clip
    plan = make_virtual_groups(list(updates), g, seed=0)
    agg = sa.secure_aggregate_round(updates, plan,
                                    jnp.asarray([1, 2], jnp.uint32), cfg)
    np.testing.assert_allclose(np.asarray(agg), 1.0, atol=1e-5)


def test_master_aggregate_large_cohort_small_bits():
    """4096+ clients at the default 20 bits (the ISSUE's wrap case) stays
    exact through the master combine."""
    from repro.core.quantize import dequantize_interim_sum
    bits, g = 20, 8
    n_groups = 520            # 4160 clients: 20 + ceil(log2(4160)) = 33 > 32
    n = n_groups * g
    # every client at the max code: interim = g * (2^bits - 1), exact
    interims = jnp.full((n_groups, 8), g * ((1 << bits) - 1), jnp.uint32)
    mean = dequantize_interim_sum(interims, n, 1.0, bits)
    np.testing.assert_allclose(np.asarray(mean), 1.0, atol=1e-5)


def test_master_group_count_guard():
    from repro.core.quantize import check_master_headroom
    check_master_headroom(65535)
    with pytest.raises(ValueError):
        check_master_headroom(1 << 16)


# ---------------------------------------------------------------------------
# hierarchical (two-tier) stage-2 combine (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------

def test_shard_count_guard():
    from repro.core.quantize import check_shard_headroom
    check_shard_headroom(65535)
    with pytest.raises(ValueError):
        check_shard_headroom(1 << 16)


def test_limb_state_merge_is_shard_layout_independent():
    """The canonical limb digits of the grand total do not depend on how
    the VG axis is partitioned — the property that makes every shard
    count bit-identical."""
    from repro.core.quantize import (interim_limb_state,  # noqa: F401
                                     merge_limb_states)
    rng = np.random.RandomState(0)
    interims = jnp.asarray(
        rng.randint(0, 1 << 32, (60, 9), dtype=np.uint64).astype(np.uint32))
    single = interim_limb_state(interims)
    for cuts in [(30,), (7, 31, 44), (1, 2, 3, 4, 59), tuple(range(1, 60))]:
        parts = np.split(np.asarray(interims), list(cuts))
        states = jnp.stack([interim_limb_state(jnp.asarray(p))
                            for p in parts])
        merged = merge_limb_states(states)
        np.testing.assert_array_equal(np.asarray(merged),
                                      np.asarray(single))
    # digits really are the exact total (checked in python ints)
    total = np.asarray(interims, np.uint64).sum(axis=0, dtype=np.uint64)
    digits = np.asarray(single, np.uint64)
    rebuilt = digits[0] + (digits[1] << 16) + (digits[2] << 32)
    np.testing.assert_array_equal(rebuilt, total)


def test_single_tier_wraps_past_2_16_groups_sharded_is_exact():
    """The >2^16-VG regression: the old single-tier combine either raises
    (guarded) or silently wraps mod 2^32 in its 16-bit half-sums
    (unguarded math); the sharded combine stays exact."""
    import sys
    qz = sys.modules["repro.core.quantize"]
    from repro.core import secure_agg as sa_mod
    G, size, bits = 1 << 17, 8, 20            # 131072 VGs of 2 clients
    n = 2 * G
    code = (1 << bits) - 1                    # every client at +clip
    interims = jnp.full((G, size), 2 * code, jnp.uint32)

    # guarded single-tier path refuses the plan
    with pytest.raises(ValueError):
        qz.check_master_headroom(G)
    with pytest.raises(ValueError):
        sa_mod.resolve_master_shards(G, sa_mod.SecureAggConfig(), 1)

    # the raw single-tier math WOULD wrap: its uint32 lo half-sum is
    # G * 0xFFFF-scale and exceeds 2^32 for G >= 2^17 at these codes
    wrapped = qz.dequantize_interim_sum(interims, n, 1.0, bits)
    assert not np.allclose(np.asarray(wrapped), 1.0, atol=1e-4)

    # the hierarchical route is exact (auto shard count, and explicit)
    for shards in [None, 4, 9]:
        cfg = sa_mod.SecureAggConfig(bits=bits)
        ns = sa_mod.resolve_master_shards(G, cfg, shards)
        per = -(-G // ns)
        states = jnp.stack([
            qz.interim_limb_state(interims[s * per:(s + 1) * per])
            for s in range(ns)])
        mean = sa_mod.combine_limb_states(states, n, cfg)
        np.testing.assert_allclose(np.asarray(mean), 1.0, atol=1e-5)


def test_sharded_pipeline_bit_identical_across_shard_counts():
    """aggregate_flat with explicit n_shards in {1..7} is bit-identical to
    the default route AND to the serial reference, across ragged plans
    and DP."""
    rng = np.random.RandomState(11)
    n = 19
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.1, 1.1, 33).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), 4, seed=3)   # ragged: merged
    seed = jnp.asarray([5, 6], jnp.uint32)
    key = jax.random.PRNGKey(2)
    dcfg = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                           noise_multiplier=0.7)
    scfg = sa.SecureAggConfig(bits=18)
    serial = _secure_mean_serial(dict(sorted(updates.items())), plan, seed,
                                 key, scfg, dcfg)
    cids = sorted(updates)
    flat = jnp.stack([updates[c] for c in cids])
    ref = pe.aggregate_flat(flat, plan, cids, seed, secure_cfg=scfg,
                            dp_cfg=dcfg, key=key)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(ref))
    for shards in range(1, 8):
        out = pe.aggregate_flat(flat, plan, cids, seed, secure_cfg=scfg,
                                dp_cfg=dcfg, key=key, n_shards=shards)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    # master_shards via config (the service-layer route) too
    out = pe.aggregate_flat(flat, plan, cids, seed,
                            secure_cfg=sa.SecureAggConfig(
                                bits=18, master_shards=3),
                            dp_cfg=dcfg, key=key)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_serial_master_aggregate_sharded_matches_single_tier():
    """The serial master's sharded route (list-of-interims entry) is
    bit-identical to its single-tier form."""
    rng = np.random.RandomState(4)
    interims = [jnp.asarray(rng.randint(0, 1 << 20, 13, dtype=np.int64)
                            .astype(np.uint32)) for _ in range(9)]
    sizes = [4] * 9
    unflatten = lambda x: x  # noqa: E731
    ref = sa.master_aggregate(interims, sizes, unflatten)
    for shards in [2, 3, 9]:
        out = sa.master_aggregate(interims, sizes, unflatten,
                                  n_shards=shards)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_full_pipeline_past_2_16_virtual_groups():
    """Acceptance: a cohort with > 2^16 VGs aggregates exactly via the
    sharded combine (the single-tier path rejects the same plan). Kept
    cheap: tiny rows, vg_size 2, DP off."""
    n_groups = (1 << 16) + 3
    n = 2 * n_groups
    size = 4
    rng = np.random.RandomState(0)
    base = rng.uniform(-0.9, 0.9, size).astype(np.float32)
    flat = jnp.broadcast_to(jnp.asarray(base), (n, size))
    cids = list(range(n))
    plan = make_virtual_groups(cids, 2, seed=0)
    assert len(plan.groups) > (1 << 16)
    seed = jnp.asarray([9, 1], jnp.uint32)
    with pytest.raises(ValueError):
        pe.aggregate_flat(flat, plan, cids, seed, n_shards=1)
    out = pe.aggregate_flat(flat, plan, cids, seed)
    from repro.core.quantize import quantization_resolution
    np.testing.assert_allclose(np.asarray(out), base,
                               atol=2 * quantization_resolution())


# ---------------------------------------------------------------------------
# 4-limb stage-2 variant (churn-ISSUE satellite; ROADMAP >2^32-VG headroom)
# ---------------------------------------------------------------------------

class TestFourLimb:
    def test_limb_state_variants_bit_parity_within_bound(self):
        """Within the 3-limb representable bound the 4-limb state carries
        the SAME canonical digits (plus a zero top lane), and the float
        tail dequantizes bit-identically."""
        import sys
        qz = sys.modules["repro.core.quantize"]
        rng = np.random.RandomState(3)
        interims = jnp.asarray(rng.randint(
            0, 1 << 32, (77, 11), dtype=np.uint64).astype(np.uint32))
        for shards in (1, 3, 8):
            s3 = qz.shard_limb_states(interims, shards, 3)
            s4 = qz.shard_limb_states(interims, shards, 4)
            m3 = qz.merge_limb_states(s3)
            m4 = qz.merge_limb_states(s4)
            np.testing.assert_array_equal(np.asarray(m4[:2]),
                                          np.asarray(m3[:2]))
            # 3-limb top lane == canonical l2 + l3 recombined
            np.testing.assert_array_equal(
                np.asarray(m3[2], np.uint64),
                np.asarray(m4[2], np.uint64)
                + (np.asarray(m4[3], np.uint64) << 16))
            f3 = sa._finalize_jit(m3, 616, 1.0, 20)
            f4 = sa._finalize_jit(m4, 616, 1.0, 20)
            np.testing.assert_array_equal(np.asarray(f3), np.asarray(f4))

    def test_limb_digits_exact_against_python_ints(self):
        import sys
        qz = sys.modules["repro.core.quantize"]
        rng = np.random.RandomState(4)
        interims = jnp.asarray(rng.randint(
            0, 1 << 32, (40, 6), dtype=np.uint64).astype(np.uint32))
        m4 = qz.merge_limb_states(qz.shard_limb_states(interims, 5, 4))
        d = np.asarray(m4, np.uint64)
        rebuilt = d[0] + (d[1] << 16) + (d[2] << 32) + (d[3] << 48)
        np.testing.assert_array_equal(
            rebuilt, np.asarray(interims, np.uint64).sum(axis=0))

    def test_pipeline_with_limbs_4_bit_identical(self):
        """SecureAggConfig(limbs=4) routes the whole engine through the
        4-lane states and still matches the serial 3-limb reference."""
        rng = np.random.RandomState(6)
        updates = {f"c{i:03d}": jnp.asarray(
            rng.uniform(-1.1, 1.1, 31).astype(np.float32))
            for i in range(14)}
        plan = make_virtual_groups(list(updates), 4, seed=2)
        seed = jnp.asarray([8, 1], jnp.uint32)
        key = jax.random.PRNGKey(3)
        dcfg = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                               noise_multiplier=0.6)
        serial = _secure_mean_serial(
            dict(sorted(updates.items())), plan, seed, key,
            sa.SecureAggConfig(), dcfg)
        cids = sorted(updates)
        flat = jnp.stack([updates[c] for c in cids])
        for shards in (None, 3):
            out = pe.aggregate_flat(
                flat, plan, cids, seed,
                secure_cfg=sa.SecureAggConfig(limbs=4), dp_cfg=dcfg,
                key=key, n_shards=shards)
            np.testing.assert_array_equal(np.asarray(serial),
                                          np.asarray(out))

    def test_serial_master_with_limbs_4(self):
        rng = np.random.RandomState(5)
        interims = [jnp.asarray(rng.randint(0, 1 << 20, 9, dtype=np.int64)
                                .astype(np.uint32)) for _ in range(7)]
        ref = sa.master_aggregate(interims, [4] * 7, lambda x: x)
        out = sa.master_aggregate(interims, [4] * 7, lambda x: x,
                                  sa.SecureAggConfig(limbs=4))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))

    def test_invalid_limb_count_rejected(self):
        import sys
        qz = sys.modules["repro.core.quantize"]
        with pytest.raises(ValueError, match="n_limbs"):
            qz.interim_limb_state(jnp.zeros((3, 4), jnp.uint32), 5)


# ---------------------------------------------------------------------------
# cost model consistency (ISSUE satellite 2) — deterministic sweep
# ---------------------------------------------------------------------------

def test_pairwise_cost_matches_real_plans_sweep():
    """pairwise_cost must price the plan make_virtual_groups actually
    builds, including the remainder-merge rule."""
    for g in (2, 3, 4, 5, 8, 16, 32):
        for n in range(1, 121):
            plan = make_virtual_groups(range(n), g, seed=0)
            actual = sum(len(grp.members) * (len(grp.members) - 1)
                         for grp in plan.groups)
            assert pairwise_cost(n, g) == actual, (n, g)


# ---------------------------------------------------------------------------
# bucket planning
# ---------------------------------------------------------------------------

def test_plan_buckets_two_shapes_max():
    """The merge rule yields at most two group sizes -> <= 2 buckets."""
    for n in range(1, 40):
        cids = [f"c{i:03d}" for i in range(n)]
        plan = make_virtual_groups(cids, 4, seed=n)
        buckets = pe.plan_buckets(plan, cids)
        assert 1 <= len(buckets) <= 2
        rows = [r for b in buckets for r in b.rows]
        assert sorted(rows) == list(range(n))
        for b in buckets:
            assert len(b.rows) == b.g * b.n_groups


def test_plan_buckets_rejects_duplicates():
    plan = make_virtual_groups(["a", "b"], 2, seed=0)
    with pytest.raises(ValueError):
        pe.plan_buckets(plan, ["a", "a"])


# ---------------------------------------------------------------------------
# parity: deterministic sweep (the hypothesis version adds random coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,vg_size,bits,mech,noise", [
    (12, 4, 20, "off", 0.0),     # uniform groups
    (13, 4, 20, "off", 0.0),     # ragged: trailing remainder merges
    (11, 4, 16, "local", 0.9),   # ragged + local DP noise
    (11, 4, 16, "local", 0.0),   # clip-only local DP
    (10, 3, 24, "global", 0.5),  # global mechanism (clip per client)
    (7, 16, 12, "off", 0.0),     # single group larger than cohort
    (1, 4, 20, "local", 0.5),    # single-client cohort
])
def test_vectorized_bit_identical_to_serial(n, vg_size, bits, mech, noise):
    rng = np.random.RandomState(n * 100 + bits)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, 57).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), vg_size, seed=n)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    key = jax.random.PRNGKey(n)
    scfg = sa.SecureAggConfig(bits=bits)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=noise)
    serial, vect = _both(updates, plan, round_seed, key, scfg, dcfg)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))


def test_kernel_path_bit_identical():
    """use_kernels routes mask expansion through the batched Pallas kernel;
    wrapping-add order-independence keeps the result bit-identical."""
    rng = np.random.RandomState(3)
    updates = _updates(rng, 13)
    plan = make_virtual_groups(list(updates), 4, seed=0)  # ragged: merged 5
    seed = jnp.asarray([9, 9], jnp.uint32)
    key = jax.random.PRNGKey(0)
    dcfg = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                           noise_multiplier=0.6)
    serial, vect = _both(updates, plan, seed, key,
                         sa.SecureAggConfig(use_kernels=True), dcfg)
    np.testing.assert_array_equal(np.asarray(serial["w"]),
                                  np.asarray(vect["w"]))


# ---------------------------------------------------------------------------
# fused stacked entry + round-level wiring
# ---------------------------------------------------------------------------

def test_run_sync_round_vectorized_matches_serial():
    """The orchestrator's default fast path reproduces the serial round
    bit-exactly (same strategy update on a bit-identical delta)."""
    rng = np.random.RandomState(5)
    updates = _updates(rng, 10)
    results = {c: ClientResult(update=u, n_samples=4, metrics={"loss": 1.0})
               for c, u in updates.items()}
    params = {"w": jnp.zeros((6, 3), jnp.float32)}
    strat = FedAvg(server_lr=1.0)
    for dcfg in [dp_mod.DPConfig(),
                 dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                                 noise_multiplier=0.4),
                 dp_mod.DPConfig(mechanism="global", clip_norm=0.5,
                                 noise_multiplier=0.4)]:
        p_v, _, _ = run_sync_round(
            params, strat, strat.init_state(params), results,
            round_idx=2, vg_size=4, dp_cfg=dcfg,
            secure_cfg=sa.SecureAggConfig(vectorized=True))
        p_s, _, _ = run_sync_round(
            params, strat, strat.init_state(params), results,
            round_idx=2, vg_size=4, dp_cfg=dcfg,
            secure_cfg=sa.SecureAggConfig(vectorized=False))
        np.testing.assert_array_equal(np.asarray(p_v["w"]),
                                      np.asarray(p_s["w"]))


def test_stacked_round_matches_dict_round():
    """The fused entry (stacked leaves, no per-client dicts) is the same
    round as the dict path — including out-of-order client rows."""
    rng = np.random.RandomState(6)
    updates = _updates(rng, 9)
    cids = list(updates)
    results = {c: ClientResult(update=updates[c], n_samples=4,
                               metrics={"loss": 2.0}) for c in cids}
    params = {"w": jnp.zeros((6, 3), jnp.float32)}
    strat = FedAvg(server_lr=1.0)
    p_d, _, info_d = run_sync_round(
        params, strat, strat.init_state(params), results,
        round_idx=1, vg_size=4)
    # reversed order: run_sync_round_stacked must re-sort rows internally
    rev = list(reversed(cids))
    stacked = {"w": jnp.stack([updates[c]["w"] for c in rev])}
    p_s, _, info_s = run_sync_round_stacked(
        params, strat, strat.init_state(params), rev, stacked,
        [{"loss": 2.0}] * len(rev), round_idx=1, vg_size=4)
    np.testing.assert_array_equal(np.asarray(p_d["w"]), np.asarray(p_s["w"]))
    assert info_d.metrics == info_s.metrics
    assert info_d.n_groups == info_s.n_groups


def test_aggregate_stacked_multi_leaf():
    rng = np.random.RandomState(7)
    n = 8
    updates = {f"c{i}": {"a": jnp.asarray(rng.uniform(-1, 1, (3, 2)),
                                          jnp.float32),
                         "b": jnp.asarray(rng.uniform(-1, 1, 5),
                                          jnp.float32)}
               for i in range(n)}
    cids = sorted(updates)
    plan = make_virtual_groups(cids, 4, seed=0)
    seed = jnp.asarray([4, 2], jnp.uint32)
    stacked = {"a": jnp.stack([updates[c]["a"] for c in cids]),
               "b": jnp.stack([updates[c]["b"] for c in cids])}
    fused = pe.aggregate_stacked(stacked, plan, cids, seed)
    ref = pe.PrivacyEngine().aggregate_updates(updates, plan, seed)
    np.testing.assert_array_equal(np.asarray(fused["a"]),
                                  np.asarray(ref["a"]))
    np.testing.assert_array_equal(np.asarray(fused["b"]),
                                  np.asarray(ref["b"]))
