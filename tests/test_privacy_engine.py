"""Vectorized privacy engine (repro.core.privacy_engine): bit-exact parity
with the serial reference across ragged plans, bits, and DP mechanisms;
the stage-2 overflow regression; bucket planning; the fused stacked entry;
and the batched kernel path. (No hypothesis dependency — the wider random
sweep lives in test_privacy_engine_property.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import (ClientResult, _secure_mean_serial,
                                     run_sync_round, run_sync_round_stacked)
from repro.core.strategies import FedAvg
from repro.core.virtual_groups import make_virtual_groups, pairwise_cost


def _updates(rng, n, shape=(6, 3)):
    return {f"c{i:03d}": {"w": jnp.asarray(
        rng.uniform(-0.6, 0.6, shape).astype(np.float32))}
        for i in range(n)}


def _both(updates, plan, seed, key, scfg, dcfg):
    serial = _secure_mean_serial(dict(sorted(updates.items())), plan, seed,
                                 key, sa.SecureAggConfig(bits=scfg.bits,
                                                         clip=scfg.clip),
                                 dcfg)
    vect = pe.PrivacyEngine(scfg, dcfg).aggregate_updates(
        updates, plan, seed, key=key)
    return serial, vect


# ---------------------------------------------------------------------------
# stage-2 overflow regression (ISSUE satellite 1)
# ---------------------------------------------------------------------------

def test_master_aggregate_no_stage2_overflow():
    """Stage-2 overflow regression: bits=28, vg=8, cohort=32 passes the
    per-group headroom check (28 + 3 = 31 <= 32) but the cohort TOTAL needs
    28 + 5 = 33 bits — the pre-fix master summed interims in uint32 and
    silently wrapped mod 2^32 (this exact case dequantized to 0.0 instead
    of 1.0). The split-limb combine keeps it exact."""
    bits, g, n = 28, 8, 32
    cfg = sa.SecureAggConfig(bits=bits)
    updates = {i: jnp.full(16, 1.0, jnp.float32) for i in range(n)}  # +clip
    plan = make_virtual_groups(list(updates), g, seed=0)
    agg = sa.secure_aggregate_round(updates, plan,
                                    jnp.asarray([1, 2], jnp.uint32), cfg)
    np.testing.assert_allclose(np.asarray(agg), 1.0, atol=1e-5)


def test_master_aggregate_large_cohort_small_bits():
    """4096+ clients at the default 20 bits (the ISSUE's wrap case) stays
    exact through the master combine."""
    from repro.core.quantize import dequantize_interim_sum
    bits, g = 20, 8
    n_groups = 520            # 4160 clients: 20 + ceil(log2(4160)) = 33 > 32
    n = n_groups * g
    # every client at the max code: interim = g * (2^bits - 1), exact
    interims = jnp.full((n_groups, 8), g * ((1 << bits) - 1), jnp.uint32)
    mean = dequantize_interim_sum(interims, n, 1.0, bits)
    np.testing.assert_allclose(np.asarray(mean), 1.0, atol=1e-5)


def test_master_group_count_guard():
    from repro.core.quantize import check_master_headroom
    check_master_headroom(65535)
    with pytest.raises(ValueError):
        check_master_headroom(1 << 16)


# ---------------------------------------------------------------------------
# cost model consistency (ISSUE satellite 2) — deterministic sweep
# ---------------------------------------------------------------------------

def test_pairwise_cost_matches_real_plans_sweep():
    """pairwise_cost must price the plan make_virtual_groups actually
    builds, including the remainder-merge rule."""
    for g in (2, 3, 4, 5, 8, 16, 32):
        for n in range(1, 121):
            plan = make_virtual_groups(range(n), g, seed=0)
            actual = sum(len(grp.members) * (len(grp.members) - 1)
                         for grp in plan.groups)
            assert pairwise_cost(n, g) == actual, (n, g)


# ---------------------------------------------------------------------------
# bucket planning
# ---------------------------------------------------------------------------

def test_plan_buckets_two_shapes_max():
    """The merge rule yields at most two group sizes -> <= 2 buckets."""
    for n in range(1, 40):
        cids = [f"c{i:03d}" for i in range(n)]
        plan = make_virtual_groups(cids, 4, seed=n)
        buckets = pe.plan_buckets(plan, cids)
        assert 1 <= len(buckets) <= 2
        rows = [r for b in buckets for r in b.rows]
        assert sorted(rows) == list(range(n))
        for b in buckets:
            assert len(b.rows) == b.g * b.n_groups


def test_plan_buckets_rejects_duplicates():
    plan = make_virtual_groups(["a", "b"], 2, seed=0)
    with pytest.raises(ValueError):
        pe.plan_buckets(plan, ["a", "a"])


# ---------------------------------------------------------------------------
# parity: deterministic sweep (the hypothesis version adds random coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,vg_size,bits,mech,noise", [
    (12, 4, 20, "off", 0.0),     # uniform groups
    (13, 4, 20, "off", 0.0),     # ragged: trailing remainder merges
    (11, 4, 16, "local", 0.9),   # ragged + local DP noise
    (11, 4, 16, "local", 0.0),   # clip-only local DP
    (10, 3, 24, "global", 0.5),  # global mechanism (clip per client)
    (7, 16, 12, "off", 0.0),     # single group larger than cohort
    (1, 4, 20, "local", 0.5),    # single-client cohort
])
def test_vectorized_bit_identical_to_serial(n, vg_size, bits, mech, noise):
    rng = np.random.RandomState(n * 100 + bits)
    updates = {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, 57).astype(np.float32)) for i in range(n)}
    plan = make_virtual_groups(list(updates), vg_size, seed=n)
    round_seed = jnp.asarray(rng.randint(0, 2**31, 2), jnp.uint32)
    key = jax.random.PRNGKey(n)
    scfg = sa.SecureAggConfig(bits=bits)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=noise)
    serial, vect = _both(updates, plan, round_seed, key, scfg, dcfg)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))


def test_kernel_path_bit_identical():
    """use_kernels routes mask expansion through the batched Pallas kernel;
    wrapping-add order-independence keeps the result bit-identical."""
    rng = np.random.RandomState(3)
    updates = _updates(rng, 13)
    plan = make_virtual_groups(list(updates), 4, seed=0)  # ragged: merged 5
    seed = jnp.asarray([9, 9], jnp.uint32)
    key = jax.random.PRNGKey(0)
    dcfg = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                           noise_multiplier=0.6)
    serial, vect = _both(updates, plan, seed, key,
                         sa.SecureAggConfig(use_kernels=True), dcfg)
    np.testing.assert_array_equal(np.asarray(serial["w"]),
                                  np.asarray(vect["w"]))


# ---------------------------------------------------------------------------
# fused stacked entry + round-level wiring
# ---------------------------------------------------------------------------

def test_run_sync_round_vectorized_matches_serial():
    """The orchestrator's default fast path reproduces the serial round
    bit-exactly (same strategy update on a bit-identical delta)."""
    rng = np.random.RandomState(5)
    updates = _updates(rng, 10)
    results = {c: ClientResult(update=u, n_samples=4, metrics={"loss": 1.0})
               for c, u in updates.items()}
    params = {"w": jnp.zeros((6, 3), jnp.float32)}
    strat = FedAvg(server_lr=1.0)
    for dcfg in [dp_mod.DPConfig(),
                 dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                                 noise_multiplier=0.4),
                 dp_mod.DPConfig(mechanism="global", clip_norm=0.5,
                                 noise_multiplier=0.4)]:
        p_v, _, _ = run_sync_round(
            params, strat, strat.init_state(params), results,
            round_idx=2, vg_size=4, dp_cfg=dcfg,
            secure_cfg=sa.SecureAggConfig(vectorized=True))
        p_s, _, _ = run_sync_round(
            params, strat, strat.init_state(params), results,
            round_idx=2, vg_size=4, dp_cfg=dcfg,
            secure_cfg=sa.SecureAggConfig(vectorized=False))
        np.testing.assert_array_equal(np.asarray(p_v["w"]),
                                      np.asarray(p_s["w"]))


def test_stacked_round_matches_dict_round():
    """The fused entry (stacked leaves, no per-client dicts) is the same
    round as the dict path — including out-of-order client rows."""
    rng = np.random.RandomState(6)
    updates = _updates(rng, 9)
    cids = list(updates)
    results = {c: ClientResult(update=updates[c], n_samples=4,
                               metrics={"loss": 2.0}) for c in cids}
    params = {"w": jnp.zeros((6, 3), jnp.float32)}
    strat = FedAvg(server_lr=1.0)
    p_d, _, info_d = run_sync_round(
        params, strat, strat.init_state(params), results,
        round_idx=1, vg_size=4)
    # reversed order: run_sync_round_stacked must re-sort rows internally
    rev = list(reversed(cids))
    stacked = {"w": jnp.stack([updates[c]["w"] for c in rev])}
    p_s, _, info_s = run_sync_round_stacked(
        params, strat, strat.init_state(params), rev, stacked,
        [{"loss": 2.0}] * len(rev), round_idx=1, vg_size=4)
    np.testing.assert_array_equal(np.asarray(p_d["w"]), np.asarray(p_s["w"]))
    assert info_d.metrics == info_s.metrics
    assert info_d.n_groups == info_s.n_groups


def test_aggregate_stacked_multi_leaf():
    rng = np.random.RandomState(7)
    n = 8
    updates = {f"c{i}": {"a": jnp.asarray(rng.uniform(-1, 1, (3, 2)),
                                          jnp.float32),
                         "b": jnp.asarray(rng.uniform(-1, 1, 5),
                                          jnp.float32)}
               for i in range(n)}
    cids = sorted(updates)
    plan = make_virtual_groups(cids, 4, seed=0)
    seed = jnp.asarray([4, 2], jnp.uint32)
    stacked = {"a": jnp.stack([updates[c]["a"] for c in cids]),
               "b": jnp.stack([updates[c]["b"] for c in cids])}
    fused = pe.aggregate_stacked(stacked, plan, cids, seed)
    ref = pe.PrivacyEngine().aggregate_updates(updates, plan, seed)
    np.testing.assert_array_equal(np.asarray(fused["a"]),
                                  np.asarray(ref["a"]))
    np.testing.assert_array_equal(np.asarray(fused["b"]),
                                  np.asarray(ref["b"]))
