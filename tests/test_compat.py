"""JAX 0.4/0.5 compat shims: ambient-mesh introspection must behave
identically across API generations, and the §Perf with-sharding-constraint
helpers must be exact no-ops on unmeshed CPU under BOTH the old
(physical_mesh) and new (get_abstract_mesh) APIs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_config
from repro.launch.fl_step import _mb_constraint
from repro.launch.mesh import make_host_mesh
from repro.models.attention import _shard_heads
from repro.models.model import _constrain_batch_axis
from repro.models.moe import _constrain


class _FakeMesh:
    def __init__(self, names=("data",), sizes=(1,)):
        self.axis_names = names
        self.axis_sizes = sizes


def test_unmeshed_returns_none():
    assert compat.get_abstract_mesh() is None
    assert compat.mesh_axis_sizes(None) == {}


def test_mesh_context_visible():
    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        got = compat.get_abstract_mesh()
        assert got is not None
        assert set(got.axis_names) >= {"data", "model"}
        sizes = compat.mesh_axis_sizes(got)
        assert sizes["data"] * sizes["model"] == len(jax.devices())
    assert compat.get_abstract_mesh() is None


def test_new_api_preferred_when_present(monkeypatch):
    fake = _FakeMesh(("pod", "data"), (2, 8))
    monkeypatch.setattr(jax.sharding, "get_abstract_mesh", lambda: fake,
                        raising=False)
    assert compat.get_abstract_mesh() is fake
    assert compat.mesh_axis_sizes(fake) == {"pod": 2, "data": 8}


def test_new_api_empty_sentinel_falls_through(monkeypatch):
    # 0.5's AbstractMesh() "no mesh" sentinel has no axes -> treated as
    # unmeshed (the 0.4 physical-mesh fallback is also empty here).
    monkeypatch.setattr(jax.sharding, "get_abstract_mesh",
                        lambda: _FakeMesh((), ()), raising=False)
    assert compat.get_abstract_mesh() is None


@pytest.mark.parametrize("api", ["old", "new_none", "new_empty"])
def test_constraint_helpers_noop_unmeshed(monkeypatch, api):
    """model/attention/moe/fl_step mesh-constraint helpers: identity on
    unmeshed CPU regardless of which JAX mesh API is available."""
    if api == "new_none":
        monkeypatch.setattr(jax.sharding, "get_abstract_mesh", lambda: None,
                            raising=False)
    elif api == "new_empty":
        monkeypatch.setattr(jax.sharding, "get_abstract_mesh",
                            lambda: _FakeMesh((), ()), raising=False)

    cfg = get_config("bert-tiny-spam").replace(
        activation_batch_axes=("data",), shard_attn_heads=True,
        moe_dispatch_constraint=True)

    x = jnp.ones((4, 8, 16))
    np.testing.assert_array_equal(np.asarray(_constrain_batch_axis(cfg, x)),
                                  np.asarray(x))
    t = jnp.ones((2, 8, 4, 8))
    np.testing.assert_array_equal(np.asarray(_shard_heads(cfg, t)),
                                  np.asarray(t))
    np.testing.assert_array_equal(
        np.asarray(_constrain(cfg, x, (None, "model", None))), np.asarray(x))
    cfg_pod = cfg.replace(fl_scheme="per_pod")
    f = _mb_constraint(cfg_pod)
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_make_mesh_works_without_axis_types():
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    assert mesh.shape["data"] == len(jax.devices())
