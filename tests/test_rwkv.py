"""RWKV6 chunked-parallel form vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv import (CHUNK, _wkv_chunked, rwkv_scan_reference)


@pytest.mark.parametrize("T", [1, 7, CHUNK, 3 * CHUNK, 100])
@pytest.mark.parametrize("decay_scale", [0.1, 1.0])
def test_chunked_matches_scan(T, decay_scale):
    B, H, hd = 2, 3, 8
    rng = np.random.RandomState(0)
    r = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
    logw = -jnp.asarray(
        rng.uniform(0.01, decay_scale, (B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.randn(H, hd).astype(np.float32)) * 0.2
    s0 = jnp.asarray(rng.randn(B, H, hd, hd).astype(np.float32)) * 0.1

    o_c, s_c = _wkv_chunked(r, k, v, logw, u, s0)
    o_r, s_r = rwkv_scan_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


def test_chunked_state_carry_composes():
    """running two half-sequences with carried state == one full run."""
    B, T, H, hd = 1, 2 * CHUNK, 2, 8
    rng = np.random.RandomState(1)
    args = [jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.3
            for _ in range(3)]
    logw = -jnp.asarray(rng.uniform(0.01, 0.5, (B, T, H, hd))
                        .astype(np.float32))
    u = jnp.asarray(rng.randn(H, hd).astype(np.float32)) * 0.2
    s0 = jnp.zeros((B, H, hd, hd))
    o_full, s_full = _wkv_chunked(*args, logw, u, s0)
    h = T // 2
    o1, s1 = _wkv_chunked(*(a[:, :h] for a in args), logw[:, :h], u, s0)
    o2, s2 = _wkv_chunked(*(a[:, h:] for a in args), logw[:, h:], u, s1)
    np.testing.assert_allclose(np.asarray(o_full),
                               np.asarray(jnp.concatenate([o1, o2], 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)
