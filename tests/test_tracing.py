"""Flight-recorder layer (ISSUE 10): span tracer semantics, Perfetto
export, flight transcripts, the jit-cache probe's recompile-regression
gates (async batch pad classes, streaming-wave width), the typed metrics
registry, MetricsStore whole-store persistence, and the traced == untraced
bit-identity contract."""
import json
import pickle
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tracing
from repro.fl.telemetry import (FIXED_BUCKETS, MetricsRegistry,
                                MetricsStore)


def _flatten(span):
    out = [span]
    for c in span.children:
        out.extend(_flatten(c))
    return out


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_null_tracer_is_default_and_free():
    assert not tracing.enabled()
    sp = tracing.span("anything", task=1)
    with sp as inner:
        assert inner is sp
        assert inner.set(x=1) is inner
        assert inner.mark_fused("a", "b") is inner
    # shared singleton: no allocation per span site
    assert tracing.span("other") is sp


def test_span_nesting_and_attrs():
    with tracing.use_tracer(tracing.Tracer()) as tr:
        with tracing.span("round", task=3) as outer:
            with tracing.span("inner_a") as a:
                a.set(n=7)
            with tracing.span("inner_b"):
                pass
        roots = tr.roots()
    assert [r.name for r in roots] == ["round"]
    assert roots[0].attrs == {"task": 3}
    assert [c.name for c in roots[0].children] == ["inner_a", "inner_b"]
    assert roots[0].children[0].attrs == {"n": 7}
    assert outer.wall_s >= 0.0 and outer.cpu_s >= 0.0
    a, b = roots[0].children
    assert outer.t0 <= a.t0 <= a.t1 <= b.t1 <= outer.t1


def test_mark_fused_emits_shared_window_children():
    with tracing.use_tracer(tracing.Tracer()) as tr:
        with tracing.span("dispatch") as sp:
            sp.mark_fused("dp", "quantize", "mask")
    (root,) = tr.roots()
    assert [c.name for c in root.children] == ["dp", "quantize", "mask"]
    for c in root.children:
        assert c.fused and c.attrs["fused"] is True
        assert (c.t0, c.t1) == (root.t0, root.t1)


def test_thread_safety_separate_stacks():
    tr = tracing.Tracer()
    errs = []

    def spans(i):
        try:
            with tr.span("outer", thread=i):
                with tr.span("inner", thread=i):
                    pass
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    with tracing.use_tracer(tr):
        threads = [threading.Thread(target=spans, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errs
    roots = tr.roots()
    assert len(roots) == 8
    for r in roots:
        # nesting stayed per-thread: exactly one child, same thread tag
        assert [c.name for c in r.children] == ["inner"]
        assert r.children[0].attrs["thread"] == r.attrs["thread"]


def test_max_spans_cap_counts_drops():
    tr = tracing.Tracer(max_spans=2)
    with tracing.use_tracer(tr):
        for _ in range(5):
            with tr.span("s"):
                pass
    assert len(tr.roots()) == 2 and tr.n_dropped == 3


def test_tracer_pickle_round_trip():
    tr = tracing.Tracer()
    with tracing.use_tracer(tr):
        with tr.span("kept", k=1):
            pass
    tr2 = pickle.loads(pickle.dumps(tr))
    assert [r.name for r in tr2.roots()] == ["kept"]
    # lock/tls were dropped and recreated: the copy still collects
    with tracing.use_tracer(tr2):
        with tr2.span("more"):
            pass
    assert [r.name for r in tr2.roots()] == ["kept", "more"]


def test_use_tracer_restores_previous():
    prev = tracing.get_tracer()
    with tracing.use_tracer(tracing.Tracer()):
        assert tracing.enabled()
    assert tracing.get_tracer() is prev


def test_perfetto_export_structure(tmp_path):
    tr = tracing.Tracer()
    with tracing.use_tracer(tr):
        with tr.span("round", task=1):
            with tr.span("aggregate") as sp:
                sp.mark_fused("dp")
    path = tr.export_perfetto(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert [e["name"] for e in xs] == ["round", "aggregate", "dp"]
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 0
        assert "cpu_ms" in e["args"]
    metas = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert any(m["name"] == "process_name" for m in metas)
    # fused children are synthesized on exit, not pushed: 2 real spans
    assert doc["otherData"]["n_spans"] == 2


def test_stage_list_offsets_and_depth():
    tr = tracing.Tracer()
    with tracing.use_tracer(tr):
        with tr.span("round") as root:
            with tr.span("a"):
                with tr.span("b"):
                    pass
    rows = tracing.stage_list(root)
    assert [(r["name"], r["depth"]) for r in rows] == \
        [("round", 0), ("a", 1), ("b", 2)]
    assert rows[0]["t0_ms"] == 0.0
    assert rows[1]["t0_ms"] <= rows[2]["t0_ms"]


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_round_trip(tmp_path):
    fl = tracing.FlightRecorder(str(tmp_path / "flight"))
    assert fl.read(1) == [] and fl.task_ids() == []
    tr = tracing.Tracer()
    with tracing.use_tracer(tr):
        with tr.span("round") as root:
            with tr.span("aggregate"):
                pass
    fl.record(1, tracing.round_event(
        round_idx=0, cohort=["a", "b", "c"], survivors=["a", "b"],
        n_shards=2, stage2_route="churn_recovery", span_tree=root,
        metrics={"n_selected": 3}))
    fl.record(1, tracing.round_event(
        round_idx=1, cohort=["a"], survivors=[], voided=True,
        void_reason="all_dropped"))
    events = fl.read(1)
    assert fl.task_ids() == [1]
    assert events[0]["event"] == "round"
    assert events[0]["cohort"] == ["a", "b", "c"]
    assert events[0]["survivors"] == ["a", "b"]
    assert events[0]["n_dropped"] == 1
    assert events[0]["stage2_route"] == "churn_recovery"
    assert events[0]["n_shards"] == 2
    assert events[0]["metrics"] == {"n_selected": 3}
    assert [s["name"] for s in events[0]["stages"]] == \
        ["round", "aggregate"]
    assert events[0]["wall_ms"] >= 0 and "ts_unix" in events[0]
    assert events[1]["event"] == "round_voided"
    assert events[1]["void_reason"] == "all_dropped"
    assert "stages" not in events[1]

    doc = tracing.perfetto_from_flight(events, 1)
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    # round 0's two stages plus round 1's single voided block
    assert [e["name"] for e in xs] == ["round", "aggregate",
                                      "round_voided"]


def test_round_event_null_span_has_no_stages():
    ev = tracing.round_event(round_idx=0, cohort=["a"], survivors=["a"],
                             span_tree=tracing.span("nope"))
    assert "stages" not in ev and "wall_ms" not in ev


# ---------------------------------------------------------------------------
# jit cache probe + recompile-regression gates
# ---------------------------------------------------------------------------

def test_register_jit_counts_executables():
    fn = jax.jit(lambda x: x * 2)
    if not hasattr(fn, "_cache_size"):
        pytest.skip("jax build exposes no _cache_size")
    tracing.register_jit("test_probe.double", fn)
    try:
        base = tracing.jit_cache_sizes().get("test_probe.double", 0)
        fn(jnp.zeros(4))
        fn(jnp.zeros(8))
        assert tracing.jit_cache_sizes()["test_probe.double"] == base + 2
        fn(jnp.zeros(8))   # cache hit: no growth
        assert tracing.jit_cache_sizes()["test_probe.double"] == base + 2
    finally:
        tracing._DYNAMIC_JITS.pop(("test_probe.double", id(fn)), None)


def test_async_pad_classes_no_recompile_second_batch():
    """PR-4 fixed-shape contract, now pinned by the probe: once one
    same-shape batch (pad class) and one drain have compiled, further
    same-shape batches — including on a FRESH server — add ZERO compiled
    executables across the shared jitted entry points."""
    from repro.core.dp import DPConfig
    from repro.core.orchestrator import AsyncServer
    from repro.core.strategies import FedBuff

    def mk():
        return AsyncServer(
            {"w": jnp.zeros(16, jnp.float32)},
            FedBuff(buffer_size=4),
            DPConfig(mechanism="local", clip_norm=0.5,
                     noise_multiplier=1.0))

    rng = np.random.RandomState(0)

    def batch(server):
        rows = jnp.asarray(rng.uniform(-1, 1, (3, 16)), jnp.float32)
        server.submit_batch(rows, [1.0] * 3, [0] * 3)

    server = mk()
    batch(server)             # warm the 3-row pad class (buffer at 3)
    batch(server)             # warm the drain (fills at 4, 2 left over)
    before = tracing.jit_cache_total()
    batch(server)             # 5 -> drain -> 1: same shapes throughout
    batch(server)             # 4 -> drain -> 0
    batch(mk())               # a fresh server reuses the shared jits too
    assert tracing.jit_cache_total() == before


def test_wave_width_no_recompile_second_round():
    """PR-7 fixed-shape contract: a second same-shape streaming-wave
    round re-uses every compiled wave executable."""
    from repro.core import dp as dp_mod
    from repro.core import privacy_engine as pe
    from repro.core import secure_agg as sa
    from repro.core.virtual_groups import make_virtual_groups

    rng = np.random.RandomState(0)
    cids = [f"c{i}" for i in range(8)]
    plan = make_virtual_groups(cids, 2, seed=0)
    scfg = sa.SecureAggConfig(wave_clients=4)
    dcfg = dp_mod.DPConfig()
    key = jax.random.PRNGKey(0)
    seed = jnp.asarray([1, 2], jnp.uint32)

    def round_once(stats=None):
        flat = jnp.asarray(rng.uniform(-1, 1, (8, 32)), jnp.float32)
        return pe.aggregate_flat(flat, plan, cids, seed, secure_cfg=scfg,
                                 dp_cfg=dcfg, key=key, stats=stats)

    stats = {}
    jax.block_until_ready(round_once(stats))   # warm the wave executables
    assert stats["stage2_route"] == "waved"
    before = tracing.jit_cache_total()
    jax.block_until_ready(round_once())
    assert tracing.jit_cache_total() == before


# ---------------------------------------------------------------------------
# typed metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.counter("rounds", task=1).inc()
    reg.counter("rounds", task=1).inc(2.0)
    reg.counter("rounds", task=2).inc()            # distinct labels
    assert reg.value("rounds", task=1) == 3.0
    assert reg.value("rounds", task=2) == 1.0
    assert reg.value("missing", default=-1) == -1
    with pytest.raises(ValueError):
        reg.counter("rounds", task=1).inc(-1)

    reg.gauge("eps").set(2.5)
    reg.gauge("eps").set(3.5)                       # last value wins
    assert reg.value("eps") == 3.5

    h = reg.histogram("round_duration_s")
    assert h.edges == FIXED_BUCKETS["round_duration_s"]
    h.observe(0.01)    # first bucket (<= 0.05)
    h.observe(1.5)     # <= 2.0 bucket
    h.observe(1e6)     # overflow
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.count == 3
    assert reg.value("round_duration_s") == pytest.approx(
        (0.01 + 1.5 + 1e6) / 3)

    with pytest.raises(TypeError):
        reg.gauge("rounds", task=1)                 # kind conflict

    snap = reg.snapshot()
    names = [(r["name"], r["kind"]) for r in snap]
    assert ("rounds", "counter") in names and ("eps", "gauge") in names
    hrow = next(r for r in snap if r["kind"] == "histogram")
    assert hrow["count"] == 3 and len(hrow["buckets"]) == \
        len(hrow["edges"]) + 1
    json.dumps(snap)   # JSON-ready

    with pytest.raises(ValueError):
        reg.histogram("bad", edges=(2.0, 1.0))


def test_registry_pickles():
    reg = MetricsRegistry()
    reg.counter("c").inc(4)
    reg.histogram("h", edges=(1.0, 2.0)).observe(1.5)
    reg2 = pickle.loads(pickle.dumps(reg))
    assert reg2.value("c") == 4.0
    assert reg2.histogram("h", edges=(1.0, 2.0)).count == 1


# ---------------------------------------------------------------------------
# MetricsStore persistence (satellite: whole-store save/load)
# ---------------------------------------------------------------------------

def test_store_keeps_non_numeric_context():
    st = MetricsStore()
    st.log(1, 0, loss=0.5, stage2_route="waved", flag=True)
    rows = st._rows[1]
    assert {r["metric"]: r["value"] for r in rows} == \
        {"loss": 0.5, "stage2_route": "waved", "flag": 1.0}
    # series math sees only numerics
    assert st.series(1, "stage2_route") == ([], [])
    assert st.series(1, "loss") == ([0], [0.5])


def test_store_save_load_byte_identical(tmp_path):
    st = MetricsStore()
    st.log(1, 0, loss=0.9, n_selected=4, stage2_route="single_dispatch")
    st.log(1, 1, loss=0.7)
    st.log(3, 0, round_voided=1)
    host = {"platform": "test", "cpu_count": 2}
    p1 = str(tmp_path / "a.json")
    st.save(p1, now=1_700_000_000.123, host=host)

    loaded = MetricsStore.load(p1)
    assert loaded._rows[1] == st._rows[1]
    assert loaded._rows[3] == st._rows[3]
    assert sorted(loaded._rows) == [1, 3]          # int task keys restored
    assert loaded.header["version"] == 1
    assert loaded.header["host"] == host

    # byte-identical round trip with the header's clock/host re-injected
    p2 = str(tmp_path / "b.json")
    loaded.save(p2, now=loaded.header["saved_at_unix"],
                host=loaded.header["host"])
    assert open(p1, "rb").read() == open(p2, "rb").read()

    # and the loaded store still computes series/summaries
    assert loaded.latest(1, "loss") == 0.7
    assert loaded.churn_summary(3)["rounds_voided"] == 1


def test_store_save_header_defaults(tmp_path):
    st = MetricsStore()
    st.log(1, 0, loss=1.0)
    p = st.save(str(tmp_path / "s.json"))
    doc = json.load(open(p))
    assert doc["saved_at"].endswith("Z") and doc["saved_at_unix"] > 0
    assert "platform" in doc["host"] and "python" in doc["host"]


# ---------------------------------------------------------------------------
# bit-identity: tracing must never touch the math
# ---------------------------------------------------------------------------

def test_traced_round_bit_identical_to_untraced():
    from repro.core import dp as dp_mod
    from repro.core import secure_agg as sa
    from repro.core.orchestrator import run_sync_round_stacked
    from repro.core.strategies import make_strategy

    rng = np.random.RandomState(7)
    params = {"w": jnp.asarray(rng.uniform(-1, 1, 64), jnp.float32)}
    stacked = {"w": jnp.asarray(rng.uniform(-0.4, 0.4, (8, 64)),
                                jnp.float32)}
    cids = [f"c{i}" for i in range(8)]

    def run():
        strategy = make_strategy("fedavg")
        out, _, info = run_sync_round_stacked(
            params, strategy, strategy.init_state(params), cids, stacked,
            round_idx=0, vg_size=4,
            secure_cfg=sa.SecureAggConfig(),
            dp_cfg=dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                                   noise_multiplier=1.0),
            key=jax.random.PRNGKey(0))
        return np.asarray(out["w"]).view(np.uint32).tobytes(), info

    plain, _ = run()
    with tracing.use_tracer(tracing.Tracer()) as tr:
        traced, info = run()
    assert traced == plain
    assert info.stage2_route == "single_dispatch"
    # the full fused stage tree was recorded alongside identical bits
    names = {s.name for r in tr.roots() for s in _flatten(r)}
    assert {"secure_agg", "cohort_interims", "dp", "quantize", "mask",
            "vg_sum", "limb_combine", "server_update"} <= names


# ---------------------------------------------------------------------------
# service integration: meters + flight events from a simulated task
# ---------------------------------------------------------------------------

def test_service_records_meters_and_flight(tmp_path):
    from repro.fl import (AttestationAuthority, ManagementService,
                          SimClient, TaskConfig)
    from repro.fl.simulator import run_sync_simulation

    svc = ManagementService()
    svc.flight = tracing.FlightRecorder(str(tmp_path / "flight"))
    tid = svc.create_task(
        TaskConfig("t", "a", "w", clients_per_round=2, n_rounds=2,
                   vg_size=2),
        {"w": jnp.zeros(8, jnp.float32)})
    auth = AttestationAuthority()
    clients = {}
    for i in range(4):
        cid = f"c{i}"
        assert svc.register_client(
            tid, cid, {"os": "linux", "n_samples": 10, "battery": 0.9},
            auth.issue(cid))
        clients[cid] = SimClient(
            cid, lambda blob, r: ({"w": np.full(8, 0.01, np.float32)},
                                  10, {"loss": 1.0}))

    with tracing.use_tracer(tracing.Tracer()) as tr:
        run_sync_simulation(svc, tid, clients)

    assert svc.meters.value("rounds_completed", task=tid) == 2.0
    assert svc.meters.value("jit_cache_misses") is not None
    assert svc.meters.histogram("round_duration_s", task=tid).count == 2

    events = svc.flight.read(tid)
    assert [e["round"] for e in events] == [0, 1]
    for ev in events:
        assert ev["event"] == "round"
        assert len(ev["cohort"]) == 2
        assert sorted(ev["survivors"]) == sorted(ev["cohort"])
        names = [s["name"] for s in ev["stages"]]
        assert names[0] == "aggregate" and "secure_agg" in names
    # the live span tree holds the full stage taxonomy for the same run
    span_names = {s.name for r in tr.roots() for s in _flatten(r)}
    assert {"round", "selection", "lease_acquire", "local_train",
            "aggregate", "secure_agg", "server_update"} <= span_names
