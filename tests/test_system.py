"""End-to-end behaviour tests: federated spam training through the full
service stack (SDK -> selection -> secure agg -> master agg) must learn;
sync-vs-async duration; DP variant runs and reports epsilon."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import deserialize_pytree
from repro.configs import get_config
from repro.core.dp import DPConfig
from repro.data import ClientDataAccess, batches, spam_dataset
from repro.fl import (ManagementService, SimClient, TaskConfig,
                      run_async_simulation, run_sync_simulation)
from repro.models import (classifier_init, classify_logits, classify_loss,
                          init_params)
from repro.optim import sgd
from repro.optim.adamw import apply_updates

CFG = get_config("bert-tiny-spam").replace(vocab_size=1024, d_model=64,
                                           d_ff=128)


@pytest.fixture(scope="module")
def spam_world():
    key = jax.random.PRNGKey(0)
    model0 = {"trunk": init_params(CFG, key),
              "head": classifier_init(CFG, jax.random.fold_in(key, 1))}
    data = spam_dataset(n_samples=3000, vocab_size=1024, seq_len=16)
    test = spam_dataset(n_samples=400, vocab_size=1024, seq_len=16, seed=99)
    access = ClientDataAccess(data, n_splits=20, frac=1.0)
    opt = sgd(lr=0.5)

    @jax.jit
    def local_train(model, batch):
        loss, grads = jax.value_and_grad(
            lambda m: classify_loss(CFG, m["trunk"], m["head"], batch))(model)
        upd, _ = opt.update(grads, opt.init(model), model)
        return apply_updates(model, upd), loss

    def make_trainer(i):
        def trainer(blob, round_idx):
            model = deserialize_pytree(blob, like=model0)
            d = access.sample(client_seed=round_idx * 1000 + i)
            new, n = model, 0
            for b in batches(d, 16, seed=round_idx):
                b = {k: jnp.asarray(v) for k, v in b.items()}
                new, loss = local_train(new, b)
                n += len(b["label"])
            update = jax.tree.map(lambda a, b_: np.asarray(a) - np.asarray(b_),
                                  new, model)
            return update, n, {"loss": float(loss)}
        return trainer

    @jax.jit
    def test_acc(model):
        logits = classify_logits(CFG, model["trunk"], model["head"],
                                 {k: jnp.asarray(v) for k, v in test.items()})
        return jnp.mean(jnp.argmax(logits, -1) == test["label"])

    return dict(model0=model0, make_trainer=make_trainer, test_acc=test_acc)


def _clients(world, n=8, **kw):
    from repro.fl.simulator import make_heterogeneous_clients
    return make_heterogeneous_clients(n, world["make_trainer"], **kw)


def test_sync_federated_training_learns(spam_world):
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig("spam", "app", "wf", clients_per_round=6, n_rounds=6,
                   vg_size=3), spam_world["model0"])
    res = run_sync_simulation(svc, tid, _clients(spam_world, 8),
                              eval_fn=spam_world["test_acc"])
    accs = [h["eval_accuracy"] for h in res.metrics_history]
    assert accs[-1] > 0.8, accs
    assert len(res.round_durations) == 6


def test_async_steps_faster_than_sync(spam_world):
    """Fig. 11 center: async per-iteration duration < sync (no straggler
    barrier)."""
    svc = ManagementService()
    t_sync = svc.create_task(
        TaskConfig("s", "app", "wf", clients_per_round=8, n_rounds=4,
                   vg_size=4), spam_world["model0"])
    r_sync = run_sync_simulation(svc, t_sync, _clients(spam_world, 8,
                                                       straggler_frac=0.3))
    svc2 = ManagementService()
    t_async = svc2.create_task(
        TaskConfig("a", "app", "wf", clients_per_round=8, n_rounds=4,
                   mode="async", buffer_size=8), spam_world["model0"])
    r_async = run_async_simulation(svc2, t_async,
                                   _clients(spam_world, 8,
                                            straggler_frac=0.3))
    assert np.mean(r_async.round_durations) < np.mean(r_sync.round_durations)


def test_dp_task_reports_epsilon(spam_world):
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig("dp", "app", "wf", clients_per_round=4, n_rounds=2,
                   vg_size=2,
                   dp=DPConfig(mechanism="local", clip_norm=0.5,
                               noise_multiplier=1.0)),
        spam_world["model0"])
    run_sync_simulation(svc, tid, _clients(spam_world, 8))
    eps = svc.epsilon(tid)
    assert eps is not None and 0 < eps < 100
