"""Multi-tenant control plane: shared device directory (leases +
no-overlap audit), fair round scheduler, task lifecycle -> model
registry, the min-survivor refusal path, and the acceptance invariants —
single-task-through-scheduler bit-parity and the multi-task e2e over one
shared population."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.secure_agg import AggregationRefused
from repro.core.virtual_groups import make_virtual_groups
from repro.fl import (AttestationAuthority, ControlPlane, DeviceDirectory,
                      LeaseConflict, ManagementService, ModelRegistry,
                      PopulationConfig, TaskConfig, TaskStatus,
                      make_population_clients, run_async_simulation,
                      run_multi_task_simulation, run_sync_simulation,
                      sample_population)
from repro.fl.simulator import make_heterogeneous_clients

MODEL0 = {"w": np.zeros(8, np.float32)}


def _trainer_factory(i):
    def trainer(blob, round_idx):
        return {"w": np.full(8, 0.01, np.float32)}, 10, {"loss": 1.0}
    return trainer


def _register_all(svc, tid, n, prefix="c"):
    auth = AttestationAuthority()
    for i in range(n):
        cid = f"{prefix}{i}"
        assert svc.register_client(
            tid, cid, {"os": "linux", "n_samples": 10, "battery": 0.9},
            auth.issue(cid))


# ---------------------------------------------------------------------------
# device directory
# ---------------------------------------------------------------------------

class TestDeviceDirectory:
    def test_register_idempotent_and_enrollment(self):
        d = DeviceDirectory()
        d.register("a", {"os": "linux"}, task_id=1)
        d.register("a", {"battery": 0.5}, task_id=2)
        assert len(d) == 1 and "a" in d
        e = d._devices["a"]
        assert e.device_info == {"os": "linux", "battery": 0.5}
        assert d.enrolled(1) == ["a"] and d.enrolled(2) == ["a"]

    def test_lease_exclusivity_and_conflict(self):
        d = DeviceDirectory()
        for cid in "abc":
            d.register(cid)
        d.acquire(1, ["a", "b"])
        assert d.leased_by("a") == 1 and d.leasable("a", 1)
        assert not d.leasable("a", 2)
        with pytest.raises(LeaseConflict):
            d.acquire(2, ["c", "a"])        # atomic: c must NOT be leased
        assert d.leased_by("c") is None
        d.acquire(2, ["c"])
        assert d.leased(2) == ["c"]

    def test_release_charges_lease_seconds(self):
        d = DeviceDirectory()
        d.register("a"), d.register("b")
        d.now = 10.0
        d.acquire(1, ["a", "b"])
        d.now = 16.0
        assert d.release(1, ["a"]) == pytest.approx(6.0)
        d.now = 20.0
        d.release_all(1)
        assert d.lease_seconds[1] == pytest.approx(6.0 + 10.0)
        assert d.leased() == []
        assert len(d.lease_log) == 2

    def test_overlap_audit(self):
        d = DeviceDirectory(log_leases=True)
        d.register("a")
        d.now = 0.0
        d.acquire(1, ["a"])
        d.now = 5.0
        d.release_all(1)
        d.acquire(2, ["a"])                # starts exactly at t=5: half-open
        d.now = 9.0
        d.release_all(2)
        assert d.overlap_violations() == []
        # forge an overlapping interval: the audit must catch it
        d.lease_log.append(("a", 3, 4.0, 6.0))
        assert d.overlap_violations()

    def test_availability_from_profile(self):
        pop = sample_population(
            4, seed=0, cfg=PopulationConfig(avail_duty=0.5, avail_period=10))
        d = DeviceDirectory()
        for p in pop:
            d.register(p.client_id, profile=p)
        p0 = pop[0]
        t_in = next(t * 0.37 for t in range(400)
                    if p0.available_at(t * 0.37))
        t_out = next(t * 0.37 for t in range(400)
                     if not p0.available_at(t * 0.37))
        assert d.available_at(p0.client_id, t_in)
        assert not d.available_at(p0.client_id, t_out)
        d.register("noprofile")
        assert d.available_at("noprofile", 123.0)   # no profile => always

    def test_selection_is_a_directory_view(self):
        """Two services sharing one directory cannot co-select a device."""
        directory = DeviceDirectory()
        svc = ManagementService(directory=directory)
        t1 = svc.create_task(TaskConfig("t1", "a", "w", clients_per_round=3,
                                        n_rounds=2, vg_size=2), MODEL0)
        t2 = svc.create_task(TaskConfig("t2", "a", "w", clients_per_round=3,
                                        n_rounds=2, vg_size=2), MODEL0)
        _register_all(svc, t1, 6)
        _register_all(svc, t2, 6)
        _, cohort1 = svc.begin_round(t1)
        _, cohort2 = svc.begin_round(t2)
        assert not set(cohort1) & set(cohort2)
        assert sorted(directory.leased()) == sorted(cohort1 + cohort2)


# ---------------------------------------------------------------------------
# lifecycle -> registry
# ---------------------------------------------------------------------------

class TestLifecycle:
    def test_created_deploy_running(self):
        svc = ManagementService()
        tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=2,
                                         n_rounds=1, vg_size=2), MODEL0,
                              deploy=False)
        assert svc.get_task(tid).status is TaskStatus.CREATED
        _register_all(svc, tid, 2)
        ri, cohort = svc.begin_round(tid)
        assert cohort == []                 # CREATED tasks get no cohort
        svc.deploy_task(tid)
        assert svc.get_task(tid).status is TaskStatus.RUNNING
        with pytest.raises(ValueError, match="only CREATED"):
            svc.deploy_task(tid)

    def test_n_rounds_stop_publishes_registry(self):
        svc = ManagementService()
        tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=2,
                                         n_rounds=2, vg_size=2), MODEL0)
        _register_all(svc, tid, 4)
        for _ in range(2):
            _, cohort = svc.begin_round(tid)
            for cid in cohort:
                svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        rec = svc.get_task(tid)
        assert rec.status is TaskStatus.COMPLETED
        assert rec.stop_reason == "n_rounds"
        assert tid in svc.registry
        entry = svc.registry.get(tid)
        assert entry.rounds_run == 2 and entry.stop_reason == "n_rounds"
        np.testing.assert_array_equal(
            entry.model(like=MODEL0)["w"], np.asarray(rec.model["w"]))
        assert entry.config["secure_agg"]["min_survivors_per_vg"] == 2

    def test_epsilon_budget_stop(self):
        dp = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                             noise_multiplier=1.0)
        svc = ManagementService()
        tid = svc.create_task(
            TaskConfig("t", "a", "w", clients_per_round=4, n_rounds=50,
                       vg_size=2, dp=dp, epsilon_budget=1e-6), MODEL0)
        _register_all(svc, tid, 4)
        _, cohort = svc.begin_round(tid)
        for cid in cohort:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        rec = svc.get_task(tid)
        assert rec.status is TaskStatus.COMPLETED
        assert rec.stop_reason == "epsilon_budget"
        assert svc.registry.get(tid).epsilon >= 1e-6
        assert rec.round_idx == 1           # stopped long before n_rounds

    def test_target_metric_stop_max_and_min(self):
        for mode, target, hit, miss in (("max", 0.8, 0.9, 0.5),
                                        ("min", 0.2, 0.1, 0.5)):
            svc = ManagementService()
            tid = svc.create_task(
                TaskConfig("t", "a", "w", clients_per_round=2, n_rounds=50,
                           vg_size=2, target_metric="eval_accuracy",
                           target_value=target, target_mode=mode), MODEL0)
            _register_all(svc, tid, 2)
            svc.metrics.log(tid, 1, eval_accuracy=miss)
            assert svc.check_stop(tid) is None
            svc.metrics.log(tid, 2, eval_accuracy=hit)
            assert svc.check_stop(tid) == "target_metric"
            assert svc.get_task(tid).status is TaskStatus.COMPLETED

    def test_registry_save_load_round_trip(self, tmp_path):
        svc = ManagementService()
        tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=2,
                                         n_rounds=1, vg_size=2), MODEL0)
        _register_all(svc, tid, 2)
        _, cohort = svc.begin_round(tid)
        for cid in cohort:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        paths = svc.registry.save(str(tmp_path))
        assert len(paths) == 2
        reg2 = ModelRegistry.load(str(tmp_path))
        assert len(reg2) == 1 and tid in reg2
        e1, e2 = svc.registry.get(tid), reg2.get(tid)
        assert e1.model_blob == e2.model_blob       # byte-for-byte
        assert e2.stop_reason == "n_rounds"
        assert e2.history == e1.history

    def test_pause_aborts_inflight_round_and_frees_leases(self):
        svc = ManagementService()
        tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=3,
                                         n_rounds=3, vg_size=2), MODEL0)
        _register_all(svc, tid, 6)
        _, cohort = svc.begin_round(tid)
        assert svc.directory.leased(tid) == sorted(cohort)
        svc.pause_task(tid)
        assert svc.directory.leased(tid) == []
        # the late upload of the aborted round is a no-op
        svc.resume_task(tid)
        assert not svc.submit_update(tid, cohort[0],
                                     {"w": jnp.ones(8) * 0.1}, 10)
        assert svc.get_task(tid).round_idx == 0


# ---------------------------------------------------------------------------
# min-survivors-per-VG refusal path (satellite: trust-model floor)
# ---------------------------------------------------------------------------

class TestMinSurvivorsPerVG:
    def _updates(self, n, size=16, seed=0):
        rng = np.random.RandomState(seed)
        return {f"c{i:03d}": jnp.asarray(
            rng.uniform(-1, 1, size).astype(np.float32)) for i in range(n)}

    def test_subthreshold_group_voided_equals_fully_dropped_group(self):
        """A group cut to 1 survivor contributes NOTHING: serial result
        == the same round with that survivor also dropped."""
        updates = self._updates(8)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, 4, seed=1)
        grp = plan.groups[0].members
        seed = jnp.asarray([3, 9], jnp.uint32)
        # group 0 loses all but one member
        surv_floor = {c: updates[c] for c in cohort
                      if c not in set(grp[1:])}
        out_floor = sa.secure_aggregate_survivors(
            surv_floor, plan, seed,
            cfg=sa.SecureAggConfig(min_survivors_per_vg=2))
        # reference: the lone survivor also dropped, floor disabled
        surv_none = {c: updates[c] for c in cohort if c not in set(grp)}
        out_none = sa.secure_aggregate_survivors(
            surv_none, plan, seed,
            cfg=sa.SecureAggConfig(min_survivors_per_vg=1))
        np.testing.assert_array_equal(np.asarray(out_floor),
                                      np.asarray(out_none))

    def test_vectorized_voiding_matches_serial_and_counts(self):
        updates = self._updates(8)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, 4, seed=1)
        grp = set(plan.groups[0].members[1:])
        dropped = grp
        scfg = sa.SecureAggConfig(min_survivors_per_vg=2)
        dcfg = dp_mod.DPConfig()
        key = jax.random.PRNGKey(0)
        seed = jnp.asarray([3, 9], jnp.uint32)
        serial = sa.secure_aggregate_survivors(
            {c: updates[c] for c in cohort if c not in dropped}, plan,
            seed, cfg=scfg)
        alive = np.asarray([c not in dropped for c in cohort])
        flat = jnp.stack([updates[c] for c in cohort])
        stats = {}
        vect = pe.aggregate_flat(flat, plan, cohort, seed, secure_cfg=scfg,
                                 dp_cfg=dcfg, key=key, alive=alive,
                                 stats=stats)
        np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))
        assert stats["n_voided_groups"] == 1
        # the voided group's lone survivor counts as dropped downstream
        assert stats["n_dropped"] == len(dropped) + 1

    def test_whole_round_refused_when_all_groups_below_floor(self):
        updates = self._updates(4)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, 2, seed=0)
        seed = jnp.asarray([1, 2], jnp.uint32)
        # one survivor per 2-group: every group below the floor of 2
        survivors = {plan.groups[0].members[0]:
                     updates[plan.groups[0].members[0]],
                     plan.groups[1].members[0]:
                     updates[plan.groups[1].members[0]]}
        with pytest.raises(AggregationRefused, match="min_survivors"):
            sa.secure_aggregate_survivors(survivors, plan, seed)
        alive = np.asarray([c in survivors for c in cohort])
        with pytest.raises(AggregationRefused, match="refused"):
            pe.aggregate_flat(jnp.stack([updates[c] for c in cohort]),
                              plan, cohort, seed, alive=alive)
        assert issubclass(AggregationRefused, ValueError)

    def test_service_voids_refused_round(self):
        """cpr=2, vg=2: one dropout leaves a 1-survivor group -> the
        service voids the round instead of crashing or aggregating."""
        svc = ManagementService()
        tid = svc.create_task(TaskConfig("t", "a", "w", clients_per_round=2,
                                         n_rounds=2, vg_size=2), MODEL0)
        _register_all(svc, tid, 4)
        ri, cohort = svc.begin_round(tid)
        assert not svc.report_dropout(tid, cohort[0])
        assert svc.submit_update(tid, cohort[1],
                                 {"w": jnp.ones(8) * 0.1}, 10)
        rec = svc.get_task(tid)
        assert rec.round_idx == ri          # round NOT consumed
        assert rec.status is TaskStatus.RUNNING
        np.testing.assert_array_equal(np.asarray(rec.model["w"]), 0.0)
        assert svc.metrics.latest(tid, "round_voided") == 1.0
        # next round with full survival completes normally
        _, cohort2 = svc.begin_round(tid)
        for cid in cohort2:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        assert svc.get_task(tid).round_idx == ri + 1


# ---------------------------------------------------------------------------
# scheduler: fairness + single-task bit-parity (acceptance)
# ---------------------------------------------------------------------------

class TestScheduler:
    def _plane_with(self, n_sync, cpr=4, n_rounds=3, **kw):
        plane = ControlPlane(seed=0)
        tids = [plane.create_task(
            TaskConfig(f"t{i}", "a", "w", clients_per_round=cpr,
                       n_rounds=n_rounds, vg_size=2, **kw), MODEL0)
            for i in range(n_sync)]
        for t in tids:
            plane.deploy(t)
        return plane, tids

    def test_priority_tier_wins(self):
        plane, (t1, t2) = self._plane_with(2)
        plane.service.get_task(t2).config.priority = 5
        for t in (t1, t2):
            _register_all(plane.service, t, 8)
        assert plane.next_task(0.0) == t2

    def test_deficit_round_robin_alternates(self):
        plane, (t1, t2) = self._plane_with(2, n_rounds=4)
        svc = plane.service
        for t in (t1, t2):
            _register_all(svc, t, 8)
        order = []
        for _ in range(4):
            grant = plane.grant_round(now=plane.directory.now)
            assert grant is not None
            order.append(grant.task_id)
            for cid in grant.cohort:
                svc.submit_update(grant.task_id, cid,
                                  {"w": jnp.ones(8) * 0.1}, 10)
            plane.directory.now += 1.0
            plane.complete_round(grant.task_id)
        # equal weights, equal cohorts: strict alternation
        assert order == [t1, t2, t1, t2]

    def test_weighted_share(self):
        """weight=3 task gets ~3x the lease-seconds of weight=1."""
        plane, (t1, t2) = self._plane_with(2, n_rounds=40)
        svc = plane.service
        svc.get_task(t2).config.weight = 3.0
        for t in (t1, t2):
            _register_all(svc, t, 4)   # 4 devices each, cpr=4: serialized
        for _ in range(24):
            grant = plane.grant_round(now=plane.directory.now)
            if grant is None:
                break
            for cid in grant.cohort:
                svc.submit_update(grant.task_id, cid,
                                  {"w": jnp.ones(8) * 0.1}, 10)
            plane.directory.now += 1.0
            plane.complete_round(grant.task_id)
        fair = plane.fairness()
        ratio = fair[t2]["lease_seconds"] / fair[t1]["lease_seconds"]
        assert 2.0 < ratio < 4.0, fair

    def test_single_task_sync_parity_with_direct_path(self):
        """Acceptance: one task through grant/complete == direct
        run_sync_simulation, bit for bit (durations, clock, model)."""
        svc_a = ManagementService(seed=0)
        ta = svc_a.create_task(
            TaskConfig("p", "a", "w", clients_per_round=4, n_rounds=3,
                       vg_size=2), MODEL0)
        ra = run_sync_simulation(
            svc_a, ta, make_heterogeneous_clients(8, _trainer_factory),
            seed=0)
        plane = ControlPlane(seed=0)
        tb = plane.create_task(
            TaskConfig("p", "a", "w", clients_per_round=4, n_rounds=3,
                       vg_size=2), MODEL0)
        plane.deploy(tb)
        rb = run_multi_task_simulation(
            plane, make_heterogeneous_clients(8, _trainer_factory), seed=0)
        assert ra.round_durations == rb.per_task[tb].round_durations
        assert ra.total_time == rb.per_task[tb].total_time
        np.testing.assert_array_equal(
            np.asarray(svc_a.get_task(ta).model["w"]),
            np.asarray(plane.service.get_task(tb).model["w"]))

    def test_single_task_async_parity_with_direct_path(self):
        svc_a = ManagementService(seed=0)
        ta = svc_a.create_task(
            TaskConfig("q", "a", "w", clients_per_round=4, n_rounds=3,
                       mode="async", buffer_size=4), MODEL0)
        ra = run_async_simulation(
            svc_a, ta, make_heterogeneous_clients(8, _trainer_factory),
            seed=0)
        plane = ControlPlane(seed=0)
        tb = plane.create_task(
            TaskConfig("q", "a", "w", clients_per_round=4, n_rounds=3,
                       mode="async", buffer_size=4), MODEL0)
        plane.deploy(tb)
        rb = run_multi_task_simulation(
            plane, make_heterogeneous_clients(8, _trainer_factory), seed=0)
        assert ra.round_durations == rb.per_task[tb].round_durations
        assert ra.total_time == rb.per_task[tb].total_time
        np.testing.assert_array_equal(
            np.asarray(svc_a.get_task(ta).model["w"]),
            np.asarray(plane.service.get_task(tb).model["w"]))


# ---------------------------------------------------------------------------
# concurrent multi-task simulation (acceptance)
# ---------------------------------------------------------------------------

class TestMultiTask:
    def _mixed_plane(self, dp_on_first=False):
        plane = ControlPlane(seed=0)
        dp = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                             noise_multiplier=1.0) if dp_on_first \
            else dp_mod.DPConfig()
        t1 = plane.create_task(
            TaskConfig("s1", "a", "w", clients_per_round=4, n_rounds=3,
                       vg_size=2, dp=dp), MODEL0)
        t2 = plane.create_task(
            TaskConfig("s2", "a", "w", clients_per_round=4, n_rounds=3,
                       vg_size=2), MODEL0)
        t3 = plane.create_task(
            TaskConfig("a1", "a", "w", clients_per_round=4, n_rounds=3,
                       mode="async", buffer_size=4), MODEL0)
        for t in (t1, t2, t3):
            plane.deploy(t)
        return plane, (t1, t2, t3)

    def test_two_sync_one_async_interleave_no_overlap(self):
        plane, (t1, t2, t3) = self._mixed_plane(dp_on_first=True)
        clients = make_heterogeneous_clients(12, _trainer_factory)
        res = run_multi_task_simulation(plane, clients, seed=0)
        svc = plane.service
        for t in (t1, t2, t3):
            assert svc.get_task(t).status is TaskStatus.COMPLETED
            assert svc.get_task(t).stop_reason == "n_rounds"
            assert t in plane.registry
        assert res.lease_overlaps == []
        # async tasks hold no leases
        assert t3 not in res.lease_seconds
        assert res.lease_seconds[t1] > 0 and res.lease_seconds[t2] > 0
        # accountants are isolated: only the DP task spends epsilon
        assert svc.epsilon(t1) is not None and svc.epsilon(t1) > 0
        assert svc.epsilon(t2) is None and svc.epsilon(t3) is None
        # metrics are isolated per task
        for t in (t1, t2):
            s = svc.metrics.churn_summary(t)
            assert s["rounds"] == 3 and s["selected"] == 12
        fleet = svc.metrics.fleet_summary([t1, t2, t3])
        assert fleet["fleet"]["selected"] == 24      # async logs no cohorts
        assert fleet["tasks"] == 3

    def test_pause_and_cancel_never_stall_the_fleet(self):
        plane, (t1, t2, t3) = self._mixed_plane()
        paused = []

        def on_round(tid, round_idx, t_end):
            if not paused and tid == t1:
                plane.pause(t1)
                plane.cancel(t3)
                paused.append(tid)

        clients = make_heterogeneous_clients(12, _trainer_factory)
        res = run_multi_task_simulation(plane, clients, seed=0,
                                        on_round=on_round)
        svc = plane.service
        assert svc.get_task(t2).status is TaskStatus.COMPLETED
        assert svc.get_task(t1).status is TaskStatus.PAUSED
        assert svc.get_task(t3).status is TaskStatus.CANCELLED
        assert res.lease_overlaps == []
        assert plane.directory.leased() == []   # nothing pinned
        assert len(res.per_task[t1].round_durations) < 3

    def test_fairness_telemetry_populated(self):
        plane, tids = self._mixed_plane()
        clients = make_heterogeneous_clients(12, _trainer_factory)
        res = run_multi_task_simulation(plane, clients, seed=0)
        for t in tids[:2]:
            f = res.fairness[t]
            assert f["rounds_granted"] == 3
            assert f["normalized"] == pytest.approx(
                f["lease_seconds"] / f["weight"])

    def test_shared_population_with_churn_profiles(self):
        """Mixed tasks over a PROFILED population (availability windows +
        hazards): still completes, still zero lease overlaps."""
        pop = sample_population(
            20, seed=7, cfg=PopulationConfig(mean_hazard=0.02,
                                             avail_duty=0.8,
                                             avail_period=16.0))
        clients = make_population_clients(pop, _trainer_factory)
        plane = ControlPlane(seed=0)
        tids = [plane.create_task(
            TaskConfig(f"t{i}", "a", "w", clients_per_round=4, n_rounds=3,
                       vg_size=2, overprovision=1.5, round_timeout_s=30.0),
            MODEL0) for i in range(2)]
        tids.append(plane.create_task(
            TaskConfig("a0", "a", "w", clients_per_round=4, n_rounds=3,
                       mode="async", buffer_size=4), MODEL0))
        for t in tids:
            plane.deploy(t)
        res = run_multi_task_simulation(plane, clients, seed=0)
        assert res.lease_overlaps == []
        done = [t for t in tids
                if plane.service.get_task(t).status is TaskStatus.COMPLETED]
        assert len(done) == 3, plane.fairness()


def test_e2e_three_tenants_over_10k_device_fleet():
    """ISSUE acceptance: >= 3 concurrent tasks (mixed sync/async) over ONE
    shared 10k-device population, all completing to their stop criteria,
    zero overlapping sync leases, fairness measurable."""
    pop = sample_population(10_000, seed=1,
                            cfg=PopulationConfig(mean_hazard=0.005,
                                                 avail_duty=0.9,
                                                 avail_period=48.0))
    clients = make_population_clients(pop, _trainer_factory)
    plane = ControlPlane(seed=0)
    t1 = plane.create_task(
        TaskConfig("tenant-a", "a", "w", clients_per_round=64, n_rounds=3,
                   vg_size=8, overprovision=1.25, round_timeout_s=60.0),
        MODEL0)
    t2 = plane.create_task(
        TaskConfig("tenant-b", "b", "w", clients_per_round=32, n_rounds=4,
                   vg_size=8, weight=2.0, overprovision=1.25,
                   round_timeout_s=60.0), MODEL0)
    t3 = plane.create_task(
        TaskConfig("tenant-c", "c", "w", clients_per_round=32, n_rounds=4,
                   mode="async", buffer_size=32), MODEL0)
    for t in (t1, t2, t3):
        plane.deploy(t)
    res = run_multi_task_simulation(plane, clients, seed=0)
    svc = plane.service
    for t in (t1, t2, t3):
        rec = svc.get_task(t)
        assert rec.status is TaskStatus.COMPLETED, (t, rec.status)
        assert rec.stop_reason == "n_rounds"
        assert t in plane.registry
    assert res.lease_overlaps == []
    assert plane.directory.overlap_violations() == []
    fair = res.fairness
    assert fair[t1]["lease_seconds"] > 0 and fair[t2]["lease_seconds"] > 0
    assert len(plane.directory) == 10_000
