"""Churn subsystem: heterogeneous population model, over-provisioned
deadline selection + drop lifecycle, and dropout-tolerant secure
aggregation (Bonawitz-style mask recovery) — deterministic coverage.
The hypothesis sweep lives in tests/test_churn_property.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import dropout
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import (ClientResult, _secure_mean_survivors,
                                     run_sync_round, run_sync_round_stacked)
from repro.core.quantize import quantize
from repro.core.strategies import FedAvg
from repro.core.virtual_groups import make_virtual_groups
from repro.fl import (AttestationAuthority, ManagementService,
                      PopulationConfig, TaskConfig, TaskStatus,
                      make_population_clients, population_summary,
                      sample_population)
from repro.fl.simulator import run_sync_simulation


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _mk_updates(rng, n, size=23):
    return {f"c{i:03d}": jnp.asarray(
        rng.uniform(-1.2, 1.2, size).astype(np.float32)) for i in range(n)}


def clean_survivor_reference(updates, cohort_sorted, plan, dropped, key,
                             scfg, dcfg):
    """Independent oracle: NO masks at all. Per-client DP through the same
    shared jitted row (key folded at the client's FULL-cohort position),
    quantize, plain per-group survivor code sums, shared master combine.
    Mask application + recovery must be an exact algebraic no-op relative
    to this."""
    fold_of = {c: j for j, c in enumerate(cohort_sorted)}
    interims, sizes = [], []
    for grp in plan.groups:
        surv = [c for c in grp.members if c not in dropped]
        if not surv:
            continue
        qsum = None
        for c in surv:
            u = updates[c]
            if dcfg.mechanism == "local":
                sg = float(dcfg.noise_multiplier * dcfg.clip_norm) \
                    if dcfg.noise_multiplier > 0 else 0.0
                u = dp_mod._flat_local_dp_jit(
                    u, jax.random.fold_in(key, fold_of[c]),
                    clip_norm=float(dcfg.clip_norm), sigma=sg)
            elif dcfg.mechanism == "global":
                u = dp_mod._flat_clip_jit(u,
                                          clip_norm=float(dcfg.clip_norm))
            q = quantize(u, scfg.clip, scfg.bits)
            qsum = q if qsum is None else qsum + q
        interims.append(qsum)
        sizes.append(len(surv))
    return sa.master_aggregate(interims, sizes, lambda x: x, scfg)


def _churn_both_paths(updates, cohort_sorted, plan, dropped, seed, key,
                      scfg, dcfg):
    """-> (serial survivor-protocol result, vectorized engine result)."""
    survivors = [c for c in cohort_sorted if c not in dropped]
    fold_of = {c: j for j, c in enumerate(cohort_sorted)}
    ser = _secure_mean_survivors({c: updates[c] for c in survivors}, plan,
                                 seed, key, scfg, dcfg, fold_of)
    size = updates[cohort_sorted[0]].shape[0]
    alive = np.asarray([c not in dropped for c in cohort_sorted])
    flat = jnp.stack([updates[c] if alive[j]
                      else jnp.zeros(size, jnp.float32)
                      for j, c in enumerate(cohort_sorted)])
    vec = pe.aggregate_flat(flat, plan, cohort_sorted, seed,
                            secure_cfg=scfg, dp_cfg=dcfg, key=key,
                            alive=alive)
    return ser, vec


# ---------------------------------------------------------------------------
# population model
# ---------------------------------------------------------------------------

class TestPopulation:
    def test_deterministic_from_seed(self):
        a = sample_population(40, seed=7)
        b = sample_population(40, seed=7)
        assert a == b
        c = sample_population(40, seed=8)
        assert a != c

    def test_tier_mix_and_speeds(self):
        pop = sample_population(500, seed=0)
        s = population_summary(pop)
        assert s["n"] == 500
        assert set(s["tiers"]) <= {"flagship", "midrange", "budget"}
        # midrange is the configured bulk of the default mix
        assert max(s["tiers"], key=s["tiers"].get) == "midrange"
        assert s["speed_min"] < 1.0 < s["speed_max"]

    def test_availability_window(self):
        cfg = PopulationConfig(avail_period=10.0, avail_duty=0.5)
        p = sample_population(1, seed=1, cfg=cfg)[0]
        ups = sum(p.available_at(t / 10.0) for t in range(200))
        assert 60 <= ups <= 140          # ~50% duty over two periods
        assert p.available_at(0.0) == p.available_at(p.avail_period)

    def test_dropout_hazard(self):
        cfg = PopulationConfig(mean_hazard=0.5)
        pop = sample_population(50, seed=2, cfg=cfg)
        assert any(p.dropout_hazard > 0 for p in pop)
        p = max(pop, key=lambda q: q.dropout_hazard)
        assert p.drop_probability(0.0) == 0.0
        assert 0.0 < p.drop_probability(1.0) < p.drop_probability(10.0) < 1.0
        safe = sample_population(5, seed=2)[0]     # mean_hazard = 0
        assert safe.drop_probability(1e9) == 0.0

    def test_make_population_clients(self):
        pop = sample_population(6, seed=3)
        clients = make_population_clients(pop)
        assert set(clients) == {p.client_id for p in pop}
        sc = clients[pop[0].client_id]
        assert sc.profile is pop[0]
        assert sc.device_info["tier"] == pop[0].tier


# ---------------------------------------------------------------------------
# selection lifecycle (satellite: drop/re-register)
# ---------------------------------------------------------------------------

def _mk_service_task(n_rounds=3, cpr=4, n_clients=8, **task_kw):
    svc = ManagementService()
    model = {"w": jnp.zeros(8, jnp.float32)}
    cfg = TaskConfig("t", "app", "wf", clients_per_round=cpr,
                     n_rounds=n_rounds, vg_size=2, **task_kw)
    tid = svc.create_task(cfg, model)
    auth = AttestationAuthority()
    for i in range(n_clients):
        cert = auth.issue(f"c{i}")
        assert svc.register_client(tid, f"c{i}", {"os": "linux",
                                                  "n_samples": 10,
                                                  "battery": 0.9}, cert)
    return svc, tid


class TestSelectionChurn:
    def test_two_round_drop_reregister_sequence(self):
        """A client dropped mid-round must (a) stop counting as available
        for the rest of the round and (b) return to the registered pool —
        selectable again — when the next round begins. Pre-fix, 'dropped'
        was sticky forever and stayed in the ready()/selection pool."""
        svc, tid = _mk_service_task(cpr=3, n_clients=4)
        task = svc.get_task(tid)
        _, cohort = svc.begin_round(tid)
        victim = cohort[0]
        svc.report_dropout(tid, victim)
        assert svc.selection.statuses(task)[victim] == "dropped"
        # dropped is OUT of the selectable pool and the ready() accounting
        assert victim not in svc.selection.available(task)
        assert not svc.selection.ready(task)   # 4 - 3 selected/dropped < 3
        for cid in cohort[1:]:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        # next round: the dropped client re-registers and can be selected
        _, cohort2 = svc.begin_round(tid)
        assert svc.selection.statuses(task)[victim] in ("registered",
                                                        "selected")
        assert victim in set(svc.selection.available(task)) | set(cohort2)

    def test_overprovision_cohort_size(self):
        svc, tid = _mk_service_task(cpr=4, n_clients=8, overprovision=1.5)
        _, cohort = svc.begin_round(tid)
        assert len(cohort) == 6                 # ceil(4 * 1.5)

    def test_deadline_recorded(self):
        svc, tid = _mk_service_task(cpr=2, n_clients=4, round_timeout_s=9.5)
        task = svc.get_task(tid)
        svc.begin_round(tid)
        assert svc.selection.round_deadline(task) == 9.5

    def test_backfill_round_replaces_unavailable(self):
        svc, tid = _mk_service_task(cpr=4, n_clients=8)
        task = svc.get_task(tid)
        _, cohort = svc.begin_round(tid)
        gone = cohort[:2]
        repaired = svc.backfill_round(tid, gone)
        assert len(repaired) == len(cohort)
        assert not set(gone) & set(repaired)
        st = svc.selection.statuses(task)
        # released members are plain registered — NOT round dropouts
        assert all(st[c] == "registered" for c in gone)
        assert all(st[c] == "selected" for c in repaired)

    def test_backfill_after_submission_rejected(self):
        svc, tid = _mk_service_task(cpr=3, n_clients=6)
        _, cohort = svc.begin_round(tid)
        svc.submit_update(tid, cohort[0], {"w": jnp.ones(8) * 0.1}, 10)
        with pytest.raises(ValueError):
            svc.backfill_round(tid, [cohort[1]])

    def test_selection_availability_predicate(self):
        svc, tid = _mk_service_task(cpr=3, n_clients=6)
        _, cohort = svc.begin_round(
            tid, available=lambda cid: cid not in ("c0", "c1", "c2"))
        assert not {"c0", "c1", "c2"} & set(cohort)
        assert len(cohort) == 3


# ---------------------------------------------------------------------------
# mask recovery core
# ---------------------------------------------------------------------------

class TestRecoveryCore:
    def test_batched_corrections_match_serial(self):
        """The jitted batched reconstruction equals the per-pair python
        reference for every dropped member, including the pow2 padding
        rows (all-False alive mask -> exact zeros)."""
        g, size = 5, 13
        seed = jnp.asarray([3, 9], jnp.uint32)
        rs = jnp.asarray([7, 2], jnp.uint32)
        vg_ids = np.asarray([0, 1, 4], np.uint32)
        alive = np.asarray([[True, False, True, True, False],
                            [False, True, True, False, True],
                            [True, True, False, True, True]])
        d_idxs = np.asarray([1, 0, 2], np.uint32)
        corr = dropout._bucket_corrections(
            rs, jnp.asarray(np.concatenate([d_idxs, [0]])),
            jnp.asarray(np.concatenate([vg_ids, [0]])),
            jnp.asarray(np.concatenate([alive, np.zeros((1, g), bool)])),
            vg_size=g, size=size)
        assert corr.shape == (4, size)
        for r in range(3):
            gseed = sa.group_seed(rs, int(vg_ids[r]))
            surv = [i for i in range(g) if alive[r, i]]
            ref = dropout.dropped_net_mask([int(d_idxs[r])], surv, g,
                                           gseed, size)
            np.testing.assert_array_equal(np.asarray(corr[r]),
                                          np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(corr[3]), 0)

    @pytest.mark.parametrize("n,vg,bits,mech,noise,drop", [
        (12, 4, 20, "off", 0.0, []),                 # |D| = 0
        (12, 4, 20, "off", 0.0, [3]),                # one straggler
        (13, 4, 18, "local", 0.8, [0, 5, 12]),       # ragged + DP noise
        (13, 4, 18, "local", 0.0, [2, 3]),           # clip-only
        (11, 3, 24, "global", 0.5, [1, 7]),          # global clip
        (12, 4, 20, "off", 0.0, [4, 5, 6, 7]),       # a WHOLE VG drops
        (8, 8, 20, "local", 0.5, [0, 1, 2, 3, 4, 5, 6]),  # 1 survivor
    ])
    def test_recovered_equals_clean_survivor_round(self, n, vg, bits, mech,
                                                   noise, drop):
        """Acceptance: for any dropped subset D (incl. a whole VG and the
        empty set), BOTH churn paths are bit-identical to the maskless
        clean reference over the survivors."""
        rng = np.random.RandomState(n * 31 + len(drop))
        updates = _mk_updates(rng, n)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, vg, seed=5)
        # map drop positions (by row) to a dropped-cid set; drop whole-VG
        # cases address plan groups via membership, so translate by row
        dropped = {cohort[j] for j in drop}
        seed = jnp.asarray([11, 4], jnp.uint32)
        key = jax.random.PRNGKey(n)
        # min_survivors_per_vg=1: this test pins the exact-recovery math
        # for ANY survivor pattern (down to a single survivor), so the
        # privacy floor's group-voiding must be out of the way
        scfg = sa.SecureAggConfig(bits=bits, min_survivors_per_vg=1)
        dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                               noise_multiplier=noise)
        ser, vec = _churn_both_paths(updates, cohort, plan, dropped, seed,
                                     key, scfg, dcfg)
        ref = clean_survivor_reference(updates, cohort, plan, dropped, key,
                                       scfg, dcfg)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(ser))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(vec))

    def test_prefix_path_corrupts_without_recovery(self):
        """Regression for the pre-churn protocol: summing only the
        survivors' payloads leaves the dropped member's pairwise masks
        NON-CANCELLING — the dequantized 'aggregate' is garbage. (This is
        why the old round had to abort on any straggler.)"""
        g, size, bits = 4, 16, 20
        seed = sa.group_seed(jnp.asarray([1, 2], jnp.uint32), 0)
        cfg = sa.SecureAggConfig(bits=bits)
        updates = [jnp.full(size, 0.25, jnp.float32) for _ in range(g)]
        payloads = [sa.client_protect(u, i, g, seed, cfg)[0]
                    for i, u in enumerate(updates)]
        # everyone submits: masks cancel, mean == 0.25
        full = sa.vg_aggregate(payloads)
        from repro.core.quantize import dequantize_sum
        np.testing.assert_allclose(
            np.asarray(dequantize_sum(full, g, cfg.clip, bits)), 0.25,
            atol=1e-4)
        # client 2 drops: the naive survivor sum is corrupted...
        naive = sa.vg_aggregate([payloads[i] for i in (0, 1, 3)])
        bad = dequantize_sum(naive, 3, cfg.clip, bits)
        assert not np.allclose(np.asarray(bad), 0.25, atol=0.05)
        # ...and recovery repairs it exactly
        fixed = naive + dropout.dropped_net_mask([2], [0, 1, 3], g, seed,
                                                 size)
        np.testing.assert_allclose(
            np.asarray(dequantize_sum(fixed, 3, cfg.clip, bits)), 0.25,
            atol=1e-4)

    def test_no_survivors_raises(self):
        rng = np.random.RandomState(0)
        updates = _mk_updates(rng, 4)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, 2, seed=0)
        seed = jnp.asarray([1, 1], jnp.uint32)
        flat = jnp.stack([updates[c] for c in cohort])
        with pytest.raises(ValueError, match="no survivors"):
            pe.aggregate_flat(flat, plan, cohort, seed,
                              alive=np.zeros(4, bool))
        with pytest.raises(ValueError, match="no survivors"):
            sa.secure_aggregate_survivors({}, plan, seed)

    def test_recovery_stats_populated(self):
        rng = np.random.RandomState(1)
        updates = _mk_updates(rng, 8)
        cohort = sorted(updates)
        plan = make_virtual_groups(cohort, 4, seed=1)
        seed = jnp.asarray([2, 5], jnp.uint32)
        alive = np.ones(8, bool)
        alive[[1, 6]] = False
        flat = jnp.stack([updates[c] for c in cohort])
        stats = {}
        pe.aggregate_flat(flat, plan, cohort, seed, alive=alive,
                          stats=stats)
        assert stats["n_dropped"] == 2
        assert stats["recovery_s"] > 0.0


# ---------------------------------------------------------------------------
# round-level wiring
# ---------------------------------------------------------------------------

class TestChurnRounds:
    def _results(self, updates, survivors):
        return {c: ClientResult(update={"w": updates[c]}, n_samples=4,
                                metrics={"loss": 1.0}) for c in survivors}

    def test_run_sync_round_vectorized_matches_serial_under_churn(self):
        rng = np.random.RandomState(9)
        updates = _mk_updates(rng, 11)
        cohort = sorted(updates)
        survivors = [c for c in cohort if c not in {"c001", "c004", "c009"}]
        params = {"w": jnp.zeros(23, jnp.float32)}
        strat = FedAvg(server_lr=1.0)
        for dcfg in [dp_mod.DPConfig(),
                     dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                                     noise_multiplier=0.4),
                     dp_mod.DPConfig(mechanism="global", clip_norm=0.5,
                                     noise_multiplier=0.4)]:
            outs = {}
            for vect in (True, False):
                p, _, info = run_sync_round(
                    params, strat, strat.init_state(params),
                    self._results(updates, survivors),
                    round_idx=2, vg_size=4, cohort=cohort, dp_cfg=dcfg,
                    secure_cfg=sa.SecureAggConfig(
                        vectorized=vect, min_survivors_per_vg=1))
                outs[vect] = np.asarray(p["w"])
                assert info.n_selected == 11
                assert info.n_dropped == 3
                assert info.n_participants == 8
            np.testing.assert_array_equal(outs[True], outs[False])

    def test_stacked_churn_round_matches_dict_round(self):
        rng = np.random.RandomState(4)
        updates = _mk_updates(rng, 9)
        cohort = sorted(updates)
        survivors = [c for c in cohort if c not in {"c000", "c006"}]
        params = {"w": jnp.zeros(23, jnp.float32)}
        strat = FedAvg(server_lr=1.0)
        p_d, _, info_d = run_sync_round(
            params, strat, strat.init_state(params),
            self._results(updates, survivors),
            round_idx=1, vg_size=4, cohort=cohort)
        rev = list(reversed(survivors))   # stacked path re-sorts rows
        stacked = {"w": jnp.stack([updates[c] for c in rev])}
        p_s, _, info_s = run_sync_round_stacked(
            params, strat, strat.init_state(params), rev, stacked,
            [{"loss": 1.0}] * len(rev), round_idx=1, vg_size=4,
            cohort=cohort)
        np.testing.assert_array_equal(np.asarray(p_d["w"]),
                                      np.asarray(p_s["w"]))
        assert (info_d.n_selected, info_d.n_dropped) == \
            (info_s.n_selected, info_s.n_dropped) == (9, 2)

    def test_cohort_must_cover_results(self):
        rng = np.random.RandomState(2)
        updates = _mk_updates(rng, 4)
        params = {"w": jnp.zeros(23, jnp.float32)}
        strat = FedAvg(server_lr=1.0)
        with pytest.raises(ValueError, match="subset of cohort"):
            run_sync_round(params, strat, strat.init_state(params),
                           self._results(updates, sorted(updates)),
                           round_idx=0, vg_size=2,
                           cohort=sorted(updates)[:2])


# ---------------------------------------------------------------------------
# service layer + simulator
# ---------------------------------------------------------------------------

class TestServiceChurn:
    def test_round_no_longer_aborts_on_straggling_vg(self):
        """The headline behaviour: dropouts reported mid-round, the round
        completes over the survivors, and per-client vs bulk survivor
        submission produce the SAME model."""
        rng = np.random.RandomState(0)
        ups = {f"c{i}": jnp.asarray(rng.uniform(-0.2, 0.2, 8), jnp.float32)
               for i in range(8)}
        models = {}
        for path in ("per-client", "bulk"):
            svc, tid = _mk_service_task(n_rounds=1, cpr=6, n_clients=8)
            _, cohort = svc.begin_round(tid)
            dropped = cohort[:2]
            survivors = [c for c in cohort if c not in dropped]
            for cid in dropped:
                assert not svc.report_dropout(tid, cid)
            if path == "per-client":
                done = [svc.submit_update(tid, c, {"w": ups[c]}, 10,
                                          {"loss": 1.0})
                        for c in survivors]
                assert done == [False] * (len(survivors) - 1) + [True]
            else:
                stacked = {"w": jnp.stack([ups[c] for c in survivors])}
                assert svc.submit_cohort(tid, survivors, stacked, 10,
                                         [{"loss": 1.0}] * len(survivors))
            task = svc.get_task(tid)
            assert task.status is TaskStatus.COMPLETED
            h = task.history[-1]
            assert (h["n_selected"], h["n_survived"], h["n_dropped"]) == \
                (6, 4, 2)
            assert h["recovery_s"] >= 0.0
            models[path] = np.asarray(task.model["w"])
        np.testing.assert_array_equal(models["per-client"], models["bulk"])

    def test_dropout_report_completes_round(self):
        """A dropout report arriving LAST (after every survivor submitted)
        completes the round too — order independence."""
        svc, tid = _mk_service_task(n_rounds=1, cpr=4, n_clients=6)
        _, cohort = svc.begin_round(tid)
        for cid in cohort[1:]:
            assert not svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1},
                                         10)
        assert svc.report_dropout(tid, cohort[0])
        assert svc.get_task(tid).status is TaskStatus.COMPLETED

    def test_all_dropped_voids_round(self):
        svc, tid = _mk_service_task(n_rounds=1, cpr=3, n_clients=6)
        ri, cohort = svc.begin_round(tid)
        closed = [svc.report_dropout(tid, cid) for cid in cohort]
        # the LAST report closes (voids) the round
        assert closed == [False] * (len(cohort) - 1) + [True]
        task = svc.get_task(tid)
        assert task.status is TaskStatus.RUNNING      # round NOT consumed
        assert task.round_idx == ri
        # the next round re-selects (dropped members back in the pool)
        _, cohort2 = svc.begin_round(tid)
        assert len(cohort2) == 3
        assert svc.metrics.latest(tid, "round_voided") == 1.0

    def test_late_retry_cannot_rerun_closed_round(self):
        """A dropout report closes the round; a survivor's duplicate
        upload arriving after that must be rejected, not re-run the whole
        aggregation (double model step + double accountant count)."""
        # min_survivors_per_vg=1: the round must CLOSE via a 1-survivor
        # aggregation (under the default floor it would be voided instead,
        # which exercises a different path)
        svc, tid = _mk_service_task(
            n_rounds=2, cpr=2, n_clients=4,
            secure_agg=sa.SecureAggConfig(min_survivors_per_vg=1))
        _, cohort = svc.begin_round(tid)
        assert not svc.submit_update(tid, cohort[0],
                                     {"w": jnp.ones(8) * 0.1}, 10)
        assert svc.report_dropout(tid, cohort[1])     # closes the round
        task = svc.get_task(tid)
        assert task.round_idx == 1
        model_after = np.asarray(task.model["w"]).copy()
        # the straggling retry: same client, same round — must be a no-op
        assert not svc.submit_update(tid, cohort[0],
                                     {"w": jnp.ones(8) * 0.1}, 10)
        assert task.round_idx == 1
        np.testing.assert_array_equal(np.asarray(task.model["w"]),
                                      model_after)

    def test_dropped_client_submission_rejected(self):
        svc, tid = _mk_service_task(n_rounds=1, cpr=3, n_clients=6)
        _, cohort = svc.begin_round(tid)
        svc.report_dropout(tid, cohort[0])
        assert not svc.submit_update(tid, cohort[0],
                                     {"w": jnp.ones(8) * 0.1}, 10)
        # and a second report is a no-op
        assert not svc.report_dropout(tid, cohort[0])

    def test_accountant_uses_realized_participation(self):
        """Over-provisioned rounds aggregate MORE than clients_per_round
        clients; the RDP accountant must compose at the realized rate
        (survivors / pool), not the config target — else epsilon is
        under-reported."""
        from repro.core.dp import DPConfig, compute_rdp, get_privacy_spent
        dp = DPConfig(mechanism="local", clip_norm=0.5,
                      noise_multiplier=1.0)
        svc, tid = _mk_service_task(n_rounds=1, cpr=4, n_clients=8,
                                    overprovision=1.5, dp=dp)
        _, cohort = svc.begin_round(tid)          # 6 selected, all survive
        for cid in cohort:
            svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        exp_eps, _ = get_privacy_spent(compute_rdp(6 / 8, 1.0, steps=1),
                                       dp.delta)
        assert svc.epsilon(tid) == pytest.approx(exp_eps, rel=1e-9)
        wrong_eps, _ = get_privacy_spent(compute_rdp(4 / 8, 1.0, steps=1),
                                         dp.delta)
        assert abs(svc.epsilon(tid) - wrong_eps) > 1e-9

    def test_churn_summary_and_dashboard(self):
        svc, tid = _mk_service_task(n_rounds=2, cpr=4, n_clients=8,
                                    overprovision=1.25)
        for _ in range(2):
            _, cohort = svc.begin_round(tid)
            svc.report_dropout(tid, cohort[0])
            for cid in cohort[1:]:
                svc.submit_update(tid, cid, {"w": jnp.ones(8) * 0.1}, 10)
        s = svc.metrics.churn_summary(tid)
        assert s["rounds"] == 2
        assert s["selected"] == 10 and s["dropped"] == 2
        assert s["survived"] == 8
        assert 0 < s["dropout_rate"] < 1
        from repro.fl.dashboard import render_task_view
        view = render_task_view(svc, tid)
        assert "churn:" in view and "dropped=2" in view


class TestSimulatorChurn:
    def _trainer_factory(self, i):
        def trainer(blob, rnd):
            return {"w": jnp.ones(8, jnp.float32) * 0.05}, 10, {"loss": 1.0}
        return trainer

    def test_population_sim_completes_under_churn(self):
        pop = sample_population(
            14, seed=3, cfg=PopulationConfig(mean_hazard=0.1,
                                             avail_duty=0.75,
                                             avail_period=8.0))
        clients = make_population_clients(pop, self._trainer_factory)
        svc = ManagementService()
        cfg = TaskConfig("t", "app", "wf", clients_per_round=4, n_rounds=4,
                         vg_size=2, overprovision=1.5, round_timeout_s=2.0)
        tid = svc.create_task(cfg, {"w": jnp.zeros(8, jnp.float32)})
        res = run_sync_simulation(svc, tid, clients, seed=1)
        task = svc.get_task(tid)
        assert task.status is TaskStatus.COMPLETED
        assert task.round_idx == 4
        assert res.n_dropped_total >= 1          # hazard 0.1 over 14 devices
        # dropouts cost the deadline; every duration is bounded by it
        assert all(d <= 2.0 + 0.05 + 1e-9 for d in res.round_durations)
        s = svc.metrics.churn_summary(tid)
        assert s["dropped"] == res.n_dropped_total
        assert s["survived"] + s["dropped"] == s["selected"]

    def test_sim_idles_through_closed_availability_windows(self):
        """A momentarily-unreachable fleet must not end the run: the loop
        idles one deadline and re-selects once windows reopen."""
        from repro.fl.population import DeviceProfile
        from repro.fl.simulator import SimClient
        clients = {}
        for i in range(4):
            cid = f"c{i}"
            # window phase < 3 of a 10s period, offset 5: CLOSED at t=0,
            # open at t=6 (one idle deadline later)
            prof = DeviceProfile(cid, "midrange", 1.0, 0.5, 0.0,
                                 5.0, 10.0, 0.3)
            clients[cid] = SimClient(cid, self._trainer_factory(i),
                                     base_train_s=0.5, profile=prof)
        svc = ManagementService()
        cfg = TaskConfig("t", "app", "wf", clients_per_round=2, n_rounds=2,
                         vg_size=2, round_timeout_s=6.0)
        tid = svc.create_task(cfg, {"w": jnp.zeros(8, jnp.float32)})
        res = run_sync_simulation(svc, tid, clients, seed=0)
        assert svc.get_task(tid).status is TaskStatus.COMPLETED
        assert res.n_server_steps == 2
        assert res.total_time > 6.0          # idled at least one deadline

    def test_no_profiles_means_no_churn_path(self):
        """Without device profiles (and overprovision 1.0) the simulator
        must take the original loop — byte-identical legacy behaviour."""
        from repro.fl.simulator import SimClient
        clients = {f"c{i}": SimClient(f"c{i}", self._trainer_factory(i))
                   for i in range(6)}
        svc = ManagementService()
        cfg = TaskConfig("t", "app", "wf", clients_per_round=4, n_rounds=2,
                         vg_size=2)
        tid = svc.create_task(cfg, {"w": jnp.zeros(8, jnp.float32)})
        res = run_sync_simulation(svc, tid, clients, seed=0)
        assert svc.get_task(tid).status is TaskStatus.COMPLETED
        assert res.n_dropped_total == 0
        assert all("n_dropped" not in h or h["n_dropped"] == 0
                   for h in svc.get_task(tid).history)
