"""Sub-1% rounds under bit-exact secure aggregation (ISSUE 9 tentpole):
top-k sparse updates on the round-common shared-index domain, federated
LoRA adapter tuning, and both composed through the unchanged §4 privacy
chain — serial reference vs vectorized/wave/churn paths bit-identical on
the compressed payloads, error feedback converging on the quickstart
task, true per-client top-k on the async trusted boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_mod
from repro.core import lora
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core import sparse
from repro.core.orchestrator import _secure_mean_serial
from repro.core.sparse import (SparseConfig, TopKCompressor, resolve_k,
                               scatter, shared_indices, topk_indices)
from repro.core.virtual_groups import make_virtual_groups
from repro.fl.auth import AttestationAuthority
from repro.fl.server import ManagementService
from repro.fl.task import CompressionConfig, TaskConfig
from repro.core.dp import DPConfig
from repro.core.secure_agg import SecureAggConfig


# ---------------------------------------------------------------- sparse --

def test_resolve_k():
    assert resolve_k(100, k=7) == 7
    assert resolve_k(100, frac=0.05) == 5
    assert resolve_k(100, k=3, frac=0.5) == 3       # explicit k wins
    assert resolve_k(100, frac=0.0001) == 1         # clamp up
    assert resolve_k(100, k=500) == 100             # clamp down
    assert resolve_k(100) == 100                    # no knobs = dense


def test_shared_indices_deterministic_sorted_unique():
    for size, k in [(50, 3), (50, 25), (50, 49), (50, 50), (10_000, 100)]:
        a = shared_indices(size, k, round_idx=4, seed=1)
        b = shared_indices(size, k, round_idx=4, seed=1)
        np.testing.assert_array_equal(a, b)         # derived, not random
        assert a.shape == (k,)
        assert np.all(np.diff(a) > 0)               # sorted, unique
        assert a.min() >= 0 and a.max() < size
    # different rounds draw different supports (the EF coverage argument)
    r0 = shared_indices(10_000, 100, 0)
    r1 = shared_indices(10_000, 100, 1)
    assert not np.array_equal(r0, r1)


def test_shared_indices_covers_domain_over_rounds():
    size, k = 200, 20
    seen = set()
    for r in range(120):
        seen.update(shared_indices(size, k, r).tolist())
    assert len(seen) == size


def test_topk_indices_picks_largest_magnitudes():
    v = np.asarray([0.1, -5.0, 0.0, 3.0, -0.2], np.float32)
    np.testing.assert_array_equal(topk_indices(v, 2), [1, 3])
    np.testing.assert_array_equal(topk_indices(v, 5), np.arange(5))


def test_error_feedback_conserves_mass():
    """payload scatter + new residual == update + old residual, exactly:
    the residual is precisely the untransmitted remainder."""
    comp = TopKCompressor(SparseConfig(k=4), size=20)
    rng = np.random.default_rng(0)
    cids = ["a", "b"]
    prev = {c: comp.residual(c).copy() for c in cids}
    for r in range(5):
        rows = rng.normal(size=(2, 20)).astype(np.float32)
        payload = comp.compress_rows(cids, rows, r)
        idx = comp.round_indices(r)
        for j, c in enumerate(cids):
            total = rows[j] + prev[c]
            np.testing.assert_array_equal(
                scatter(payload[j], idx, 20) + comp.residual(c), total)
            assert np.all(comp.residual(c)[idx] == 0.0)
            prev[c] = comp.residual(c).copy()


def test_compressor_shape_validation():
    comp = TopKCompressor(SparseConfig(k=4), size=20)
    with pytest.raises(ValueError):
        comp.compress_rows(["a"], np.zeros((2, 20), np.float32), 0)
    with pytest.raises(ValueError):
        comp.compress_rows(["a"], np.zeros((1, 19), np.float32), 0)
    with pytest.raises(ValueError):
        comp.decompress(np.zeros(5, np.float32), 0)
    with pytest.raises(ValueError):
        TopKCompressor(SparseConfig(k=0), size=20)
    with pytest.raises(ValueError):
        TopKCompressor(SparseConfig(k=21), size=20)


def test_compress_topk_true_per_client_support():
    comp = TopKCompressor(SparseConfig(k=2), size=6)
    v = np.asarray([0.0, 9.0, -1.0, 0.5, -8.0, 0.2], np.float32)
    idx, vals, dense = comp.compress_topk("c", v)
    np.testing.assert_array_equal(idx, [1, 4])
    np.testing.assert_array_equal(vals, [9.0, -8.0])
    np.testing.assert_array_equal(dense, scatter(vals, idx, 6))
    # the residual holds exactly what was not sent
    np.testing.assert_array_equal(comp.residual("c"), v - dense)
    # next call folds the residual back in
    idx2, vals2, _ = comp.compress_topk("c", np.zeros(6, np.float32))
    np.testing.assert_array_equal(idx2, [2, 3])


# ------------------------------------------- sync secure-agg bit-parity --

def _payload_round(n, size, k, seed):
    rng = np.random.RandomState(seed)
    flat = rng.uniform(-1.0, 1.0, (n, size)).astype(np.float32)
    comp = TopKCompressor(SparseConfig(k=k), size)
    cids = [f"c{i:03d}" for i in range(n)]
    payload = comp.compress_rows(cids, flat, round_idx=seed % 5)
    return cids, payload


@pytest.mark.parametrize("mech", ["off", "local", "global"])
def test_compressed_payload_serial_vs_vectorized_vs_wave(mech):
    """The (n, k) shared-support payload through the chain: serial
    reference, single vectorized dispatch, and streaming waves (dividing
    AND non-dividing wave widths) all produce identical bits."""
    n, size, k = 11, 60, 9
    cids, payload = _payload_round(n, size, k, seed=3)
    plan = make_virtual_groups(cids, 4, seed=3)
    round_seed = jnp.asarray([7, 11], jnp.uint32)
    key = jax.random.PRNGKey(5)
    dcfg = dp_mod.DPConfig(mechanism=mech, clip_norm=0.5,
                           noise_multiplier=0.7 if mech != "off" else 0.0)
    scfg = sa.SecureAggConfig()
    serial = _secure_mean_serial(
        {c: jnp.asarray(payload[j]) for j, c in enumerate(cids)},
        plan, round_seed, key, scfg, dcfg)
    vect = pe.aggregate_flat(jnp.asarray(payload), plan, cids, round_seed,
                             secure_cfg=scfg, dp_cfg=dcfg, key=key)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))
    for wave in (4, 5, n - 1):
        waved = pe.aggregate_flat(
            jnp.asarray(payload), plan, cids, round_seed,
            secure_cfg=sa.SecureAggConfig(wave_clients=wave),
            dp_cfg=dcfg, key=key)
        np.testing.assert_array_equal(np.asarray(serial),
                                      np.asarray(waved))


def _tiny_model():
    return {"w": jnp.zeros((8, 5), jnp.float32),
            "b": jnp.zeros((5,), jnp.float32)}


def _updates(n, seed):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}
            for _ in range(n)]


def _run_service_rounds(vectorized, dp_mech, drop=(), rounds=3):
    svc = ManagementService(seed=0)
    cfg = TaskConfig(
        "t", "a", "w", clients_per_round=6, n_rounds=rounds + 2, vg_size=3,
        secure_agg=SecureAggConfig(vectorized=vectorized),
        dp=DPConfig(mechanism=dp_mech, clip_norm=1.0,
                    noise_multiplier=0.5 if dp_mech != "off" else 0.0),
        compression=CompressionConfig(kind="topk", frac=0.3))
    tid = svc.create_task(cfg, _tiny_model())
    auth = AttestationAuthority()
    for i in range(6):
        assert svc.register_client(
            tid, f"c{i}", {"os": "linux", "n_samples": 10, "battery": 0.9},
            auth.issue(f"c{i}"))
    models = []
    for r in range(rounds):
        _, cohort = svc.begin_round(tid)
        assert cohort
        ups = _updates(len(cohort), seed=100 + r)
        for cid in drop:
            svc.report_dropout(tid, cid)
        for j, cid in enumerate(sorted(cohort)):
            if cid in drop:
                continue
            svc.submit_update(tid, cid, ups[j], n_samples=10)
        models.append(np.asarray(svc.get_task(tid).model["w"]).copy())
    return models, svc.get_task(tid).history


@pytest.mark.parametrize("mech", ["off", "local", "global"])
def test_compressed_rounds_service_parity(mech):
    """Service-level multi-round parity (residuals carried across rounds):
    serial and vectorized tasks evolve bit-identically under top-k."""
    vect, hist_v = _run_service_rounds(True, mech)
    ser, _ = _run_service_rounds(False, mech)
    for a, b in zip(vect, ser):
        np.testing.assert_array_equal(a, b)
    # upload telemetry: k f32 per client, and < dense bytes
    assert hist_v[0]["upload_bytes_per_client"] == resolve_k(
        45, frac=0.3) * 4
    assert hist_v[0]["upload_bytes_per_client"] < 45 * 4


def test_compressed_churn_parity():
    """Dropout mid-round over sparse interims: serial survivor loop and
    vectorized recovery agree bit-for-bit; residuals of the dropped
    client are untouched (it never transmitted)."""
    vect, hist = _run_service_rounds(True, "off", drop=("c2",))
    ser, _ = _run_service_rounds(False, "off", drop=("c2",))
    for a, b in zip(vect, ser):
        np.testing.assert_array_equal(a, b)
    assert hist[0]["n_dropped"] == 1


def test_voided_round_consumes_residuals_of_transmitters_only():
    """Residual semantics under refusal: compression happens at
    transmission, so clients that sent a payload into a round the server
    later voids have consumed their residual — exactly like a real device
    that cannot know the round's server-side fate."""
    comp = TopKCompressor(SparseConfig(k=3), size=10)
    rows = np.ones((2, 10), np.float32)
    comp.compress_rows(["a", "b"], rows, 0)
    assert np.any(comp.residual("a") != 0.0)    # remainder carried
    assert not comp._residuals.get("c", np.zeros(1)).any()


# ------------------------------------------------------------ async path --

def _run_async(batch):
    svc = ManagementService(seed=0)
    cfg = TaskConfig("t", "a", "w", clients_per_round=4, n_rounds=3,
                     mode="async", buffer_size=4, vg_size=2,
                     compression=CompressionConfig(kind="topk", frac=0.3))
    tid = svc.create_task(cfg, _tiny_model())
    auth = AttestationAuthority()
    for i in range(8):
        assert svc.register_client(
            tid, f"c{i}", {"os": "linux", "n_samples": 10, "battery": 0.9},
            auth.issue(f"c{i}"))
    ups = _updates(8, seed=7)
    cids = [f"c{i}" for i in range(8)]
    if batch:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ups)
        svc.submit_updates_async(tid, cids, stacked, [10] * 8, [0] * 8)
    else:
        for cid, u in zip(cids, ups):
            svc.submit_update(tid, cid, u, n_samples=10, update_version=0)
    return np.asarray(svc.get_task(tid).model["w"]), \
        svc.get_task(tid).history


def test_async_topk_serial_batch_parity():
    """True per-client top-k at the trusted boundary: k submit_update
    calls and one fused submit_updates_async batch land the same model;
    upload accounting includes the shipped indices (k * 8 bytes)."""
    m_serial, hist = _run_async(batch=False)
    m_batch, _ = _run_async(batch=True)
    np.testing.assert_array_equal(m_serial, m_batch)
    assert hist[0]["upload_bytes_per_client"] == resolve_k(
        45, frac=0.3) * 8


# ------------------------------------------------------------ convergence --

def test_topk_error_feedback_converges_on_quickstart():
    """The acceptance bar: top-k at 10% with error feedback still trains
    the quickstart spam task — test accuracy climbs well above the
    initial model, and the residual carry is what does it (plain rand-k
    without error feedback is the ablation that barely moves).

    Deterministic end-to-end (seeded simulator, seeded draw), so the
    margins are stable; measured: initial 0.494, rand-k 0.506, EF 0.565,
    dense 0.629 over 16 rounds."""
    from benchmarks.common import SpamWorld
    from repro.fl.simulator import SimClient, run_sync_simulation
    from repro.fl.task import SelectionCriteria

    def run(comp_cfg):
        world = SpamWorld(vocab=256, d_model=32, seq_len=8, n_train=1000,
                          n_splits=10, batch_size=2, d_ff=64, head_dim=16)
        svc = ManagementService(seed=0)
        cfg = TaskConfig(
            "spam", "app", "wf", clients_per_round=6, n_rounds=16,
            vg_size=3,
            selection=SelectionCriteria(require_attestation=False),
            compression=comp_cfg)
        tid = svc.create_task(cfg, world.model0)
        sim_clients = {f"client-{i:04d}":
                       SimClient(f"client-{i:04d}", world.make_trainer(i))
                       for i in range(10)}
        engine = world.make_engine(local_steps=2, batch_size=2)
        run_sync_simulation(svc, tid, sim_clients, engine=engine)
        return (world.test_accuracy(world.model0),
                world.test_accuracy(svc.get_task(tid).model))

    acc0, ef = run(CompressionConfig(kind="topk", frac=0.1,
                                     error_feedback=True))
    assert ef > acc0 + 0.05, (acc0, ef)
    _, no_ef = run(CompressionConfig(kind="topk", frac=0.1,
                                     error_feedback=False))
    assert ef > no_ef + 0.03, (ef, no_ef)


# ----------------------------------------------------------------- LoRA --

def _lora_world():
    from benchmarks.common import SpamWorld
    return SpamWorld(vocab=256, d_model=32, seq_len=8, n_train=1000,
                     n_splits=10, batch_size=2, d_ff=64, head_dim=16)


def test_lora_merge_is_identity_at_init():
    """B = 0 at init: merge returns the base bit-for-bit, so round 0
    starts from exactly the broadcast model."""
    world = _lora_world()
    cfg = lora.LoRAConfig(rank=2, min_dim=8)
    adapters = lora.init_adapters(cfg, world.model0, jax.random.PRNGKey(1))
    merged = lora.merge(cfg, world.model0, adapters)
    for a, b in zip(jax.tree.leaves(world.model0),
                    jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ab in adapters.values():
        assert ab["A"].shape[-1] == 2 and ab["B"].shape[-2] == 2
        # scan-stacked leaves factor per layer: leading dims must agree
        assert ab["A"].shape[:-2] == ab["B"].shape[:-2]
        assert not np.asarray(ab["B"]).any()


def test_lora_target_paths_and_include_filter():
    world = _lora_world()
    all_paths = lora.target_paths(lora.LoRAConfig(rank=2, min_dim=8),
                                  world.model0)
    assert all_paths
    attn = lora.target_paths(
        lora.LoRAConfig(rank=2, min_dim=8, include=("attn",)),
        world.model0)
    assert attn and set(attn) < set(all_paths)
    assert all("attn" in p for p in attn)
    with pytest.raises(ValueError):
        lora.init_adapters(
            lora.LoRAConfig(rank=2, include=("nope",)), world.model0,
            jax.random.PRNGKey(0))


def test_lora_upload_fraction_counts():
    world = _lora_world()
    cfg = lora.LoRAConfig(rank=2, min_dim=8)
    adapters = lora.init_adapters(cfg, world.model0, jax.random.PRNGKey(1))
    frac = lora.upload_fraction(cfg, world.model0)
    assert frac == pytest.approx(
        lora.n_params(adapters) / lora.n_params(world.model0))
    # works on abstract shapes (the bench's <1% check needs this)
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.float32),
        world.model0)
    assert lora.upload_fraction(cfg, abstract) == frac


def test_lora_federated_round_trains_and_is_bit_exact():
    """Federated LoRA end-to-end on the quickstart task: the task's model
    IS the adapters pytree, clients train adapters against the frozen
    base via CohortEngine, the adapter delta flows through the unchanged
    secure-agg chain (serial == vectorized bitwise), and loss drops."""
    from repro.core.cohort_engine import CohortEngine
    from repro.models import classify_loss
    from repro.optim import adamw

    world = _lora_world()
    lcfg = lora.LoRAConfig(rank=2, min_dim=8, alpha=4.0)
    base = world.model0
    adapters0 = lora.init_adapters(lcfg, base, jax.random.PRNGKey(1))
    assert lora.upload_fraction(lcfg, base) < 0.5

    spec = lora.lora_spec(
        lcfg, base,
        lambda m, b: classify_loss(world.cfg, m["trunk"], m["head"], b),
        adamw(lr=5e-3), local_steps=2)
    engine = CohortEngine(spec, world.engine_batch_fn(2, 2),
                          template_params=adapters0)
    cids = [f"client-{i:04d}" for i in range(6)]

    def run(vectorized):
        svc = ManagementService(seed=0)
        from repro.fl.task import SelectionCriteria
        cfg = TaskConfig(
            "lora", "app", "wf", clients_per_round=6, n_rounds=6,
            vg_size=3, secure_agg=SecureAggConfig(vectorized=vectorized),
            selection=SelectionCriteria(require_attestation=False))
        tid = svc.create_task(cfg, adapters0)
        for c in cids:
            assert svc.register_client(tid, c, {"os": "linux",
                                                "n_samples": 10})
        losses = []
        for r in range(4):
            _, cohort = svc.begin_round(tid)
            model = svc.get_task(tid).model
            deltas, losses_r, n = engine.run_cohort_stacked(
                model, sorted(cohort), r)
            svc.submit_cohort(tid, sorted(cohort), deltas, n)
            losses.append(float(np.mean(np.asarray(losses_r))))
        return svc.get_task(tid).model, losses

    model_v, losses_v = run(True)
    model_s, losses_s = run(False)
    for a, b in zip(jax.tree.leaves(model_v), jax.tree.leaves(model_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert losses_v[-1] < losses_v[0], losses_v
    # the trained adapters actually moved the merged model
    merged = lora.merge(lcfg, base, model_v)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(base),
                               jax.tree.leaves(merged)))


def test_lora_composes_with_topk():
    """LoRA + top-k: the compressed adapter delta still aggregates
    bit-identically serial vs vectorized (compression composes with, not
    through, the factoring)."""
    world = _lora_world()
    lcfg = lora.LoRAConfig(rank=2, min_dim=8)
    adapters0 = lora.init_adapters(lcfg, world.model0,
                                   jax.random.PRNGKey(1))
    size = lora.n_params(adapters0)
    rng = np.random.default_rng(0)
    n = 6
    cids = [f"c{i}" for i in range(n)]
    flat = rng.normal(size=(n, size)).astype(np.float32)
    plan = make_virtual_groups(cids, 3, seed=0)
    round_seed = jnp.asarray([1, 2], jnp.uint32)
    key = jax.random.PRNGKey(0)
    comp = TopKCompressor(SparseConfig(k=max(1, size // 100)), size)
    payload = comp.compress_rows(cids, flat, 0)
    serial = _secure_mean_serial(
        {c: jnp.asarray(payload[j]) for j, c in enumerate(cids)},
        plan, round_seed, key, sa.SecureAggConfig(), dp_mod.DPConfig())
    vect = pe.aggregate_flat(jnp.asarray(payload), plan, cids, round_seed,
                             key=key)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(vect))
    assert comp.payload_bytes() < 0.02 * size * 4
