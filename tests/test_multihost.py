"""Multi-host emulation lane: the production fl_round on an 8-device host.

The in-process suite runs on ONE device by design (conftest.py forbids
setting ``xla_force_host_platform_device_count`` globally — every other
test and bench must see the single-device world). This lane spawns a
fresh python with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in the subprocess env only, builds a real (pod=4, data=2) mesh, and runs
the per_pod fl_round: the stage-2 combine must take the shard_map pod
route (per-pod limb states + one uint32 psum across 4 pods) and training
must still reduce loss. Marked ``multihost``; deselect with
``-m 'not multihost'`` when iterating.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import jax
assert jax.device_count() == 8, f"expected 8 emulated devices, got {jax.devices()}"
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.configs import get_reduced_config
from repro.launch.fl_step import make_fl_train_step
from repro.models import init_params
from repro.optim import adamw

cfg = get_reduced_config("deepseek-67b")
assert cfg.fl_scheme == "per_pod"
# 4 pods x 2-way data (FSDP inside each pod-silo); vg_size=1 keeps the VG
# axis divisible by the pod axis so stage 2 takes the shard_map route
mesh = compat.make_mesh((4, 2, 1), ("pod", "data", "model"))
with compat.set_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw().init(params)
    step, meta = make_fl_train_step(cfg, mesh, secure=True, vg_size=1,
                                    microbatches=1, server_lr=5e-3)
    assert meta["stage2_pod_axis"] == "pod", meta
    assert meta["n_silos"] == 4, meta
    step = jax.jit(step)
    rng = np.random.RandomState(0)
    b, s = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, b, s)),
                               jnp.int32),
        "mask": jnp.ones((4, b, s), jnp.float32),
    }
    losses = []
    for i in range(4):
        seed = jnp.asarray([i, i + 1], jnp.uint32)
        params, opt_state, loss = step(params, opt_state, batch, seed)
        losses.append(float(loss))
assert losses[-1] < losses[0], losses
print("MULTIHOST_OK", losses)
"""


@pytest.mark.multihost
def test_per_pod_round_on_emulated_8_device_host():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          cwd=REPO, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIHOST_OK" in proc.stdout, proc.stdout
