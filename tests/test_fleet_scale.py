"""Fleet-scale refactor invariants: the array-backed directory/selection
must reproduce the legacy dict-based control plane EXACTLY — same pools,
same RNG draw sequence, same lease interactions — while the wave-streamed
execution paths must be bit-identical to their single-dispatch twins, and
the id-padding fix must keep lexicographic pools ordered past 10^4 devices.
"""
import random

import numpy as np
import pytest

from repro.fl import (DeviceDirectory, ManagementService, PopulationArrays,
                      SelectionService, TaskConfig, client_id, client_ids,
                      sample_population)
from repro.fl.task import SelectionCriteria, TaskRecord

INFO = {"os": "linux", "n_samples": 100, "battery": 1.0}
_CRIT = SelectionCriteria(require_attestation=False)


def _task(task_id: int, k: int = 4) -> TaskRecord:
    return TaskRecord(config=TaskConfig(f"t{task_id}", "app", "wf",
                                        clients_per_round=k, n_rounds=5,
                                        vg_size=2, selection=_CRIT),
                      model={"w": np.zeros(4, np.float32)},
                      task_id=task_id)


# ---------------------------------------------------------------------------
# the legacy dict-based reference, reconstructed verbatim in shape
# ---------------------------------------------------------------------------

class LegacyRef:
    """The pre-refactor selection/lease semantics: per-task status dicts,
    a cid -> task lease dict, sorted-comprehension pools, and one shared
    ``random.Random``. The array service must match its draws element for
    element."""

    def __init__(self, seed=0):
        self.rng = random.Random(seed)
        self.status: dict = {}     # task_id -> {cid: status}
        self.leases: dict = {}     # cid -> task_id

    def register(self, tid, cid):
        self.status.setdefault(tid, {})[cid] = "registered"

    def pool(self, tid, available=None):
        pool = sorted(c for c, s in self.status[tid].items()
                      if s == "registered"
                      and self.leases.get(c, tid) == tid)
        if available is not None:
            pool = [c for c in pool if available(c)]
        return pool

    def select(self, tid, k, available=None):
        pool = self.pool(tid, available)
        picks = self.rng.sample(pool, min(k, len(pool)))
        for c in picks:
            self.status[tid][c] = "selected"
            self.leases[c] = tid
        return sorted(picks)

    def mark(self, tid, cid, status):
        self.status[tid][cid] = status

    def reset(self, tid):
        st = self.status[tid]
        for c, s in st.items():
            if s in ("selected", "done", "dropped"):
                st[c] = "registered"
        for c in [c for c, t in self.leases.items() if t == tid]:
            del self.leases[c]


def _fresh_pair(n, seed=0):
    """(array-backed service + two tasks, legacy reference) over the same
    n-device population, both enrolled in both tasks."""
    svc = SelectionService(seed=seed, directory=DeviceDirectory())
    ref = LegacyRef(seed=seed)
    t1, t2 = _task(1), _task(2)
    for cid in client_ids(n):
        assert svc.register(t1, cid, dict(INFO))
        assert svc.register(t2, cid, dict(INFO))
        ref.register(1, cid)
        ref.register(2, cid)
    return svc, ref, t1, t2


@pytest.mark.parametrize("n", [10, 100, 1000])
def test_pool_and_draw_match_legacy_two_tasks(n):
    """The tentpole compat property: pools, cohort draws, and cross-task
    lease interactions are element-for-element identical to the legacy
    dict path at the same seed — through multiple rounds of two tasks
    interleaving selections over ONE shared fleet."""
    svc, ref, t1, t2 = _fresh_pair(n, seed=7)
    k = max(2, n // 8)
    t1.config.clients_per_round = k
    t2.config.clients_per_round = k
    for _ in range(3):
        assert svc.available(t1) == ref.pool(1)
        c1 = svc.select_cohort(t1)
        r1 = ref.select(1, k)
        assert c1 == r1
        # task 2's pool must exclude task 1's leased devices, identically
        assert svc.available(t2) == ref.pool(2)
        c2 = svc.select_cohort(t2)
        r2 = ref.select(2, k)
        assert c2 == r2
        assert not set(c1) & set(c2)
        # a couple of members finish, one drops — status parity
        svc.mark(t1, c1[0], "done")
        ref.mark(1, c1[0], "done")
        svc.drop(t1, c1[1])
        ref.mark(1, c1[1], "dropped")
        # NOTE: legacy kept the dropped device leased until reset; the
        # array directory releases it immediately (physical availability)
        # — but task 1's own pool keeps it out until reset, and the
        # legacy ref's pool() for task 2 uses leases, so align the ref
        del ref.leases[c1[1]]
        assert svc.statuses(t1) == ref.status[1]
        svc.reset_round(t1)
        ref.reset(1)
        svc.reset_round(t2)
        ref.reset(2)


@pytest.mark.parametrize("n", [10, 100, 1000])
def test_availability_filter_parity(n):
    """Same draw whether the availability filter is the legacy callable
    predicate or the vectorized whole-fleet mask array."""
    from repro.fl.population import PopulationConfig
    pop = PopulationArrays.sample(
        n, seed=3, cfg=PopulationConfig(avail_duty=0.6, duty_jitter=0.3))
    t_clock = 5.0
    mask = pop.available_mask(t_clock)
    by_id = dict(zip(pop.ids, mask.tolist()))
    if not (4 <= int(mask.sum())):
        pytest.skip("degenerate availability draw")

    def run(available):
        svc = SelectionService(seed=11, directory=DeviceDirectory())
        task = _task(1, k=4)
        for cid in pop.ids:
            svc.register(task, cid, dict(INFO))
        return svc.select_cohort(task, available=available)

    c_callable = run(lambda cid: by_id[cid])
    c_mask = run(mask)
    assert c_callable == c_mask
    assert all(by_id[c] for c in c_mask)


def test_register_fleet_matches_per_device_register():
    """Bulk enrollment lands the identical pool (and draws) as n SDK
    registrations."""
    n = 200
    pop = PopulationArrays.sample(n, seed=5)
    bulk = SelectionService(seed=2, directory=DeviceDirectory())
    t_bulk = _task(1, k=8)
    assert bulk.register_fleet(t_bulk, pop, device_info=dict(INFO)) == n
    per = SelectionService(seed=2, directory=DeviceDirectory())
    t_per = _task(1, k=8)
    for i, cid in enumerate(pop.ids):
        per.register(t_per, cid, dict(INFO), profile=pop.profile(i))
    assert bulk.available(t_bulk) == per.available(t_per)
    assert bulk.select_cohort(t_bulk) == per.select_cohort(t_per)
    d1, d2 = bulk.directory, per.directory
    for i in range(0, n, 37):
        assert d1._devices[pop.ids[i]].profile == \
            d2._devices[pop.ids[i]].profile


def test_register_fleet_refuses_attestation():
    svc = SelectionService(seed=0, directory=DeviceDirectory())
    task = _task(1)
    task.config.selection = SelectionCriteria(require_attestation=True)
    with pytest.raises(ValueError, match="attest"):
        svc.register_fleet(task, PopulationArrays.sample(8, seed=0))


# ---------------------------------------------------------------------------
# id padding past 10^4 devices
# ---------------------------------------------------------------------------

def test_client_id_legacy_width_preserved():
    """<= 10^4-device populations keep their historical 4-digit ids bit
    for bit (seed compatibility); larger fleets get uniform 7-digit ids."""
    assert client_id(3, 100) == "client-0003"
    assert client_id(9999, 10_000) == "client-9999"
    assert client_id(3, 10_001) == "client-0000003"
    assert sample_population(5, seed=0)[4].client_id == "client-0004"


def test_sorted_pool_ordering_at_12000_devices():
    """The regression the 4-digit pad caused: past 9,999 devices the
    lexicographic pool order must still equal numeric device order
    ('client-10000' sorted before 'client-2000' under the old ids)."""
    n = 12_000
    ids = client_ids(n)
    assert sorted(ids) == ids                      # lex == index order
    assert ids[10_000] == "client-0010000"
    svc = SelectionService(seed=0, directory=DeviceDirectory())
    task = _task(1, k=16)
    pop = PopulationArrays.sample(n, seed=0)
    svc.register_fleet(task, pop, device_info=dict(INFO))
    pool = svc.available(task)
    assert pool == ids                             # registered == sorted
    assert svc.n_available(task) == n


# ---------------------------------------------------------------------------
# PopulationArrays
# ---------------------------------------------------------------------------

def test_population_arrays_deterministic():
    a = PopulationArrays.sample(500, seed=9)
    b = PopulationArrays.sample(500, seed=9)
    assert a.ids == b.ids
    np.testing.assert_array_equal(a.tier_code, b.tier_code)
    np.testing.assert_array_equal(a.speed, b.speed)
    np.testing.assert_array_equal(a.avail_offset, b.avail_offset)


def test_population_arrays_available_mask_matches_profiles():
    from repro.fl.population import PopulationConfig
    pop = PopulationArrays.sample(
        300, seed=4, cfg=PopulationConfig(avail_duty=0.5, duty_jitter=0.3))
    for t in (0.0, 3.7, 11.2, 23.9, 101.5):
        mask = pop.available_mask(t)
        expect = [pop.profile(i).available_at(t) for i in range(len(pop))]
        np.testing.assert_array_equal(mask, np.asarray(expect))


def test_population_arrays_from_profiles_round_trip():
    profiles = sample_population(64, seed=13)
    pop = PopulationArrays.from_profiles(profiles)
    assert pop.ids == [p.client_id for p in profiles]
    assert pop.profiles() == profiles


# ---------------------------------------------------------------------------
# wave streaming bit-parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,vg,wave,mech", [
    (64, 8, 16, "off"),
    (64, 8, 16, "local"),
    (60, 8, 16, "global"),    # ragged plan: two bucket shapes
    (33, 5, 11, "local"),     # wave not a multiple of vg size
])
def test_privacy_wave_aggregate_bit_identical(n, vg, wave, mech):
    """The ISSUE acceptance: a cohort streamed through fixed-width waves
    folds partial VG/limb sums into EXACTLY the single-dispatch result."""
    import jax.numpy as jnp
    from repro.core import privacy_engine as pe
    from repro.core.dp import DPConfig
    from repro.core.secure_agg import SecureAggConfig
    from repro.core.virtual_groups import make_virtual_groups
    cids = [f"c{i:03d}" for i in range(n)]
    plan = make_virtual_groups(cids, vg, seed=2)
    flat = jnp.asarray(np.random.RandomState(n).standard_normal(
        (n, 48)).astype(np.float32) * 0.03)
    dp = DPConfig() if mech == "off" else DPConfig(
        mechanism=mech, clip_norm=0.5, noise_multiplier=0.8)
    kw = dict(dp_cfg=dp)
    if mech != "off":
        import jax
        kw["key"] = jax.random.PRNGKey(5)
    one = pe.aggregate_flat(flat, plan, cids, (21, 22),
                            secure_cfg=SecureAggConfig(), **kw)
    waved = pe.aggregate_flat(
        flat, plan, cids, (21, 22),
        secure_cfg=SecureAggConfig(wave_clients=wave), **kw)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(waved))


def test_cohort_engine_wave_matches_single_dispatch():
    """Waved local training (10 clients through 4-wide waves) returns the
    same per-client deltas and losses as one full-cohort dispatch."""
    from benchmarks.common import SpamWorld
    from repro.core.cohort_engine import CohortEngine
    world = SpamWorld(vocab=128, d_model=16, seq_len=8, n_train=400,
                      n_splits=5, batch_size=2, d_ff=32, head_dim=8)
    engine = world.make_engine(local_steps=2, batch_size=2)
    waved = CohortEngine(engine.spec, engine.batch_fn,
                         template_params=world.model0, wave_size=4)
    cids = [f"client-{i:04d}" for i in range(10)]
    d1, l1, n1 = engine.run_cohort_stacked(world.model0, cids, round_idx=0)
    d2, l2, n2 = waved.run_cohort_stacked(world.model0, cids, round_idx=0)
    assert n1 == n2
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    import jax
    for a, b in zip(jax.tree.leaves(d1), jax.tree.leaves(d2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wave_round_through_management_service():
    """End to end: a task whose SecureAggConfig streams waves completes a
    round with the identical model as the unwaved twin."""
    from dataclasses import replace

    def run(wave):
        svc = ManagementService(seed=0)
        cfg = TaskConfig("wave", "app", "wf", clients_per_round=24,
                         n_rounds=1, vg_size=4, selection=_CRIT)
        cfg.secure_agg = replace(cfg.secure_agg, wave_clients=wave)
        tid = svc.create_task(cfg, {"w": np.zeros(32, np.float32)})
        svc.register_fleet(tid, PopulationArrays.sample(64, seed=1))
        _, cohort = svc.begin_round(tid)
        rng = np.random.RandomState(0)
        stacked = {"w": rng.standard_normal(
            (len(cohort), 32)).astype(np.float32) * 0.01}
        assert svc.submit_cohort(tid, cohort, stacked, n_samples=5)
        return np.asarray(svc.get_task(tid).model["w"])

    np.testing.assert_array_equal(run(0), run(8))
