"""CLI + dashboard rendering (paper §3.3)."""
import jax.numpy as jnp

from repro.fl import ManagementService, TaskConfig
from repro.fl.dashboard import (render_metrics, render_task_list,
                                render_task_view, sparkline)


def _svc_with_task(**kw):
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig("spam-demo", "app", "wf", clients_per_round=2,
                   n_rounds=3, vg_size=2, **kw),
        {"w": jnp.zeros(4)})
    return svc, tid


def test_sparkline():
    assert sparkline([]) == "(no data)"
    s = sparkline([0, 1, 2, 3])
    assert len(s) == 4 and s[0] != s[-1]


def test_task_list_and_view():
    svc, tid = _svc_with_task()
    out = render_task_list(svc)
    assert "spam-demo" in out and "running" in out
    view = render_task_view(svc, tid)
    assert "rounds: 0/3" in view and "fedavg" in view


def test_metrics_render():
    svc, tid = _svc_with_task()
    svc.metrics.log(tid, 1, accuracy=0.5)
    svc.metrics.log(tid, 2, accuracy=0.8)
    out = render_metrics(svc, tid)
    assert "accuracy" in out and "last=0.8" in out


def test_cli_session_round_trip(tmp_path):
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "t1",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    svc = cli.load_service(session)
    tasks = svc.list_tasks()
    assert len(tasks) == 1 and tasks[0].config.task_name == "t1"
    cli.main(["--session", session, "pause", str(tasks[0].task_id)])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "paused"
    cli.main(["--session", session, "list"])
    cli.main(["--session", session, "show", str(tasks[0].task_id)])


def test_cli_session_reload_never_reuses_task_ids(tmp_path):
    """Regression: task ids must be derived from the service's task
    store, not a process-global counter — a create in a FRESH process
    against a reloaded session used to collide with (and clobber) the
    task created before the save."""
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "first",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    # a fresh python process has a fresh module counter; simulate it by
    # resetting the fallback counter before the reloaded create
    import repro.fl.task as task_mod
    task_mod._task_counter = 0
    cli.main(["--session", session, "create", "--task-name", "second",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    svc = cli.load_service(session)
    tasks = svc.list_tasks()
    assert len(tasks) == 2
    names = {t.config.task_name for t in tasks}
    assert names == {"first", "second"}
    ids = [t.task_id for t in tasks]
    assert len(set(ids)) == 2


def test_cli_deploy_and_registry(tmp_path, capsys):
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "t1",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2", "--no-deploy"])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "created"
    tid = svc.list_tasks()[0].task_id
    cli.main(["--session", session, "deploy", str(tid)])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "running"
    cli.main(["--session", session, "registry"])
    assert "no published models" in capsys.readouterr().out
    cli.main(["--session", session, "fleet"])
    assert "fleet:" in capsys.readouterr().out


def test_fleet_render():
    from repro.fl.dashboard import render_fleet
    from repro.fl.scheduler import ControlPlane
    svc, tid = _svc_with_task()
    out = render_fleet(ControlPlane(svc))
    assert "spam-demo" in out and "registry: 0" in out
