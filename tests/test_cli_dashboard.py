"""CLI + dashboard rendering (paper §3.3)."""
import jax.numpy as jnp
import numpy as np

from repro import tracing
from repro.fl import ManagementService, TaskConfig
from repro.fl.dashboard import (render_metrics, render_status,
                                render_task_list, render_task_view,
                                render_trace, sparkline)


def _svc_with_task(**kw):
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig("spam-demo", "app", "wf", clients_per_round=2,
                   n_rounds=3, vg_size=2, **kw),
        {"w": jnp.zeros(4)})
    return svc, tid


def test_sparkline():
    assert sparkline([]) == "(no data)"
    s = sparkline([0, 1, 2, 3])
    assert len(s) == 4 and s[0] != s[-1]


def test_task_list_and_view():
    svc, tid = _svc_with_task()
    out = render_task_list(svc)
    assert "spam-demo" in out and "running" in out
    view = render_task_view(svc, tid)
    assert "rounds: 0/3" in view and "fedavg" in view


def test_metrics_render():
    svc, tid = _svc_with_task()
    svc.metrics.log(tid, 1, accuracy=0.5)
    svc.metrics.log(tid, 2, accuracy=0.8)
    out = render_metrics(svc, tid)
    assert "accuracy" in out and "last=0.8" in out


def test_cli_session_round_trip(tmp_path):
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "t1",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    svc = cli.load_service(session)
    tasks = svc.list_tasks()
    assert len(tasks) == 1 and tasks[0].config.task_name == "t1"
    cli.main(["--session", session, "pause", str(tasks[0].task_id)])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "paused"
    cli.main(["--session", session, "list"])
    cli.main(["--session", session, "show", str(tasks[0].task_id)])


def test_cli_session_reload_never_reuses_task_ids(tmp_path):
    """Regression: task ids must be derived from the service's task
    store, not a process-global counter — a create in a FRESH process
    against a reloaded session used to collide with (and clobber) the
    task created before the save."""
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "first",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    # a fresh python process has a fresh module counter; simulate it by
    # resetting the fallback counter before the reloaded create
    import repro.fl.task as task_mod
    task_mod._task_counter = 0
    cli.main(["--session", session, "create", "--task-name", "second",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    svc = cli.load_service(session)
    tasks = svc.list_tasks()
    assert len(tasks) == 2
    names = {t.config.task_name for t in tasks}
    assert names == {"first", "second"}
    ids = [t.task_id for t in tasks]
    assert len(set(ids)) == 2


def test_cli_deploy_and_registry(tmp_path, capsys):
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "t1",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2", "--no-deploy"])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "created"
    tid = svc.list_tasks()[0].task_id
    cli.main(["--session", session, "deploy", str(tid)])
    svc = cli.load_service(session)
    assert svc.list_tasks()[0].status.value == "running"
    cli.main(["--session", session, "registry"])
    assert "no published models" in capsys.readouterr().out
    cli.main(["--session", session, "fleet"])
    assert "fleet:" in capsys.readouterr().out


def test_fleet_render():
    from repro.fl.dashboard import render_fleet
    from repro.fl.scheduler import ControlPlane
    svc, tid = _svc_with_task()
    out = render_fleet(ControlPlane(svc))
    assert "spam-demo" in out and "registry: 0" in out


# ---------------------------------------------------------------------------
# renderer edge cases (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def test_sparkline_constant_series():
    # all-equal values: the range fallback must not divide by zero, and
    # every point lands on the same block
    s = sparkline([2.0] * 10)
    assert len(s) == 10 and len(set(s)) == 1
    assert sparkline([0.0]) != "(no data)"


def test_sparkline_window_width():
    s = sparkline(list(range(200)), width=48)
    assert len(s) == 48
    assert s[-1] == sparkline([0, 1])[-1]   # max block at the tail


def test_task_list_alignment_past_round_99():
    svc = ManagementService()
    t1 = svc.create_task(
        TaskConfig("long-runner", "app", "wf", clients_per_round=2,
                   n_rounds=150, vg_size=2), {"w": jnp.zeros(4)})
    t2 = svc.create_task(
        TaskConfig("fresh", "app", "wf", clients_per_round=2,
                   n_rounds=3, vg_size=2), {"w": jnp.zeros(4)})
    svc.get_task(t1).round_idx = 120
    out = render_task_list(svc)
    assert "120/150" in out
    # 3-digit round fields keep every data row the same width — the old
    # 2-digit format drifted the columns once a task passed round 99
    lines = out.splitlines()
    assert len({len(ln) for ln in lines[2:]}) == 1


# ---------------------------------------------------------------------------
# scripted 2-task simulation driving every renderer (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def _trainer_factory(i):
    def trainer(blob, round_idx):
        return {"w": np.full(8, 0.01, np.float32)}, 10, {"loss": 1.0}
    return trainer


def _run_two_task_sim(tmp_path):
    from repro.fl import ControlPlane, run_multi_task_simulation
    from repro.fl.simulator import make_heterogeneous_clients
    plane = ControlPlane(seed=0)
    tids = [plane.create_task(
        TaskConfig(name, "app", "wf", clients_per_round=4, n_rounds=2,
                   vg_size=2), {"w": np.zeros(8, np.float32)})
        for name in ("alpha", "beta")]
    for t in tids:
        plane.deploy(t)
    svc = plane.service
    svc.flight = tracing.FlightRecorder(str(tmp_path / "flight"))
    with tracing.use_tracer(tracing.Tracer()) as tr:
        run_multi_task_simulation(
            plane, make_heterogeneous_clients(8, _trainer_factory),
            seed=0)
    return plane, svc, tids, tr


def test_two_task_sim_drives_all_renderers(tmp_path):
    from repro.fl.dashboard import render_fleet
    plane, svc, tids, tr = _run_two_task_sim(tmp_path)

    out = render_task_list(svc)
    assert "alpha" in out and "beta" in out and "completed" in out

    view = render_task_view(svc, tids[0])
    assert "rounds: 2/2" in view and "round history:" in view

    fleet = render_fleet(plane)
    assert "registry: 2 published model(s)" in fleet
    assert "8 devices" in fleet

    status = render_status(svc)
    assert "meters:" in status
    assert "rounds_completed{task=%d}" % tids[0] in status
    assert "rounds_granted" in status and "jit_cache_misses" in status
    assert "round_duration_s" in status and "lease_seconds" in status

    # scheduler-layer meters landed too (fair-share lease accounting)
    for tid in tids:
        assert svc.meters.value("rounds_granted", task=tid) == 2.0
        assert svc.meters.value("lease_seconds", task=tid) is not None

    # the grant decisions were traced alongside the round pipeline
    names = {s.name for r in tr.roots() for s in _span_tree(r)}
    assert {"grant_round", "lease_acquire", "local_train", "aggregate",
            "secure_agg", "server_update"} <= names


def _span_tree(span):
    out = [span]
    for c in span.children:
        out.extend(_span_tree(c))
    return out


def test_render_trace_transcript(tmp_path):
    _, svc, tids, _ = _run_two_task_sim(tmp_path)
    out = render_trace(svc, tids[1])
    assert f"flight transcript for task {tids[1]}" in out
    assert "round   0 [round]" in out and "round   1 [round]" in out
    assert "cohort=4 survivors=4" in out
    assert "route=single_dispatch" in out
    assert "aggregate" in out and "secure_agg" in out
    assert "(fused)" in out            # dp/quantize/mask/vg_sum rows
    # unknown task / missing recorder degrade to messages, not crashes
    assert "no flight records" in render_trace(svc, 999)
    svc.flight = None
    assert "no flight recorder" in render_trace(svc, tids[0])


def test_cli_status_and_trace_commands(tmp_path, capsys):
    from repro.fl import cli
    session = str(tmp_path / "s.pkl")
    cli.main(["--session", session, "create", "--task-name", "t1",
              "--app-name", "a", "--workflow", "w",
              "--clients-per-round", "2", "--rounds", "2"])
    capsys.readouterr()
    cli.main(["--session", session, "status"])
    out = capsys.readouterr().out
    assert "t1" in out and "meters:" in out
    cli.main(["--session", session, "trace", "1"])
    assert "no flight recorder" in capsys.readouterr().out
