"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.kdf import kdf_u32, mask_stream, pair_seed
from repro.core.masking import modular_sum
from repro.core.quantize import dequantize, quantize
from repro.core.virtual_groups import (make_virtual_groups, pairwise_cost,
                                       recommended_vg_size)


@settings(deadline=None, max_examples=30)
@given(k0=st.integers(0, 2**32 - 1), k1=st.integers(0, 2**32 - 1),
       c=st.integers(0, 2**32 - 1))
def test_kdf_deterministic_and_sensitive(k0, k1, c):
    a = int(kdf_u32(jnp.uint32(k0), jnp.uint32(k1), jnp.uint32(c)))
    b = int(kdf_u32(jnp.uint32(k0), jnp.uint32(k1), jnp.uint32(c)))
    assert a == b
    flipped = int(kdf_u32(jnp.uint32(k0 ^ 1), jnp.uint32(k1),
                          jnp.uint32(c)))
    assert a != flipped  # 2^-32 failure probability; fine for a hash test


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), off=st.integers(0, 2**20))
def test_mask_stream_position_addressable(seed, off):
    """stream(offset)[k] == stream(0)[offset+k] — the property the sharded
    per-pod masking relies on."""
    s = pair_seed(jnp.asarray([seed, seed ^ 77], jnp.uint32), 0, 1)
    a = mask_stream(s, off, 8)
    b = mask_stream(s, 0, off + 8)[off:]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(deadline=None, max_examples=30)
@given(x=st.floats(-10, 10), bits=st.integers(4, 24),
       clip=st.floats(0.1, 4.0))
def test_quantize_round_trip_bound(x, bits, clip):
    q = quantize(jnp.asarray([x], jnp.float32), clip, bits)
    back = float(dequantize(q, clip, bits)[0])
    expect = float(np.clip(x, -clip, clip))
    assert abs(back - expect) <= 2 * clip / (2**bits - 1) + 1e-6
    assert 0 <= int(q[0]) < 2**bits


@settings(deadline=None, max_examples=20)
@given(perm_seed=st.integers(0, 100))
def test_modular_sum_permutation_invariant(perm_seed):
    rng = np.random.RandomState(perm_seed)
    p = rng.randint(0, 2**32, (6, 50), dtype=np.uint32)
    a = modular_sum(jnp.asarray(p))
    b = modular_sum(jnp.asarray(p[rng.permutation(6)]))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(deadline=None, max_examples=30)
@given(n=st.integers(1, 500), g=st.integers(2, 64))
def test_vg_partition_covers_all_clients(n, g):
    plan = make_virtual_groups(range(n), g, seed=0)
    members = [c for grp in plan.groups for c in grp.members]
    assert sorted(members) == list(range(n))
    if n > max(g, 2):
        assert all(len(grp.members) >= 2 for grp in plan.groups)


@settings(deadline=None, max_examples=30)
@given(n=st.integers(8, 100_000))
def test_vg_cost_reduction(n):
    g = recommended_vg_size(n)
    assert pairwise_cost(n, g) <= pairwise_cost(n)
    if n > 200:
        assert pairwise_cost(n, g) < 0.2 * pairwise_cost(n)


@settings(deadline=None, max_examples=60)
@given(n=st.integers(1, 400), g=st.integers(2, 32))
def test_pairwise_cost_matches_real_plans(n, g):
    """The cost model must price the plan make_virtual_groups actually
    builds — including the remainder-merge rule (a trailing remainder
    < min_vg_size joins the previous group, costing (g+rem)(g+rem-1))."""
    plan = make_virtual_groups(range(n), g, seed=0)
    actual = sum(len(grp.members) * (len(grp.members) - 1)
                 for grp in plan.groups)
    assert pairwise_cost(n, g) == actual
