"""Per-kernel validation: sweep shapes/dtypes, assert kernels == ref.py
oracles (bit-exact for integer ops, allclose for f32)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SIZES = [5, 128, 4096, 32768, 50_001]
SEED = jnp.asarray([123, 456], jnp.uint32)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("bits", [8, 18, 24])
def test_quantize_kernel_bit_exact(rng, n, bits):
    x = jnp.asarray(rng.uniform(-2, 2, n).astype(np.float32))
    np.testing.assert_array_equal(ops.quantize(x, 1.0, bits),
                                  ref.quantize(x, 1.0, bits))


@pytest.mark.parametrize("n", SIZES)
def test_dequantize_sum_kernel(rng, n):
    q = jnp.asarray(rng.randint(0, 2**24, n, dtype=np.uint32))
    # f32 ops: XLA constant-folds (x/lv)*2c differently inside vs outside
    # the kernel; integer ops stay bit-exact, floats to ~1e-7
    np.testing.assert_allclose(np.asarray(ops.dequantize_sum(q, 7)),
                               np.asarray(ref.dequantize_sum(q, 7)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [1000, 40_000])
@pytest.mark.parametrize("i,g", [(0, 2), (1, 4), (7, 8), (3, 5)])
def test_mask_apply_kernel_bit_exact(rng, n, i, g):
    q = jnp.asarray(rng.randint(0, 2**18, n, dtype=np.uint32))
    np.testing.assert_array_equal(ops.mask_apply(q, i, g, SEED),
                                  ref.mask_apply(q, i, g, SEED))


@pytest.mark.parametrize("n_clients,g", [(4, 2), (8, 4), (5, 5), (3, 1)])
@pytest.mark.parametrize("size", [100, 33_000])
def test_mask_apply_cohort_kernel_bit_exact(rng, n_clients, g, size):
    """Batched whole-cohort kernel == per-client oracle, bit for bit
    (including g=1 degenerate groups and ragged client counts)."""
    from repro.core.secure_agg import group_seed
    qs = jnp.asarray(rng.randint(0, 2**18, (n_clients, size),
                                 dtype=np.uint32))
    idxs = jnp.asarray([i % g for i in range(n_clients)], jnp.uint32)
    vgs = jnp.asarray([i // g for i in range(n_clients)], jnp.uint32)
    gseeds = jnp.stack([group_seed(SEED, int(v)) for v in vgs])
    np.testing.assert_array_equal(
        np.asarray(ops.mask_apply_cohort(qs, idxs, gseeds, g)),
        np.asarray(ref.mask_apply_cohort(qs, idxs, gseeds, g)))


def test_build_pair_seeds_traced_matches_static():
    g = 5
    for i in range(g):
        a = ops.build_pair_seeds(i, g, SEED)
        b = ops.build_pair_seeds_traced(jnp.uint32(i), g, SEED)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("clients", [1, 2, 5, 16])
@pytest.mark.parametrize("n", [100, 33_000])
def test_secure_sum_kernel_bit_exact(rng, clients, n):
    p = jnp.asarray(rng.randint(0, 2**32, (clients, n), dtype=np.uint32))
    np.testing.assert_array_equal(ops.secure_sum(p), ref.secure_sum(p))


@pytest.mark.parametrize("n", [256, 40_000])
@pytest.mark.parametrize("sigma", [0.0, 0.05, 1.0])
def test_dp_noise_kernel_matches_ref(rng, n, sigma):
    x = jnp.asarray(rng.uniform(-1, 1, n).astype(np.float32))
    a = ops.dp_clip_noise(x, 0.5, sigma, SEED)
    b = ref.dp_clip_noise(x, 0.5, sigma, SEED)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_dp_noise_is_gaussian(rng):
    x = jnp.zeros(200_000, jnp.float32)
    y = np.asarray(ops.dp_clip_noise(x, 1.0, 1.0, SEED))
    assert abs(y.mean()) < 0.02
    assert abs(y.std() - 1.0) < 0.02
    # tail sanity
    assert 0.14 < (np.abs(y) > 1.0).mean() * 0.5 + 0.08 < 0.35


def test_kernel_mask_cancellation_end_to_end(rng):
    n, size = 6, 12_345
    xs = rng.uniform(-1, 1, (n, size)).astype(np.float32)
    qs = jnp.stack([ops.quantize(jnp.asarray(x)) for x in xs])
    masked = jnp.stack([ops.mask_apply(qs[i], i, n, SEED)
                        for i in range(n)])
    np.testing.assert_array_equal(ops.secure_sum(masked),
                                  ops.secure_sum(qs))
