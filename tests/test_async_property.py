"""Hypothesis property: the fused async path (batched DP + device buffer +
one-dispatch drain) is bit-identical to the serial ``AsyncServer.submit``
reference under random buffer sizes, submission counts, staleness versions,
weights, DP on/off, and random serial/batch interleavings — the ISSUE 3
acceptance criterion (async analogue of the privacy-engine property)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.flatten_util import ravel_pytree

from repro.core.dp import DPConfig
from repro.core.orchestrator import AsyncServer, ClientResult
from repro.core.strategies import FedBuff

SIZE = 12


def _params():
    return {"a": jnp.zeros((2, 3), jnp.float32),
            "b": jnp.ones(6, jnp.float32) * 0.25}


def _mk_server(buffer_size, dp, seed):
    cfg = DPConfig(mechanism=dp, clip_norm=0.5,
                   noise_multiplier=1.0 if dp == "local" else 0.0)
    return AsyncServer(_params(), FedBuff(buffer_size=buffer_size,
                                          server_lr=0.9), cfg, seed=seed)


def _serial_feed(server, rows, weights, versions):
    _, unflatten = ravel_pytree(_params())
    steps = []
    for j in range(rows.shape[0]):
        if server.submit(ClientResult(update=unflatten(jnp.asarray(rows[j])),
                                      n_samples=weights[j]), versions[j]):
            steps.append(j)
    return steps


@settings(deadline=None, max_examples=25)
@given(st.data())
def test_async_batch_bitwise_parity(data):
    buffer_size = data.draw(st.integers(2, 5), label="buffer_size")
    n = data.draw(st.integers(1, 18), label="n_submissions")
    dp = data.draw(st.sampled_from(["off", "local"]), label="dp")
    seed = data.draw(st.integers(0, 3), label="seed")
    versions = data.draw(st.lists(st.integers(0, 6), min_size=n,
                                  max_size=n), label="versions")
    weights = [float(w) for w in data.draw(
        st.lists(st.integers(1, 40), min_size=n, max_size=n),
        label="weights")]
    # random chunking of the same ordered submission stream: chunks of
    # size 1 go through the serial entry, larger chunks through
    # submit_batch — every interleaving must match the all-serial feed
    cuts = sorted(data.draw(
        st.lists(st.integers(1, max(1, n - 1)), max_size=4, unique=True),
        label="cuts")) if n > 1 else []
    bounds = [0] + [c for c in cuts if c < n] + [n]

    rows = np.random.RandomState(seed + 17).uniform(
        -1, 1, (n, SIZE)).astype(np.float32)
    s_serial = _mk_server(buffer_size, dp, seed)
    s_fused = _mk_server(buffer_size, dp, seed)

    serial_steps = _serial_feed(s_serial, rows, weights, versions)
    fused_steps = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi - lo == 1:
            fused_steps += [lo + j for j in _serial_feed(
                s_fused, rows[lo:hi], weights[lo:hi], versions[lo:hi])]
        else:
            fused_steps += [lo + j for j in s_fused.submit_batch(
                jnp.asarray(rows[lo:hi]), weights[lo:hi], versions[lo:hi])]

    assert serial_steps == fused_steps
    assert s_serial.n_server_steps == s_fused.n_server_steps
    assert s_serial.model_version == s_fused.model_version
    # staleness-weight vector matches the serial reference bit for bit
    np.testing.assert_array_equal(np.asarray(s_serial.strategy._weights),
                                  np.asarray(s_fused.strategy._weights))
    c = s_serial.strategy._cursor
    assert c == s_fused.strategy._cursor
    if c:
        np.testing.assert_array_equal(
            np.asarray(s_serial.strategy._rows)[:c],
            np.asarray(s_fused.strategy._rows)[:c])
    # and so do the model bits
    np.testing.assert_array_equal(
        np.asarray(ravel_pytree(s_serial.params)[0]),
        np.asarray(ravel_pytree(s_fused.params)[0]))
