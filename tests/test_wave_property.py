"""Hypothesis property sweep for the PR 7 streaming-wave route (ISSUE 9
satellite): across random cohort sizes, wave widths that divide and don't
divide the cohort, ragged/merged VG plans, and DP off/local/global, the
waved pipeline is bit-identical to the single vectorized dispatch — both
at the CANONICAL LIMB-STATE level (the integer digits before the float
tail) and at the final float output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.quantize import carry_normalize, merge_limb_states
from repro.core.virtual_groups import make_virtual_groups


def _cohort(n, size, seed):
    rng = np.random.RandomState(seed)
    cids = [f"c{i:03d}" for i in range(n)]
    flat = jnp.asarray(rng.uniform(-1.2, 1.2, (n, size)), jnp.float32)
    return cids, flat


def _canonical_state(states):
    """Per-dispatch limb states -> the canonical digits of the grand
    total. Layout-independence of the digits is exactly the property the
    wave route relies on, so canonicalizing both sides and comparing
    bitwise pins it."""
    return np.asarray(merge_limb_states(jnp.asarray(states)))


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 26), vg_size=st.integers(2, 7),
       wave=st.integers(1, 30), size=st.integers(1, 80),
       mech=st.sampled_from(["off", "local", "global"]),
       seed=st.integers(0, 10_000))
def test_wave_bit_identical_to_single_dispatch(n, vg_size, wave, size,
                                               mech, seed):
    """The acceptance property: any wave width (dividing, non-dividing,
    degenerate 1-client, wider-than-cohort => unwaved) over any
    ragged/merged plan and DP mode produces the same bits as one
    dispatch."""
    cids, flat = _cohort(n, size, seed)
    plan = make_virtual_groups(cids, vg_size, seed=seed)
    round_seed = jnp.asarray([seed & 0xFFFF, seed >> 3], jnp.uint32)
    key = jax.random.PRNGKey(seed)
    dcfg = dp_mod.DPConfig(
        mechanism=mech, clip_norm=0.5,
        noise_multiplier=0.8 if mech != "off" else 0.0)
    single = pe.aggregate_flat(flat, plan, cids, round_seed,
                               secure_cfg=sa.SecureAggConfig(),
                               dp_cfg=dcfg, key=key)
    waved = pe.aggregate_flat(
        flat, plan, cids, round_seed,
        secure_cfg=sa.SecureAggConfig(wave_clients=wave),
        dp_cfg=dcfg, key=key)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(waved))


@settings(deadline=None, max_examples=25)
@given(n=st.integers(2, 20), vg_size=st.integers(2, 6),
       wave=st.integers(1, 19), size=st.integers(1, 60),
       mech=st.sampled_from(["off", "local"]),
       seed=st.integers(0, 10_000))
def test_wave_limb_states_canonically_identical(n, vg_size, wave, size,
                                                mech, seed):
    """Below the float tail: folding the per-wave limb states must give
    the SAME canonical digits as folding the single dispatch's per-shard
    states — the integer chain is exact, so this is equality of integers,
    not of rounded floats."""
    cids, flat = _cohort(n, size, seed)
    plan = make_virtual_groups(cids, vg_size, seed=seed)
    buckets = pe.plan_buckets(plan, cids)
    round_seed = jnp.asarray([seed & 0xFFFF, seed >> 3], jnp.uint32)
    key = jax.random.PRNGKey(seed)
    scfg = sa.SecureAggConfig()
    dcfg = dp_mod.DPConfig(
        mechanism=mech, clip_norm=0.5,
        noise_multiplier=0.8 if mech != "off" else 0.0)
    rows_t = tuple(jnp.asarray(b.rows, jnp.int32) for b in buckets)
    vgs_t = tuple(jnp.asarray(b.vg_ids, jnp.uint32) for b in buckets)
    shapes = tuple((b.g, b.n_groups) for b in buckets)
    single_states = pe._cohort_interims(
        flat, round_seed, key, rows_t, vgs_t, bucket_shapes=shapes,
        n_shards=1, secure_cfg=scfg, dp_cfg=dcfg)
    wave_states = pe._waved_states(flat, buckets, round_seed, key,
                                   max(1, wave), scfg, dcfg)
    np.testing.assert_array_equal(_canonical_state(single_states),
                                  _canonical_state(wave_states))


@settings(deadline=None, max_examples=15)
@given(n=st.integers(4, 18), vg_size=st.integers(2, 5),
       wave=st.integers(2, 17), shards=st.integers(1, 6),
       seed=st.integers(0, 10_000))
def test_wave_matches_sharded_stage2(n, vg_size, wave, shards, seed):
    """Waves and explicit stage-2 sharding are two partitions of the same
    integer total: both must match the unsharded single dispatch."""
    cids, flat = _cohort(n, 40, seed)
    plan = make_virtual_groups(cids, vg_size, seed=seed)
    round_seed = jnp.asarray([seed & 0xFFFF, 5], jnp.uint32)
    key = jax.random.PRNGKey(seed)
    base = pe.aggregate_flat(flat, plan, cids, round_seed, key=key)
    waved = pe.aggregate_flat(
        flat, plan, cids, round_seed,
        secure_cfg=sa.SecureAggConfig(wave_clients=wave), key=key)
    sharded = pe.aggregate_flat(flat, plan, cids, round_seed, key=key,
                                n_shards=shards)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(waved))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(sharded))
