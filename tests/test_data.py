import numpy as np

from repro.data import (ClientDataAccess, batches, dirichlet_splits,
                        equal_splits, lm_batches, lm_dataset, spam_dataset)


def test_spam_dataset_learnable_signal():
    d = spam_dataset(n_samples=1000, vocab_size=1024, seq_len=16)
    spam_frac = (d["tokens"][d["label"] == 1] < 64).mean()
    ham_frac = (d["tokens"][d["label"] == 0] < 64).mean()
    assert spam_frac > ham_frac + 0.2


def test_equal_splits_partition():
    d = spam_dataset(n_samples=100, seq_len=8)
    splits = equal_splits(d, 10)
    all_idx = np.concatenate(splits)
    assert len(all_idx) == 100 and len(set(all_idx.tolist())) == 100


def test_dirichlet_skew():
    labels = np.asarray([0] * 500 + [1] * 500)
    splits = dirichlet_splits(labels, n_clients=10, alpha=0.1, seed=0)
    assert sum(len(s) for s in splits) == 1000
    fracs = [labels[s].mean() for s in splits if len(s) > 10]
    assert np.std(fracs) > 0.2  # strongly non-IID at alpha=0.1


def test_client_data_access_fraction():
    d = spam_dataset(n_samples=1000, seq_len=8)
    acc = ClientDataAccess(d, n_splits=100, frac=0.2)
    sample = acc.sample(client_seed=3)
    assert len(sample["label"]) == 2  # 20% of a 10-element split


def test_lm_batches_shapes():
    stream = lm_dataset(n_tokens=5000, vocab_size=64)
    it = lm_batches(stream, batch_size=4, seq_len=16)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_batches_iterator():
    d = spam_dataset(n_samples=25, seq_len=8)
    bs = list(batches(d, 10))
    assert [len(b["label"]) for b in bs] == [10, 10, 5]
