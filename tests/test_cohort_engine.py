"""Vectorized cohort execution engine: per-client parity of the serial /
vmap / shard_map paths on bert-tiny-spam, simulator fast-path equivalence,
and the async served-version regression (FedBuff staleness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import SpamWorld
from repro.core.cohort_engine import (serial_cohort, shard_cohort,
                                      stack_trees, vmap_cohort)
from repro.compat import make_mesh
from repro.fl import ManagementService, TaskConfig
from repro.fl.simulator import (_SnapshotStore, make_heterogeneous_clients,
                                run_async_simulation, run_sync_simulation)


@pytest.fixture(scope="module")
def world():
    return SpamWorld(vocab=256, d_model=32, seq_len=8, n_train=1000,
                     n_splits=10, batch_size=2, d_ff=64, head_dim=16)


@pytest.fixture(scope="module")
def engine(world):
    return world.make_engine(local_steps=2, batch_size=2)


def _cids(n):
    return [f"client-{i:04d}" for i in range(n)]


def _max_err(t1, t2):
    return max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32))))
               for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))


def test_vmap_matches_serial(world, engine):
    """Issue acceptance: vmapped cohort output == serial per-client loop
    within float tolerance on bert-tiny-spam."""
    cids = _cids(6)
    batches = stack_trees([engine.batch_fn(c, 0) for c in cids])
    d_serial, l_serial = serial_cohort(engine.spec)(world.model0, batches)
    d_vmap, l_vmap = vmap_cohort(engine.spec)(world.model0, batches)
    assert _max_err(d_serial, d_vmap) < 1e-5
    np.testing.assert_allclose(np.asarray(l_serial), np.asarray(l_vmap),
                               atol=1e-6)


def test_shard_map_matches_vmap(world, engine):
    mesh = make_mesh((len(jax.devices()),), ("data",))
    cids = _cids(4 * len(jax.devices()))
    batches = stack_trees([engine.batch_fn(c, 1) for c in cids])
    d_vmap, _ = vmap_cohort(engine.spec)(world.model0, batches)
    d_shard, _ = shard_cohort(engine.spec, mesh)(world.model0, batches)
    assert _max_err(d_vmap, d_shard) < 1e-5


def test_personalized_params_match_per_client_serial(world, engine):
    """Stacked per-client params (clustered / mixed-version async) give the
    same result as separate serial calls with each client's own params."""
    cids = _cids(3)
    params_list = [jax.tree.map(lambda a, s=s: a + 0.01 * s, world.model0)
                   for s in range(3)]
    res = engine.run_cohort_personalized(params_list, cids, [0, 0, 0])
    serial = serial_cohort(engine.spec)
    for j, c in enumerate(cids):
        b = stack_trees([engine.batch_fn(c, 0)])
        d, _ = serial(params_list[j], b)
        d0 = jax.tree.map(lambda a: a[0], d)
        assert _max_err(res[j][0], d0) < 1e-5


def test_sync_simulation_engine_fast_path_parity(world, engine):
    """Engine-driven sync simulation produces the same final model as the
    serial-trainer simulation built from the same local_update."""
    def run(use_engine):
        svc = ManagementService()
        tid = svc.create_task(
            TaskConfig("spam", "app", "wf", clients_per_round=4, n_rounds=2,
                       vg_size=2), world.model0)
        clients = make_heterogeneous_clients(
            6, lambda i: engine.make_trainer(f"client-{i:04d}"))
        run_sync_simulation(svc, tid, clients,
                            engine=engine if use_engine else None)
        return svc.get_task(tid).model

    assert _max_err(run(False), run(True)) < 1e-5


def test_sync_churn_engine_fast_path_parity(world, engine):
    """Churn rounds (over-provisioned cohort, hazard dropouts, deadline,
    mask recovery) through the engine's fused survivor path produce the
    same model as the serial-trainer churn loop — and both experience the
    SAME dropouts (the virtual-clock draws are path-independent)."""
    from repro.fl import (PopulationConfig, make_population_clients,
                          sample_population)
    pop = sample_population(8, seed=5,
                            cfg=PopulationConfig(mean_hazard=0.3))

    def run(use_engine):
        svc = ManagementService()
        tid = svc.create_task(
            TaskConfig("spam", "app", "wf", clients_per_round=4, n_rounds=2,
                       vg_size=2, overprovision=1.5, round_timeout_s=4.0),
            world.model0)
        clients = make_population_clients(
            pop, lambda i: engine.make_trainer(f"client-{i:04d}"))
        res = run_sync_simulation(svc, tid, clients, seed=2,
                                  engine=engine if use_engine else None)
        return svc.get_task(tid).model, res

    m_serial, r_serial = run(False)
    m_engine, r_engine = run(True)
    assert r_serial.n_dropped_total == r_engine.n_dropped_total >= 1
    np.testing.assert_allclose(r_engine.round_durations,
                               r_serial.round_durations, atol=1e-9)
    assert _max_err(m_serial, m_engine) < 1e-5


def test_async_simulation_engine_fast_path_parity(world, engine):
    def run(use_engine):
        svc = ManagementService()
        tid = svc.create_task(
            TaskConfig("spam", "app", "wf", clients_per_round=4, n_rounds=3,
                       vg_size=2, mode="async", buffer_size=3), world.model0)
        clients = make_heterogeneous_clients(
            6, lambda i: engine.make_trainer(f"client-{i:04d}"))
        res = run_async_simulation(svc, tid, clients,
                                   engine=engine if use_engine else None)
        return svc.get_task(tid).model, res

    m_serial, r_serial = run(False)
    m_engine, r_engine = run(True)
    assert r_serial.n_server_steps == r_engine.n_server_steps
    assert _max_err(m_serial, m_engine) < 1e-5


def test_async_engine_parity_under_extreme_heterogeneity(world, engine):
    """Adversarial interleaving: a 50x-faster client re-submits several
    times before each server step. The engine's timing pre-pass must batch
    those re-submissions in virtual-time order (same client twice in one
    group) — model AND round durations must match the serial reference."""
    from repro.fl import SimClient

    def mk():
        return {
            "client-0000": SimClient(
                "client-0000", engine.make_trainer("client-0000"),
                speed=10.0),
            "client-0001": SimClient(
                "client-0001", engine.make_trainer("client-0001"),
                speed=0.2),
        }

    def run(use_engine):
        svc = ManagementService()
        tid = svc.create_task(
            TaskConfig("spam", "app", "wf", clients_per_round=2, n_rounds=4,
                       vg_size=2, mode="async", buffer_size=3), world.model0)
        res = run_async_simulation(svc, tid, mk(), seed=0,
                                   engine=engine if use_engine else None)
        return svc.get_task(tid).model, res

    m_serial, r_serial = run(False)
    m_engine, r_engine = run(True)
    assert _max_err(m_serial, m_engine) < 1e-5
    np.testing.assert_allclose(r_engine.round_durations,
                               r_serial.round_durations, atol=1e-9)


def test_snapshot_store_does_not_leak_past_versions():
    """A version whose last ref drops while it is still current must be
    evicted once the version advances (was retained forever)."""
    store = _SnapshotStore()
    for v in range(4):
        store.put(v, f"v{v}".encode())
        store.ref(v)
        store.serve(v, v, lambda: b"cur")
        store._gc(v + 1)
    assert not store._blobs


def test_async_records_served_version(world, engine):
    """Regression (FedBuff staleness): the version submitted must be the
    version actually SERVED to the client — stale starts keep their true
    version (snapshot retained while referenced), and the staleness
    discount sees real staleness > 0 for stragglers."""
    recorded = []

    class SpyService(ManagementService):
        def submit_update(self, task_id, client_id, update, n_samples,
                          metrics=None, update_version=None):
            rec = self._tasks[task_id]
            recorded.append((update_version, rec.round_idx))
            return super().submit_update(task_id, client_id, update,
                                         n_samples, metrics,
                                         update_version=update_version)

    svc = SpyService()
    tid = svc.create_task(
        TaskConfig("spam", "app", "wf", clients_per_round=4, n_rounds=4,
                   vg_size=2, mode="async", buffer_size=2), world.model0)
    clients = make_heterogeneous_clients(
        6, lambda i: engine.make_trainer(f"client-{i:04d}"),
        straggler_frac=0.5)
    run_async_simulation(svc, tid, clients, seed=3)
    assert all(v is not None for v, _ in recorded)
    # with stragglers and buffer 2, some update must arrive genuinely stale
    assert any(v < cur for v, cur in recorded), recorded


def test_snapshot_store_retains_referenced_versions():
    store = _SnapshotStore()
    store.put(0, b"v0")
    store.ref(0)
    store.ref(0)
    store.put(1, b"v1")
    store.ref(1)
    blob, served = store.serve(0, 1, lambda: b"cur")
    assert (blob, served) == (b"v0", 0)          # still referenced once
    blob, served = store.serve(0, 1, lambda: b"cur")
    assert (blob, served) == (b"v0", 0)          # last reference, then gc
    assert 0 not in store._blobs
    # a version that was never stored falls back to the CURRENT snapshot
    # and reports the version actually served (the old bug reported the
    # stale version while serving current weights)
    store.ref(7)
    blob, served = store.serve(7, 1, lambda: b"cur")
    assert (blob, served) == (b"v1", 1)


def test_fl_step_local_steps_smoke():
    """launch/fl_step.py local_steps>1 routes through the cohort engine's
    local_update and still trains under the secure-agg pipeline."""
    from repro import compat
    from repro.configs import get_config
    from repro.launch.fl_step import make_fl_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.optim import adamw

    cfg = get_config("bert-tiny-spam").replace(vocab_size=256, d_model=32,
                                               d_ff=64, head_dim=16)
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0), max_positions=16)
    opt_state = adamw(1e-3).init(params)
    step, meta = make_fl_train_step(cfg, mesh, vg_size=2, local_steps=2,
                                    client_lr=1e-2)
    assert meta["local_steps"] == 2
    n = meta["n_silos"]
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 256, (n, 4, 16)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.randint(0, 256, (n, 4, 16)),
                                    jnp.int32),
             "mask": jnp.ones((n, 4, 16), jnp.float32)}
    with compat.set_mesh(mesh):
        p2, _, loss = jax.jit(step)(params, opt_state, batch,
                                    jnp.asarray([1, 2], jnp.uint32))
    assert np.isfinite(float(loss))
    assert _max_err(params, p2) > 0  # params moved
