"""Clustered FL (beyond-paper, paper §7 future work): similarity math,
bipartition, and split-on-divergence behaviour."""
import jax.numpy as jnp
import numpy as np

from repro.core.clustered import (ClusteredFL, bipartition,
                                  cosine_similarity_matrix)


def _u(v):
    return {"w": jnp.asarray(v, jnp.float32)}


def test_cosine_similarity():
    sim = cosine_similarity_matrix([_u([1, 0]), _u([0, 1]), _u([2, 0])])
    np.testing.assert_allclose(sim[0, 2], 1.0, atol=1e-6)
    np.testing.assert_allclose(sim[0, 1], 0.0, atol=1e-6)


def test_bipartition_separates_opposites():
    sim = cosine_similarity_matrix(
        [_u([1, 0]), _u([0.9, 0.1]), _u([-1, 0]), _u([-0.9, -0.1])])
    a, b = bipartition(sim)
    assert sorted(a + b) == [0, 1, 2, 3]
    assert {tuple(a), tuple(b)} == {(0, 1), (2, 3)}


def test_split_triggers_on_divergent_clients():
    cfl = ClusteredFL(split_threshold=0.0, min_rounds_before_split=1,
                      max_clusters=2)
    params = _u([0.0, 0.0])
    state = cfl.init(params)
    # two VGs pulling in opposite directions -> mean similarity < 0 -> split
    ups = [_u([1.0, 0.0]), _u([-1.0, 0.0])]
    state, split = cfl.round(state, 0, ups, [1.0, 1.0],
                             [["c0", "c1"], ["c2", "c3"]])
    assert split is not None
    assert len(state["clusters"]) == 2
    ma, mb = split
    assert set(ma) == {"c0", "c1"} and set(mb) == {"c2", "c3"}
    # routing respects membership
    assert cfl.cluster_of(state, "c0") == 0
    assert cfl.cluster_of(state, "c2") == 1


def test_no_split_when_aligned():
    cfl = ClusteredFL(split_threshold=0.0, min_rounds_before_split=1)
    state = cfl.init(_u([0.0, 0.0]))
    ups = [_u([1.0, 0.1]), _u([0.9, 0.0])]
    state, split = cfl.round(state, 0, ups, [1.0, 1.0],
                             [["c0"], ["c1"]])
    assert split is None
    assert len(state["clusters"]) == 1
    # model moved by the mean update with server_lr=1
    np.testing.assert_allclose(
        np.asarray(state["clusters"][0]["model"]["w"]),
        np.asarray((jnp.asarray([1.0, 0.1]) + jnp.asarray([0.9, 0.0])) / 2),
        atol=1e-6)
