"""Kernel microbenchmarks: Pallas (interpret on CPU; compiled on TPU) vs the
pure-jnp reference path, per secure-agg stage."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, repeat=3):
    out = fn(*args)
    getattr(out, "block_until_ready", lambda: None)()
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    getattr(out, "block_until_ready", lambda: None)()
    return (time.perf_counter() - t0) / repeat * 1e6


def main(quick=False):
    n = 1 << 18 if quick else 1 << 20
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.uniform(-1, 1, n).astype(np.float32))
    q = ops.quantize(x)
    seed = jnp.asarray([3, 4], jnp.uint32)
    payloads = jnp.stack([q] * 8)
    rows = []
    for name, k_fn, r_fn, args in [
        ("quantize", ops.quantize, ref.quantize, (x,)),
        ("mask_apply_g8", lambda a: ops.mask_apply(a, 0, 8, seed),
         lambda a: ref.mask_apply(a, 0, 8, seed), (q,)),
        ("secure_sum_n8", ops.secure_sum, ref.secure_sum, (payloads,)),
        ("dp_clip_noise", lambda a: ops.dp_clip_noise(a, 0.5, 0.1, seed),
         lambda a: ref.dp_clip_noise(a, 0.5, 0.1, seed), (x,)),
    ]:
        tk = _time(k_fn, *args)
        tr = _time(r_fn, *args)
        print(f"# kernel {name}: pallas(interp)={tk:.0f}us jnp-ref={tr:.0f}us"
              f" ({n} elems)")
        rows.append((f"kernel_{name}_pallas", tk, f"n={n}"))
        rows.append((f"kernel_{name}_ref", tr, f"n={n}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
