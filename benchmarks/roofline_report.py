"""Render §Dry-run / §Roofline markdown tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = ["command-r-35b", "whisper-medium", "rwkv6-7b", "gemma2-27b",
              "llama4-maverick-400b-a17b", "llava-next-mistral-7b",
              "jamba-v0.1-52b", "qwen3-moe-235b-a22b", "deepseek-67b",
              "yi-9b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath, mesh="single", tag=""):
    recs = {}
    for f in glob.glob(os.path.join(dirpath, f"*__{mesh}{tag}.json")):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(recs):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful ratio | mem GiB/dev | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             f"missing |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             f"SKIP: {r['reason'][:60]}... |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             f"ERROR |")
                continue
            t = r["roofline"]
            mem = r["memory_analysis"].get("total_bytes_per_device", 0)
            note = ""
            if r.get("meta", {}).get("window_override"):
                note = f"window={r['meta']['window_override']}"
            lines.append(
                f"| {arch} | {shape} | {t['compute_s']:.3f} | "
                f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                f"{t['dominant']} | {t['useful_flops_ratio']:.2f} | "
                f"{fmt_bytes(mem)} | {note} |")
    return "\n".join(lines)


def dryrun_table(recs_single, recs_multi):
    lines = ["| arch | shape | 1-pod (256) | 2-pod (512) | "
             "collective bytes/dev (1-pod) | top collective |",
             "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = recs_single.get((arch, shape))
            m = recs_multi.get((arch, shape))

            def stat(r):
                if r is None:
                    return "missing"
                if r["status"] == "skipped":
                    return "skip"
                if r["status"] != "ok":
                    return "FAIL"
                mem = r["memory_analysis"].get("total_bytes_per_device", 0)
                return f"ok {fmt_bytes(mem)}GiB"

            cb, top = "-", "-"
            if s and s["status"] == "ok":
                t = s["roofline"]
                cb = f"{t['collective_bytes'] / 2**30:.2f}GiB"
                kinds = t.get("collective_by_kind", {})
                if kinds:
                    top = max(kinds, key=kinds.get)
            lines.append(f"| {arch} | {shape} | {stat(s)} | {stat(m)} | "
                         f"{cb} | {top} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    single = load(args.dir, "single", args.tag)
    multi = load(args.dir, "multi", args.tag)
    print("## Dry-run grid\n")
    print(dryrun_table(single, multi))
    print("\n## Roofline (single-pod 16x16, per chip)\n")
    print(roofline_table(single))
    n_ok = sum(1 for r in single.values() if r["status"] == "ok")
    n_ok_m = sum(1 for r in multi.values() if r["status"] == "ok")
    print(f"\nsingle-pod ok: {n_ok}; multi-pod ok: {n_ok_m}")


if __name__ == "__main__":
    main()
