"""Paper Fig. 11 (center): per-iteration duration — sync vs async (buffer
32) vs async with over-participation (2x client pool), under the
heterogeneous-client virtual clock. Expected ordering (paper): sync >
async > async+over-participation, with comparable accuracies.

Plus the ISSUE 3 server-step (host-compute) benchmark: wall time of one
full buffer fill + drain through the serial ``AsyncServer.submit`` loop vs
the fused ``submit_batch`` (batched DP rows, one buffer write, one-dispatch
drain) at buffer sizes {32, 256, 1024} — the tracked number behind the
async tentpole, independent of virtual-clock time.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SpamWorld
from repro.core.dp import DPConfig
from repro.core.orchestrator import AsyncServer, ClientResult
from repro.core.strategies import FedBuff
from repro.fl import ManagementService, TaskConfig
from repro.fl.simulator import (make_heterogeneous_clients,
                                run_async_simulation, run_sync_simulation)


def _mk_server(buffer_size: int, size: int, dp: str = "local"):
    params = {"w": jnp.zeros(size, jnp.float32)}
    cfg = DPConfig(mechanism=dp, clip_norm=0.5,
                   noise_multiplier=1.0 if dp == "local" else 0.0)
    return AsyncServer(params, FedBuff(buffer_size=buffer_size), cfg)


def _server_step_times(buffer_size: int, size: int = 16_384,
                       repeats: int = 3) -> dict:
    """Host-compute seconds for one full fill + server step, serial vs
    batched (fresh servers per path; first fill warms the jit caches)."""
    rng = np.random.RandomState(0)
    host_rows = rng.uniform(-1, 1, (buffer_size, size)).astype(np.float32)
    dev_rows = jnp.asarray(host_rows)
    weights = [1.0] * buffer_size

    def serial_fill(server):
        v = server.model_version
        for j in range(buffer_size):
            server.submit(ClientResult(update={"w": dev_rows[j]},
                                       n_samples=1), v)
        jax.block_until_ready(server.params["w"])

    def batch_fill(server):
        v = server.model_version
        server.submit_batch(dev_rows, weights, [v] * buffer_size)
        jax.block_until_ready(server.params["w"])

    out = {}
    for name, fill in (("serial", serial_fill), ("batch", batch_fill)):
        server = _mk_server(buffer_size, size)
        fill(server)                      # warmup / compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            fill(server)
        out[name] = (time.perf_counter() - t0) / repeats
    return out


def server_step_bench(quick=False):
    sizes = (32, 256) if quick else (32, 256, 1024)
    size = 1 << 12 if quick else 1 << 14
    rows = []
    print(f"# async server step (host compute), model={size} elems, "
          f"local DP: serial submit loop vs submit_batch + fused drain")
    for b in sizes:
        t = _server_step_times(b, size=size, repeats=2 if quick else 3)
        speedup = t["serial"] / t["batch"]
        print(f"#   buffer {b:5d} | serial {t['serial'] * 1e3:9.2f} ms | "
              f"batch {t['batch'] * 1e3:7.2f} ms | {speedup:7.1f}x")
        rows.append((f"async_step_serial_b{b}", t["serial"] * 1e6, ""))
        rows.append((f"async_step_batch_b{b}", t["batch"] * 1e6, ""))
        rows.append((f"async_step_speedup_b{b}", speedup,
                     f"{speedup:.1f}x at buffer {b}"))
    return rows


def main(rounds=8, quick=False):
    if quick:
        rounds = 3
    world = SpamWorld(n_train=3000 if quick else 6000)
    cohort = 32 if not quick else 8
    pool = cohort

    def mk_clients(n):
        return make_heterogeneous_clients(n, world.make_trainer,
                                          base_train_s=1.0,
                                          straggler_frac=0.15)

    svc = ManagementService()
    t_sync = svc.create_task(
        TaskConfig("sync", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, vg_size=8), world.model0)
    r_sync = run_sync_simulation(svc, t_sync, mk_clients(pool),
                                 eval_fn=world.test_accuracy)

    svc = ManagementService()
    t_async = svc.create_task(
        TaskConfig("async", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, mode="async", buffer_size=cohort),
        world.model0)
    r_async = run_async_simulation(svc, t_async, mk_clients(pool),
                                   eval_fn=world.test_accuracy)

    svc = ManagementService()
    t_over = svc.create_task(
        TaskConfig("async-over", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, mode="async", buffer_size=cohort),
        world.model0)
    r_over = run_async_simulation(svc, t_over, mk_clients(2 * pool),
                                  eval_fn=world.test_accuracy)

    d_sync = float(np.mean(r_sync.round_durations))
    d_async = float(np.mean(r_async.round_durations))
    d_over = float(np.mean(r_over.round_durations))
    a = lambda r: r.metrics_history[-1].get("eval_accuracy", float("nan"))
    print(f"# fig11-center: duration sync={d_sync:.2f}s async={d_async:.2f}s "
          f"async+over={d_over:.2f}s | acc {a(r_sync):.3f}/"
          f"{a(r_async):.3f}/{a(r_over):.3f}")
    return [
        ("fig11_center_sync_iter_s", d_sync * 1e6, f"acc={a(r_sync):.3f}"),
        ("fig11_center_async_iter_s", d_async * 1e6, f"acc={a(r_async):.3f}"),
        ("fig11_center_async_over_iter_s", d_over * 1e6,
         f"acc={a(r_over):.3f}"),
        # the speedup IS the metric value (was 0.0 with the ratio buried
        # in the note string)
        ("fig11_center_async_speedup", d_sync / d_async,
         "sync/async iter-duration ratio"),
    ] + server_step_bench(quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('async', rows, quick=args.quick)}")
