"""Paper Fig. 11 (center): per-iteration duration — sync vs async (buffer
32) vs async with over-participation (2x client pool), under the
heterogeneous-client virtual clock. Expected ordering (paper): sync >
async > async+over-participation, with comparable accuracies."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SpamWorld
from repro.fl import ManagementService, TaskConfig
from repro.fl.simulator import (make_heterogeneous_clients,
                                run_async_simulation, run_sync_simulation)


def main(rounds=8, quick=False):
    if quick:
        rounds = 3
    world = SpamWorld(n_train=3000 if quick else 6000)
    cohort = 32 if not quick else 8
    pool = cohort

    def mk_clients(n):
        return make_heterogeneous_clients(n, world.make_trainer,
                                          base_train_s=1.0,
                                          straggler_frac=0.15)

    svc = ManagementService()
    t_sync = svc.create_task(
        TaskConfig("sync", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, vg_size=8), world.model0)
    r_sync = run_sync_simulation(svc, t_sync, mk_clients(pool),
                                 eval_fn=world.test_accuracy)

    svc = ManagementService()
    t_async = svc.create_task(
        TaskConfig("async", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, mode="async", buffer_size=cohort),
        world.model0)
    r_async = run_async_simulation(svc, t_async, mk_clients(pool),
                                   eval_fn=world.test_accuracy)

    svc = ManagementService()
    t_over = svc.create_task(
        TaskConfig("async-over", "app", "wf", clients_per_round=cohort,
                   n_rounds=rounds, mode="async", buffer_size=cohort),
        world.model0)
    r_over = run_async_simulation(svc, t_over, mk_clients(2 * pool),
                                  eval_fn=world.test_accuracy)

    d_sync = float(np.mean(r_sync.round_durations))
    d_async = float(np.mean(r_async.round_durations))
    d_over = float(np.mean(r_over.round_durations))
    a = lambda r: r.metrics_history[-1].get("eval_accuracy", float("nan"))
    print(f"# fig11-center: duration sync={d_sync:.2f}s async={d_async:.2f}s "
          f"async+over={d_over:.2f}s | acc {a(r_sync):.3f}/"
          f"{a(r_async):.3f}/{a(r_over):.3f}")
    return [
        ("fig11_center_sync_iter_s", d_sync * 1e6, f"acc={a(r_sync):.3f}"),
        ("fig11_center_async_iter_s", d_async * 1e6, f"acc={a(r_async):.3f}"),
        ("fig11_center_async_over_iter_s", d_over * 1e6,
         f"acc={a(r_over):.3f}"),
        ("fig11_center_async_speedup", 0.0, f"{d_sync / d_async:.2f}x"),
    ]


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
