"""Re-run the roofline analyzer over dumped HLO (experiments/dryrun/hlo/
*.txt.gz) and refresh the 'roofline' section of the corresponding JSONs —
lets analyzer fixes propagate without recompiling 76 programs.

    PYTHONPATH=src python -m benchmarks.rescore [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.configs import get_config, get_shape
from repro.launch.roofline import analyze_hlo, roofline_terms


def _jsonable(d):
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out[k] = _jsonable(v)
        elif isinstance(v, (int, float, str, bool)) or v is None:
            out[k] = v
        else:
            out[k] = float(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    n = 0
    for gz in sorted(glob.glob(os.path.join(args.dir, "hlo", "*.txt.gz"))):
        base = os.path.basename(gz)[:-len(".txt.gz")]
        jpath = os.path.join(args.dir, base + ".json")
        if not os.path.exists(jpath):
            continue
        rec = json.load(open(jpath))
        if rec.get("status") != "ok":
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        n_chips = rec["meta"]["n_chips"]
        text = gzip.open(gz, "rt").read()
        stats = analyze_hlo(text)
        terms = roofline_terms(stats, cfg, shape, n_chips)
        rec["roofline"] = _jsonable(terms)
        json.dump(rec, open(jpath, "w"), indent=1)
        n += 1
        print(f"rescored {base}: dominant={terms['dominant']} "
              f"mem={terms['memory_s']:.2f}s coll={terms['collective_s']:.2f}s")
    print(f"{n} records rescored")


if __name__ == "__main__":
    main()
