"""Cohort execution engine throughput: the simulator's serial per-client
loop vs one vmapped call vs shard_map over the data axis, across cohort
sizes {8, 64, 256} on bert-tiny-spam.

Serial baseline = exactly what the simulator did pre-engine, per client:
deserialize the model snapshot blob, run the jitted local update, convert
the delta back to numpy. The engine amortizes the deserialize + dispatch +
transfer overhead over the whole cohort and runs the math as one compiled
vmap-over-clients computation.

Two worlds, because the win is regime-dependent:
  sim-scale   — reduced bert-tiny-spam (the cross-device regime this
                engine exists for: thousands of lightweight clients whose
                per-client overhead dwarfs their local compute).
                Acceptance floor: >= 5x at cohort 64 on CPU.
  paper-scale — the full §5.1 protocol (batch 8, 4 local AdamW steps).
                On a small-core host this is compute-bound, so the
                speedup is Amdahl-limited (~1.1-1.5x): the engine then
                wins by *sharding the client axis* over devices
                (shard_map path), not by killing dispatch overhead.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import SpamWorld
from repro.checkpoint import deserialize_pytree, serialize_pytree
from repro.compat import make_mesh

COHORTS = (8, 64, 256)

SIM_SCALE = dict(
    world=dict(vocab=256, d_model=32, seq_len=8, n_train=4000, n_splits=20,
               batch_size=2, d_ff=128, head_dim=16),
    engine=dict(local_steps=1, batch_size=2))
PAPER_SCALE = dict(
    world=dict(n_train=4000, n_splits=20),
    engine=dict(local_steps=4, batch_size=8))


def _bench_world(label, setup, cohorts, mesh, rows):
    world = SpamWorld(**setup["world"])
    engine = world.make_engine(**setup["engine"])
    engine_sh = world.make_engine(**setup["engine"], mesh=mesh)
    blob = serialize_pytree(world.model0)
    speedup_at_64 = None
    for n in cohorts:
        cids = [f"client-{i:04d}" for i in range(n)]
        trainers = {c: engine.make_trainer(c) for c in cids}

        # warm every path (compile + caches)
        trainers[cids[0]](blob, 0)
        params = deserialize_pytree(blob, like=engine.template)
        engine.run_cohort(params, cids, 0)
        engine_sh.run_cohort(params, cids, 0)

        t0 = time.perf_counter()
        serial_res = {c: trainers[c](blob, 1) for c in cids}
        t_serial = time.perf_counter() - t0

        t0 = time.perf_counter()
        params = deserialize_pytree(blob, like=engine.template)
        vmap_res = engine.run_cohort(params, cids, 1)
        t_vmap = time.perf_counter() - t0

        t0 = time.perf_counter()
        params = deserialize_pytree(blob, like=engine.template)
        shard_res = engine_sh.run_cohort(params, cids, 1)
        t_shard = time.perf_counter() - t0

        err = max(float(np.max(np.abs(a - b)))
                  for c in cids
                  for a, b in zip(jax.tree.leaves(serial_res[c][0]),
                                  jax.tree.leaves(vmap_res[c][0])))
        err_sh = max(float(np.max(np.abs(a - b)))
                     for c in cids
                     for a, b in zip(jax.tree.leaves(vmap_res[c][0]),
                                     jax.tree.leaves(shard_res[c][0])))
        sp = t_serial / t_vmap
        if n == 64:
            speedup_at_64 = sp
        print(f"# [{label}] cohort={n:4d}: "
              f"serial {t_serial * 1e3:8.1f} ms | "
              f"vmap {t_vmap * 1e3:7.1f} ms ({n / t_vmap:7.0f} cl/s) | "
              f"shard {t_shard * 1e3:7.1f} ms | speedup {sp:5.1f}x | "
              f"parity {err:.1e}/{err_sh:.1e}")
        rows.append((f"{label}_cohort{n}_serial_loop", t_serial * 1e6,
                     f"{n / t_serial:.0f}cl/s"))
        rows.append((f"{label}_cohort{n}_vmap", t_vmap * 1e6, f"{sp:.1f}x"))
        rows.append((f"{label}_cohort{n}_shard_map", t_shard * 1e6,
                     f"{t_serial / t_shard:.1f}x"))
        assert err < 1e-5 and err_sh < 1e-5, (err, err_sh)
    return speedup_at_64


def main(quick=False):
    cohorts = COHORTS[:2] if quick else COHORTS
    mesh = make_mesh((len(jax.devices()),), ("data",))
    rows = []
    sp64 = _bench_world("sim_scale", SIM_SCALE, cohorts, mesh, rows)
    _bench_world("paper_scale", PAPER_SCALE, cohorts[:2] if quick
                 else (8, 64), mesh, rows)
    if sp64 is not None:
        rows.append(("cohort64_vmap_speedup", 0.0, f"{sp64:.1f}x"))
        print(f"# sim-scale vmap speedup at cohort 64: {sp64:.1f}x "
              f"(acceptance floor: 5x)")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('cohort', rows, quick=args.quick)}")
