"""Tracing overhead + bit-identity contract (the flight-recorder
acceptance gates):

1. A full sync round (cohort 256, model 16384 elems, local DP, vectorized
   secure aggregation) is timed with the collecting :class:`Tracer`
   installed vs. the default :class:`NullTracer`. Overhead must stay
   below 2% (min-of-N against min-of-N, with a small absolute floor so
   sub-millisecond jitter cannot fail the gate on a fast box).
2. The traced round must be BIT-IDENTICAL to the untraced round: same
   final param bits and same aggregate-delta bits (the integer limb
   pipeline underneath is deterministic, so equal output bits pin the
   limb digits too). Tracing only wraps python control flow around the
   same shared jitted executables — this gate proves it never touches
   the math.
3. The traced run's span tree is exported as a sample Perfetto
   ``trace_events`` JSON plus a flight-recorder JSONL transcript under
   ``benchmarks/results/`` — the CI artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import tracing
from repro.core import dp as dp_mod
from repro.core import secure_agg as sa
from repro.core.orchestrator import run_sync_round_stacked
from repro.core.strategies import make_strategy

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _round_inputs(n: int, size: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    params = {"w": jnp.asarray(rng.uniform(-1, 1, size)
                               .astype(np.float32))}
    stacked = {"w": jnp.asarray(rng.uniform(-0.4, 0.4, (n, size))
                                .astype(np.float32))}
    cids = [f"c{i:05d}" for i in range(n)]
    return params, stacked, cids


def _run_round(params, stacked, cids, round_idx: int = 0):
    """One fused sync round (DP -> quantize -> mask -> VG sum -> limb
    combine -> strategy apply); returns the new params, blocked."""
    strategy = make_strategy("fedavg")
    state = strategy.init_state(params)
    out, _, info = run_sync_round_stacked(
        params, strategy, state, cids, stacked,
        round_idx=round_idx, vg_size=8,
        secure_cfg=sa.SecureAggConfig(),
        dp_cfg=dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                               noise_multiplier=0.5),
        key=jax.random.PRNGKey(0))
    jax.block_until_ready(out)
    return out, info


def _time_rounds(params, stacked, cids, repeats: int) -> float:
    """min-of-N wall seconds for one round under the CURRENT tracer."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _run_round(params, stacked, cids)
        best = min(best, time.perf_counter() - t0)
    return best


def _bits(tree) -> list:
    return [np.asarray(leaf).view(np.uint32).tobytes()
            for leaf in jax.tree.leaves(tree)]


def main(quick: bool = False):
    n, size = (64, 4096) if quick else (256, 16384)
    repeats = 7 if quick else 5
    params, stacked, cids = _round_inputs(n, size)
    rows = []

    # warm the shared executables OUTSIDE both timed arms so neither
    # pays compilation
    baseline, info0 = _run_round(params, stacked, cids)

    t_off = _time_rounds(params, stacked, cids, repeats)
    tracer = tracing.Tracer()
    with tracing.use_tracer(tracer):
        t_on = _time_rounds(params, stacked, cids, repeats)
        with tracing.span("round", task=1, round=0) as root:
            traced_out, info = _run_round(params, stacked, cids)

    overhead = t_on / t_off - 1.0
    # absolute floor: the quick smoke runs a ~10ms round on shared CI
    # hosts where scheduler noise alone exceeds 2% — it gates wiring and
    # bit-identity, while the full 256-client mode holds the strict 2%
    budget = max(0.02 * t_off, 8e-3 if quick else 2e-3)
    print(f"# trace overhead: n={n} size={size} off={t_off * 1e3:.3f}ms "
          f"on={t_on * 1e3:.3f}ms overhead={overhead:+.2%} "
          f"(budget {budget * 1e3:.3f}ms)")
    assert t_on - t_off <= budget, (
        f"tracing overhead {t_on - t_off:.6f}s exceeds budget "
        f"{budget:.6f}s ({overhead:+.2%} on a {t_off * 1e3:.2f}ms round)")

    # bit-identity: tracing must not perturb the math — same param bits
    # traced vs untraced (the integer limb pipeline is deterministic, so
    # equal output bits pin the limb digits as well)
    untraced_out, _ = _run_round(params, stacked, cids)
    assert _bits(traced_out) == _bits(untraced_out) == _bits(baseline), \
        "traced round is not bit-identical to untraced round"
    print("# bit-identity: traced == untraced (param bits)")

    # sample artifacts for CI upload: the live-tracer Perfetto timeline
    # and a flight-recorder JSONL transcript of the traced round
    os.makedirs(RESULTS_DIR, exist_ok=True)
    pf_path = os.path.join(RESULTS_DIR, "trace_sample_perfetto.json")
    tracer.export_perfetto(pf_path)
    flight = tracing.FlightRecorder(os.path.join(RESULTS_DIR,
                                                 "flight_sample"))
    fl_path = flight.path(1)
    if os.path.exists(fl_path):
        os.remove(fl_path)
    flight.record(1, tracing.round_event(
        round_idx=0, cohort=cids, survivors=cids,
        n_shards=info.n_shards, stage2_route=info.stage2_route,
        span_tree=root))
    pf = json.load(open(pf_path))
    names = {e["name"] for e in pf["traceEvents"] if e.get("ph") == "X"}
    for stage in ("secure_agg", "cohort_interims", "dp", "quantize",
                  "mask", "vg_sum", "limb_combine", "server_update"):
        assert stage in names, f"stage {stage!r} missing from trace"
    print(f"# wrote {pf_path} and {fl_path}")

    rows.append((f"trace_off_n{n}", t_off * 1e6, f"size={size}"))
    rows.append((f"trace_on_n{n}", t_on * 1e6,
                 f"overhead={overhead:+.2%}"))
    rows.append(("trace_overhead_pct", overhead * 100.0,
                 f"budget_ms={budget * 1e3:.3f}"))
    rows.append(("trace_bit_identical", 1.0,
                 f"route={info.stage2_route}"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    out_rows = main(quick=args.quick)
    for r in out_rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    path = write_bench_json("trace", out_rows, quick=args.quick)
    print(f"# wrote {path}")
