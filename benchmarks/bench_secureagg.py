"""Paper §3.1.2 scaling claim: Virtual Groups cap the O(n^2) pairwise-mask
MPC cost at O(n*g). Measures real mask-expansion wall time per client
(kernel path) as VG size grows, and reports the cohort-level cost model."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.virtual_groups import pairwise_cost
from repro.kernels import ops


def mask_time_per_client(vg_size: int, model_size: int = 1 << 20) -> float:
    q = jnp.zeros(model_size, jnp.uint32)
    seed = jnp.asarray([1, 2], jnp.uint32)
    out = ops.mask_apply(q, 0, vg_size, seed)  # warmup/compile
    out.block_until_ready()
    t0 = time.perf_counter()
    out = ops.mask_apply(q, 0, vg_size, seed)
    out.block_until_ready()
    return time.perf_counter() - t0


def main(quick=False):
    rows = []
    n_cohort = 1024
    model_size = 1 << 18 if quick else 1 << 20
    print(f"# secure-agg cost: cohort n={n_cohort}, model={model_size} elems")
    print("#  vg_size | mask s/client | cohort mask-expansions | vs O(n^2)")
    base = pairwise_cost(n_cohort)
    for g in ([4, 16] if quick else [2, 4, 8, 16, 32, 64]):
        t = mask_time_per_client(g, model_size)
        cost = pairwise_cost(n_cohort, g)
        print(f"#   {g:6d} | {t:.4f} | {cost:10d} | {cost / base:.4f}")
        rows.append((f"secureagg_maskgen_vg{g}", t * 1e6,
                     f"cohort_cost_ratio={cost / base:.5f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
