"""Paper §3.1.2 scaling claims, measured two ways.

1. Virtual Groups cap the O(n^2) pairwise-mask MPC cost at O(n*g):
   per-client mask-expansion wall time (kernel path) as VG size grows,
   plus the cohort-level cost model (now merge-rule consistent).
2. The whole sync-round privacy pipeline (DP -> quantize -> mask -> VG
   sums -> master combine) serial vs. vectorized vs. vectorized+kernels at
   cohort sizes {64, 256, 1024}: the serial reference dispatches O(n)
   python-level jnp calls; ``repro.core.privacy_engine`` runs the cohort
   as one compiled call (two at most, for ragged plans).
3. The hierarchical stage-2 combine at {2^14, 2^16, 2^18} virtual groups:
   per-shard limb-state fold + exact cross-shard merge wall time, vs the
   single-tier fold where it is still legal (< 2^16 VGs; 2^16 and beyond
   REQUIRE the sharded route — the single-tier accumulator would wrap).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_mod
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.orchestrator import _secure_mean_serial
from repro.core.virtual_groups import make_virtual_groups, pairwise_cost
from repro.kernels import ops


def mask_time_per_client(vg_size: int, model_size: int = 1 << 20) -> float:
    q = jnp.zeros(model_size, jnp.uint32)
    seed = jnp.asarray([1, 2], jnp.uint32)
    out = ops.mask_apply(q, 0, vg_size, seed)  # warmup/compile
    out.block_until_ready()
    t0 = time.perf_counter()
    out = ops.mask_apply(q, 0, vg_size, seed)
    out.block_until_ready()
    return time.perf_counter() - t0


def _pipeline_once(mode, updates, plan, seed, key, scfg, dcfg):
    if mode == "serial":
        out = _secure_mean_serial(dict(sorted(updates.items())), plan, seed,
                                  key, scfg, dcfg)
    else:
        engine = pe.PrivacyEngine(scfg, dcfg)
        out = engine.aggregate_updates(updates, plan, seed, key=key)
    jax.block_until_ready(out)
    return out


def pipeline_times(n_cohort: int, model_size: int, vg_size: int = 8,
                   repeats: int = 3) -> dict:
    """-> {mode: seconds} for one full privacy-pipeline round."""
    rng = np.random.RandomState(0)
    cids = [f"c{i:05d}" for i in range(n_cohort)]
    updates = {c: jnp.asarray(rng.uniform(-0.4, 0.4, model_size)
                              .astype(np.float32)) for c in cids}
    plan = make_virtual_groups(cids, vg_size, seed=0)
    seed = jnp.asarray([1, 2], jnp.uint32)
    key = jax.random.PRNGKey(0)
    dcfg = dp_mod.DPConfig(mechanism="local", clip_norm=0.5,
                           noise_multiplier=0.5)
    times = {}
    for mode, scfg in [("serial", sa.SecureAggConfig(vectorized=False)),
                       ("vectorized", sa.SecureAggConfig()),
                       ("kernels", sa.SecureAggConfig(use_kernels=True))]:
        _pipeline_once(mode, updates, plan, seed, key, scfg, dcfg)  # warmup
        t0 = time.perf_counter()
        for _ in range(repeats):
            _pipeline_once(mode, updates, plan, seed, key, scfg, dcfg)
        times[mode] = (time.perf_counter() - t0) / repeats
    return times


def sharded_combine_times(n_groups: int, size: int, n_shards: int,
                          repeats: int = 3) -> dict:
    """-> {'single'|'sharded': seconds} for one stage-2 combine over
    ``n_groups`` interims of ``size`` elems ('single' only when legal)."""
    from repro.core.quantize import MAX_MASTER_GROUPS
    rng = np.random.RandomState(0)
    interims = jnp.asarray(rng.randint(
        0, 1 << 24, (n_groups, size), dtype=np.int64).astype(np.uint32))
    n = 8 * n_groups
    cfg = sa.SecureAggConfig()

    def run_sharded():
        return sa.combine_limb_states(
            sa._shard_limbs_jit(interims, n_shards), n, cfg)

    def run_single():
        return sa.combine_limb_states(
            sa._shard_limbs_jit(interims, 1), n, cfg)

    out = {}
    runs = {"sharded": run_sharded}
    if n_groups < MAX_MASTER_GROUPS:
        runs["single"] = run_single
    for name, fn in runs.items():
        jax.block_until_ready(fn())              # warmup/compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(fn())
        out[name] = (time.perf_counter() - t0) / repeats
    return out


def main(quick=False):
    rows = []
    n_cohort = 1024
    model_size = 1 << 18 if quick else 1 << 20
    print(f"# secure-agg cost: cohort n={n_cohort}, model={model_size} elems")
    print("#  vg_size | mask s/client | cohort mask-expansions | vs O(n^2)")
    base = pairwise_cost(n_cohort)
    for g in ([4] if quick else [2, 4, 8, 16, 32, 64]):
        t = mask_time_per_client(g, model_size)
        cost = pairwise_cost(n_cohort, g)
        print(f"#   {g:6d} | {t:.4f} | {cost:10d} | {cost / base:.4f}")
        rows.append((f"secureagg_maskgen_vg{g}", t * 1e6,
                     f"cohort_cost_ratio={cost / base:.5f}"))

    size = 1 << 10 if quick else 1 << 14
    cohorts = [16] if quick else [64, 256, 1024]
    print(f"# privacy pipeline (DP+quantize+mask+sums+combine), "
          f"model={size} elems, vg=8")
    print("#  cohort | serial s | vectorized s | kernels s | "
          "vec speedup | kern speedup")
    for n in cohorts:
        t = pipeline_times(n, size, repeats=1 if quick else 2)
        sv = t["serial"] / t["vectorized"]
        sk = t["serial"] / t["kernels"]
        print(f"#   {n:5d} | {t['serial']:.3f} | {t['vectorized']:.4f} | "
              f"{t['kernels']:.4f} | {sv:7.1f}x | {sk:7.1f}x")
        rows.append((f"secureagg_pipeline_n{n}",
                     t["vectorized"] * 1e6,
                     f"serial_speedup={sv:.2f}x kernels_speedup={sk:.2f}x"))

    csize = 1 << 6 if quick else 1 << 8
    sweeps = [1 << 10, 1 << 12] if quick else [1 << 14, 1 << 16, 1 << 18]
    print(f"# hierarchical stage-2 combine, interim size={csize} elems")
    print("#    n_vgs | shards | sharded s | single-tier s")
    for g in sweeps:
        from repro.core.secure_agg import resolve_master_shards
        shards = max(4, resolve_master_shards(g))
        t = sharded_combine_times(g, csize, shards,
                                  repeats=1 if quick else 3)
        single = f"{t['single']:.4f}" if "single" in t else \
            "     wraps (>2^16)"
        print(f"#  {g:7d} | {shards:6d} | {t['sharded']:9.4f} | {single}")
        note = (f"single_tier={t['single']:.5f}s" if "single" in t
                else "single_tier=illegal_past_2^16")
        rows.append((f"secureagg_sharded_combine_vg{g}",
                     t["sharded"] * 1e6, f"shards={shards} {note}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('secureagg', rows, quick=args.quick)}")
