"""Dropout-tolerant secure aggregation: what churn actually costs.

Two claims measured (the churn-ISSUE acceptance):

1. **Round cost under churn** — the full vectorized privacy pipeline with
   dropout rates {0, 5, 20}% at cohorts {64, 256, 1024}: a churn round =
   the alive-masked cohort jit + ONE batched mask-reconstruction call +
   the stage-2 combine. The delta over a clean round is the recovery.
2. **Recovery scales with |D|, not with the plan** — reconstruction wall
   time at fixed cohort while |D| grows (linear in |D|), and at fixed |D|
   while the cohort/group-count grows 16x (flat): each dropped client
   costs g-1 pair-mask expansions, independent of how many groups exist.

Run: ``PYTHONPATH=src python -m benchmarks.bench_dropout [--quick]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_mod
from repro.core import dropout
from repro.core import privacy_engine as pe
from repro.core import secure_agg as sa
from repro.core.virtual_groups import make_virtual_groups


def _mk_cohort(n, size, drop_rate, vg_size, seed=0):
    rng = np.random.RandomState(seed)
    cids = [f"c{i:05d}" for i in range(n)]
    flat = jnp.asarray(rng.uniform(-0.4, 0.4, (n, size)).astype(np.float32))
    plan = make_virtual_groups(cids, vg_size, seed=seed)
    n_drop = int(round(drop_rate * n))
    alive = np.ones(n, bool)
    if n_drop:
        alive[rng.choice(n, n_drop, replace=False)] = False
    return cids, flat, plan, alive, n_drop


def churn_round_time(n_cohort, size, drop_rate, vg_size=8,
                     repeats=3) -> dict:
    """One full churn round (DP off isolates the protocol cost):
    -> {'round_s', 'recovery_s', 'n_dropped'}."""
    cids, flat, plan, alive, n_drop = _mk_cohort(n_cohort, size, drop_rate,
                                                 vg_size)
    seed = jnp.asarray([1, 2], jnp.uint32)
    scfg = sa.SecureAggConfig()
    dcfg = dp_mod.DPConfig()
    kw = dict(secure_cfg=scfg, dp_cfg=dcfg, key=jax.random.PRNGKey(0))
    if n_drop:
        kw["alive"] = alive

    def once():
        stats: dict = {}
        out = pe.aggregate_flat(flat, plan, cids, seed,
                                stats=stats if n_drop else None, **kw)
        jax.block_until_ready(out)
        return stats

    stats = once()                       # warmup / compile
    t0 = time.perf_counter()
    rec = 0.0
    for _ in range(repeats):
        s = once()
        rec += s.get("recovery_s", 0.0)
    return {"round_s": (time.perf_counter() - t0) / repeats,
            "recovery_s": rec / repeats, "n_dropped": n_drop}


def recovery_time(n_cohort, size, n_drop, vg_size=8, repeats=3) -> float:
    """Standalone batched-reconstruction wall time for exactly ``n_drop``
    dropped clients in an ``n_cohort``-client plan (interims prebuilt, so
    ONLY the recovery is on the clock)."""
    rng = np.random.RandomState(1)
    cids = [f"c{i:05d}" for i in range(n_cohort)]
    plan = make_virtual_groups(cids, vg_size, seed=1)
    buckets = pe.plan_buckets(plan, cids)
    n_groups = sum(b.n_groups for b in buckets)
    interims = jnp.asarray(rng.randint(
        0, 1 << 20, (n_groups, size), dtype=np.int64).astype(np.uint32))
    alive = np.ones(n_cohort, bool)
    if n_drop:
        alive[rng.choice(n_cohort, n_drop, replace=False)] = False
    seed = jnp.asarray([3, 4], jnp.uint32)

    def once():
        out = dropout.recover_interims(interims, buckets, alive, seed)
        jax.block_until_ready(out)

    once()                               # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        once()
    return (time.perf_counter() - t0) / repeats


def main(quick=False):
    rows = []
    size = 1 << 10 if quick else 1 << 14
    cohorts = [16, 64] if quick else [64, 256, 1024]
    rates = [0.0, 0.05, 0.20]
    repeats = 1 if quick else 3

    print(f"# churn round cost: vectorized pipeline + mask recovery, "
          f"model={size} elems, vg=8, DP off")
    print("#  cohort | drop % | |D| | round s | recovery s")
    for n in cohorts:
        for rate in rates:
            t = churn_round_time(n, size, rate, repeats=repeats)
            print(f"#   {n:5d} | {int(rate * 100):5d}% | {t['n_dropped']:3d}"
                  f" | {t['round_s']:.4f} | {t['recovery_s']:.4f}")
            rows.append((f"dropout_round_n{n}_r{int(rate * 100)}",
                         t["round_s"] * 1e6,
                         f"recovery_s={t['recovery_s']:.5f} "
                         f"n_dropped={t['n_dropped']}"))

    # recovery cost ~ |D| at fixed plan ...
    n_fix = 64 if quick else 1024
    drops = [1, 2, 4, 8] if quick else [1, 8, 51, 205]
    print(f"# recovery scaling in |D| (cohort {n_fix}, vg=8, "
          f"{size} elems)")
    print("#    |D| | recovery s")
    base = None
    for d in drops:
        t = recovery_time(n_fix, size, d, repeats=repeats)
        base = base or t
        print(f"#   {d:4d} | {t:.4f}")
        rows.append((f"dropout_recovery_d{d}", t * 1e6,
                     f"cohort={n_fix} vs_d{drops[0]}={t / base:.2f}x"))

    # ... and flat in the group count at fixed |D|
    d_fix = 2 if quick else 8
    sweep = [16, 64] if quick else [64, 256, 1024]
    print(f"# recovery vs cohort size at fixed |D|={d_fix} "
          f"(cost must stay ~flat)")
    print("#  cohort | groups | recovery s")
    for n in sweep:
        t = recovery_time(n, size, d_fix, repeats=repeats)
        print(f"#   {n:5d} | {n // 8:6d} | {t:.4f}")
        rows.append((f"dropout_recovery_flat_n{n}", t * 1e6,
                     f"n_dropped={d_fix}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('dropout', rows, quick=args.quick)}")
