"""Million-device fleet: what the array-backed control plane costs.

The fleet-scale ISSUE acceptance, measured end to end:

1. **Register** — bulk-enroll a ``PopulationArrays`` fleet (10^6 devices
   in the full run) into a task through ``ManagementService.
   register_fleet``: one vectorized pass instead of 10^6 SDK calls.
2. **Select** — cohort selection at growing sizes (1k/4k/16k) against the
   full fleet, including the whole-fleet ``available_mask`` filter; plus
   a head-to-head against an inline reconstruction of the legacy
   dict+sorted-comprehension pool at 10^5 devices (the full run asserts
   the >= 10x speedup the ISSUE requires).
3. **Round** — one complete sync round (begin_round -> synthetic stacked
   updates -> submit_cohort) with a 16,384-client cohort streamed through
   4096-wide compiled waves (``SecureAggConfig.wave_clients``).
4. **Wave parity** — the streamed aggregate at cohort 4096 / wave 1024 is
   asserted BIT-IDENTICAL to the single-dispatch aggregate.

Run: ``PYTHONPATH=src python -m benchmarks.bench_fleet [--quick]``.
"""
from __future__ import annotations

import argparse
import random
import time

import numpy as np

from repro.fl import ManagementService, PopulationArrays, TaskConfig
from repro.fl.task import SelectionCriteria

MODEL_DIM = 256

# the bulk enrollment path matches criteria once against the fleet
# template; attestation is per-device by design, so the bench opts out
_CRIT = SelectionCriteria(require_attestation=False)


def _model0():
    return {"w": np.zeros(MODEL_DIM, np.float32)}


def _legacy_pool_select(regs: dict, leases: dict, task_id: int, k: int,
                        rng) -> list:
    """The pre-refactor selectable-pool computation, verbatim in shape
    (see the old ``SelectionService.available``): a sorted comprehension
    over the per-task registration dict, with a per-device status
    attribute check AND a per-device ``directory.leasable`` lease-dict
    probe, then ``random.Random.sample`` over the materialized list. This
    is the baseline the array path must beat 10x at 10^5 devices."""
    pool = sorted(cid for cid, reg in regs.items()
                  if reg.status == "registered"
                  and (leases.get(cid) is None
                       or leases[cid].task_id == task_id))
    return sorted(rng.sample(pool, min(k, len(pool))))


def bench_register(svc, task_id, pop) -> float:
    t0 = time.perf_counter()
    n = svc.register_fleet(task_id, pop)
    dt = time.perf_counter() - t0
    assert n == len(pop), (n, len(pop))
    return dt


def bench_select(svc, rec, pop, cohort_sizes, repeat=3):
    """Per-cohort-size mean select+reset seconds against the full fleet,
    with the vectorized availability mask in the loop (the realistic
    selection-time filter)."""
    out = []
    for k in cohort_sizes:
        rec.config.clients_per_round = k
        times = []
        for r in range(repeat):
            avail = pop.available_mask(float(r))
            t0 = time.perf_counter()
            cohort = svc.selection.select_cohort(rec, available=avail)
            times.append(time.perf_counter() - t0)
            assert len(cohort) == k, (len(cohort), k)
            svc.selection.reset_round(rec)
        out.append((k, sum(times) / len(times)))
    return out


def bench_select_vs_legacy(n_devices: int, k: int, repeat=3):
    """Array select vs the legacy dict-pool reference at the same fleet
    size, same draw target. Returns (array_s, legacy_s)."""
    svc = ManagementService(seed=0)
    tid = svc.create_task(
        TaskConfig("fleet-legacy", "bench", "wf", clients_per_round=k,
                   n_rounds=1, vg_size=8, selection=_CRIT), _model0())
    rec = svc.get_task(tid)
    pop = PopulationArrays.sample(n_devices, seed=1)
    svc.register_fleet(tid, pop)
    arr_t = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        svc.selection.select_cohort(rec)
        arr_t.append(time.perf_counter() - t0)
        svc.selection.reset_round(rec)
    from repro.fl.selection import Registration
    regs = {cid: Registration(cid, {}) for cid in pop.ids}
    leases: dict = {}
    rng = random.Random(0)
    leg_t = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        picks = _legacy_pool_select(regs, leases, tid, k, rng)
        leg_t.append(time.perf_counter() - t0)
        assert len(picks) == k
    return min(arr_t), min(leg_t)


def bench_round(svc, rec, cohort_size: int, wave: int) -> dict:
    """One full sync round at ``cohort_size`` with the privacy pipeline
    streaming ``wave``-client compiled waves; synthetic stacked updates
    stand in for training (this measures the CONTROL+AGGREGATION plane)."""
    from dataclasses import replace
    rec.config.clients_per_round = cohort_size
    rec.config.secure_agg = replace(rec.config.secure_agg,
                                    wave_clients=wave)
    t0 = time.perf_counter()
    round_idx, cohort = svc.begin_round(rec.task_id)
    select_s = time.perf_counter() - t0
    assert len(cohort) == cohort_size
    rng = np.random.RandomState(round_idx)
    stacked = {"w": rng.standard_normal(
        (len(cohort), MODEL_DIM)).astype(np.float32) * 0.01}
    t0 = time.perf_counter()
    ok = svc.submit_cohort(rec.task_id, cohort, stacked, n_samples=10)
    agg_s = time.perf_counter() - t0
    assert ok, "round did not complete"
    return {"select_s": select_s, "agg_s": agg_s,
            "round_idx": rec.round_idx}


def wave_parity(cohort=4096, wave=1024, dim=64, vg=8) -> bool:
    """Streamed-wave aggregate == single-dispatch aggregate, bit for bit
    (the acceptance shape: cohort 4096, wave 1024)."""
    import jax.numpy as jnp
    from repro.core import privacy_engine as pe
    from repro.core.secure_agg import SecureAggConfig
    from repro.core.virtual_groups import make_virtual_groups
    cids = [f"c{i:05d}" for i in range(cohort)]
    plan = make_virtual_groups(cids, vg, seed=3)
    flat = jnp.asarray(np.random.RandomState(7).standard_normal(
        (cohort, dim)).astype(np.float32) * 0.02)
    seed = (11, 13)
    one = pe.aggregate_flat(flat, plan, cids, seed,
                            secure_cfg=SecureAggConfig())
    waved = pe.aggregate_flat(flat, plan, cids, seed,
                              secure_cfg=SecureAggConfig(wave_clients=wave))
    return bool(np.array_equal(np.asarray(one), np.asarray(waved)))


def main(quick=False):
    if quick:
        fleet, cohorts = 20_000, [256, 1024]
        legacy_n, legacy_k = 5_000, 128
        round_cohort, round_wave = 1024, 256
        parity_kw = dict(cohort=512, wave=128)
    else:
        fleet, cohorts = 1_000_000, [1024, 4096, 16384]
        legacy_n, legacy_k = 100_000, 256
        round_cohort, round_wave = 16384, 4096
        parity_kw = dict(cohort=4096, wave=1024)
    rows = []
    print(f"# fleet-scale control plane: {fleet} devices")
    pop = PopulationArrays.sample(fleet, seed=0)
    svc = ManagementService(seed=0)
    tid = svc.create_task(
        TaskConfig("fleet", "bench", "wf", clients_per_round=cohorts[0],
                   n_rounds=10**6, vg_size=8, selection=_CRIT), _model0())
    rec = svc.get_task(tid)

    reg_s = bench_register(svc, tid, pop)
    print(f"#   register_fleet: {fleet} devices in {reg_s:.3f}s "
          f"({fleet / reg_s / 1e6:.2f} M dev/s)")
    rows.append((f"fleet{fleet}_register_s", reg_s,
                 f"bulk enroll, {fleet / reg_s / 1e6:.2f} M devices/s"))

    for k, sel_s in bench_select(svc, rec, pop, cohorts):
        print(f"#   select cohort {k:6d}: {sel_s * 1e3:.1f} ms")
        rows.append((f"fleet{fleet}_select{k}_ms", sel_s * 1e3,
                     "select_cohort + availability mask + reset, mean of 3"))

    arr_s, leg_s = bench_select_vs_legacy(legacy_n, legacy_k)
    speedup = leg_s / arr_s
    print(f"#   select @ {legacy_n} devices: array {arr_s * 1e3:.1f} ms vs "
          f"legacy dict pool {leg_s * 1e3:.1f} ms -> {speedup:.1f}x")
    rows.append((f"select{legacy_n}_speedup_x", speedup,
                 f"array {arr_s * 1e3:.2f} ms vs legacy sorted-dict "
                 f"{leg_s * 1e3:.2f} ms at cohort {legacy_k}"))
    if not quick:
        assert speedup >= 10.0, f"array select only {speedup:.1f}x faster"

    r = bench_round(svc, rec, round_cohort, round_wave)
    print(f"#   round @ cohort {round_cohort} (wave {round_wave}): "
          f"select {r['select_s']:.2f}s, secure-agg {r['agg_s']:.2f}s")
    rows.append((f"fleet{fleet}_round{round_cohort}_agg_s", r["agg_s"],
                 f"submit_cohort w/ wave_clients={round_wave}, "
                 f"select={r['select_s']:.2f}s"))

    ok = wave_parity(**parity_kw)
    assert ok, "waved aggregate diverged from single dispatch"
    print(f"#   wave parity (cohort {parity_kw['cohort']}, wave "
          f"{parity_kw['wave']}): bit-identical")
    rows.append(("wave_parity_bitident", 1.0,
                 f"cohort {parity_kw['cohort']} / wave {parity_kw['wave']} "
                 "streamed == single dispatch"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small fleet — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('fleet', rows, quick=args.quick)}")
