"""Sub-1% rounds: federated LoRA + top-k under bit-exact secure agg.

The ISSUE 9 acceptance, measured end to end:

1. **<1% on a real config** — rank-2 attention-only LoRA over the real
   whisper-medium shapes (759M params, 3.0 GB f32): the per-client
   upload fraction is computed from the ACTUAL param tree (abstract
   ShapeDtypeStructs in quick mode — no 3 GB init) and asserted < 1%.
2. **LoRA e2e (quickstart)** — a federated LoRA round on the spam task
   through CohortEngine + ManagementService: adapters train, the bytes
   entering secure aggregation are the measured flat adapter delta.
3. **Top-k e2e** — a compressed sync round through the real service path
   with the measured ``upload_bytes_per_client`` telemetry asserted
   < 1% of the dense model bytes.
4. **Full mode only: whisper-medium LoRA finetune** — materialize the
   real 3 GB model, train rank-2 attention adapters on 4 clients, run
   the actual secure-agg round over the adapter deltas, and assert the
   MEASURED bytes per client entering the chain (the raveled payload
   rows) are < 1% of the dense model size — optionally composed with
   top-k for another ~4x.

Run: ``PYTHONPATH=src python -m benchmarks.bench_compression [--quick]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lora
from repro.core import privacy_engine as pe
from repro.core.sparse import SparseConfig, TopKCompressor
from repro.fl import ManagementService, TaskConfig
from repro.fl.task import CompressionConfig, SelectionCriteria

_CRIT = SelectionCriteria(require_attestation=False)
_WHISPER_LORA = lora.LoRAConfig(rank=2, alpha=4.0, include=("attn",))


def bench_whisper_fraction(rows) -> float:
    """The <1% acceptance against the real config's shapes — computed
    from the abstract param tree, so it measures exactly what the full
    run materializes."""
    from repro.configs import get_config
    from repro.launch.input_specs import abstract_params

    params = abstract_params(get_config("whisper-medium"))
    dense = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    frac = lora.upload_fraction(_WHISPER_LORA, params)
    print(f"#   whisper-medium: {dense / 1e6:.0f}M params "
          f"({dense * 4 / 1e9:.2f} GB f32), rank-2 attn LoRA upload "
          f"fraction {frac * 100:.3f}%")
    assert frac < 0.01, f"LoRA upload {frac:.4f} >= 1% of dense"
    rows.append(("whisper_lora_upload_pct", frac * 100,
                 "rank-2 attn-only adapters / 759M dense params"))
    return frac


def bench_lora_quickstart(rows):
    """Federated LoRA on the spam quickstart: adapters-as-model through
    the unchanged service + secure agg; upload = measured raveled delta."""
    from benchmarks.common import SpamWorld
    from repro.core.cohort_engine import CohortEngine
    from repro.models import classify_loss
    from repro.optim import adamw

    world = SpamWorld(vocab=256, d_model=32, seq_len=8, n_train=1000,
                      n_splits=10, batch_size=2, d_ff=64, head_dim=16)
    lcfg = lora.LoRAConfig(rank=2, alpha=4.0, min_dim=8)
    adapters0 = lora.init_adapters(lcfg, world.model0,
                                   jax.random.PRNGKey(1))
    spec = lora.lora_spec(
        lcfg, world.model0,
        lambda m, b: classify_loss(world.cfg, m["trunk"], m["head"], b),
        adamw(lr=5e-3), local_steps=2)
    engine = CohortEngine(spec, world.engine_batch_fn(2, 2),
                          template_params=adapters0)
    svc = ManagementService(seed=0)
    tid = svc.create_task(
        TaskConfig("lora", "bench", "wf", clients_per_round=6, n_rounds=4,
                   vg_size=3, selection=_CRIT), adapters0)
    for i in range(6):
        svc.register_client(tid, f"client-{i:04d}",
                            {"os": "linux", "n_samples": 10})
    t0, losses, upload = time.perf_counter(), [], 0
    for r in range(3):
        _, cohort = svc.begin_round(tid)
        deltas, l_r, n = engine.run_cohort_stacked(
            svc.get_task(tid).model, sorted(cohort), r)
        upload = int(pe.ravel_rows(deltas).shape[1]) * 4
        svc.submit_cohort(tid, sorted(cohort), deltas, n)
        losses.append(float(np.mean(np.asarray(l_r))))
    dt = time.perf_counter() - t0
    dense = lora.n_params(world.model0) * 4
    assert losses[-1] < losses[0], losses
    print(f"#   quickstart LoRA: 3 rounds in {dt:.2f}s, loss "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}, upload {upload} B "
          f"vs dense {dense} B ({upload / dense * 100:.1f}%)")
    rows.append(("quickstart_lora_round_s", dt / 3,
                 f"loss {losses[0]:.3f}->{losses[-1]:.3f}, "
                 f"upload {upload / dense * 100:.1f}% of dense"))


def bench_topk_service(rows):
    """A compressed sync round through the real service path; the
    telemetry's measured upload is asserted < 1% of dense bytes."""
    dim = 320
    model = {"w": jnp.zeros((dim, dim), jnp.float32)}
    dense_bytes = dim * dim * 4
    svc = ManagementService(seed=0)
    tid = svc.create_task(
        TaskConfig("topk", "bench", "wf", clients_per_round=8, n_rounds=4,
                   vg_size=4, selection=_CRIT,
                   compression=CompressionConfig(kind="topk", frac=0.005)),
        model)
    for i in range(8):
        svc.register_client(tid, f"c{i}", {"os": "linux", "n_samples": 10})
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for r in range(3):
        _, cohort = svc.begin_round(tid)
        for cid in sorted(cohort):
            svc.submit_update(
                tid, cid,
                {"w": jnp.asarray(rng.normal(size=(dim, dim)),
                                  jnp.float32)}, n_samples=10)
    dt = time.perf_counter() - t0
    up = svc.get_task(tid).history[-1]["upload_bytes_per_client"]
    frac = up / dense_bytes
    assert frac < 0.01, f"top-k upload {frac:.4f} >= 1% of dense"
    print(f"#   top-k service round: {up} B/client vs dense "
          f"{dense_bytes} B ({frac * 100:.2f}%), {dt / 3:.2f}s/round")
    rows.append(("topk_upload_pct", frac * 100,
                 f"frac=0.005 over {dim * dim} coords, secure-agg path"))


def bench_whisper_lora_e2e(rows):
    """Full mode: the real 3 GB whisper-medium, rank-2 attention
    adapters, 4 clients, one real secure-agg round over the adapter
    deltas — the MEASURED payload row entering the chain < 1% of dense."""
    from repro.configs import get_config
    from repro.core.cohort_engine import make_local_update
    from repro.core.orchestrator import run_sync_round_stacked
    from repro.core.strategies import make_strategy
    from repro.models import init_params, loss_fn
    from repro.optim import sgd

    cfg = get_config("whisper-medium")
    t0 = time.perf_counter()
    base = init_params(cfg, jax.random.PRNGKey(0))
    dense_bytes = lora.n_params(base) * 4
    print(f"#   whisper-medium materialized: {dense_bytes / 1e9:.2f} GB "
          f"in {time.perf_counter() - t0:.1f}s")
    adapters0 = lora.init_adapters(_WHISPER_LORA, base,
                                   jax.random.PRNGKey(1))
    spec = lora.lora_spec(_WHISPER_LORA, base,
                          lambda p, b: loss_fn(cfg, p, b),
                          sgd(1e-3), local_steps=1)
    local_update = make_local_update(spec)

    b, s, sd = 2, 8, 16

    def client_batch(seed):
        r = np.random.RandomState(seed)
        return {
            "frames": jnp.asarray(r.randn(1, b, s, cfg.d_model) * 0.02,
                                  jnp.float32),
            "tokens": jnp.asarray(r.randint(0, cfg.vocab_size, (1, b, sd)),
                                  jnp.int32),
            "targets": jnp.asarray(r.randint(0, cfg.vocab_size,
                                             (1, b, sd)), jnp.int32),
            "mask": jnp.ones((1, b, sd), jnp.float32),
        }

    t0 = time.perf_counter()
    deltas, losses = [], []
    for i in range(4):      # serial: one 3 GB merge live at a time
        delta, loss = local_update(adapters0, client_batch(100 + i))
        deltas.append(jax.tree.map(np.asarray, delta))
        losses.append(float(loss))
    train_s = time.perf_counter() - t0
    assert all(np.isfinite(losses)), losses

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    upload = int(pe.ravel_rows(stacked).shape[1]) * 4   # measured payload
    frac = upload / dense_bytes
    assert frac < 0.01, f"measured upload {frac:.4f} >= 1% of dense"

    cids = [f"c{i}" for i in range(4)]
    strategy = make_strategy("fedavg")
    t0 = time.perf_counter()
    new_adapters, _, info = run_sync_round_stacked(
        adapters0, strategy, strategy.init_state(adapters0), cids, stacked,
        round_idx=0, vg_size=4)
    agg_s = time.perf_counter() - t0
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(adapters0),
                                jax.tree.leaves(new_adapters)))
    assert moved, "round did not move the adapters"
    print(f"#   whisper LoRA round: train {train_s:.1f}s (4 clients), "
          f"secure-agg {agg_s:.2f}s, upload {upload / 1e6:.1f} MB/client "
          f"vs dense {dense_bytes / 1e9:.2f} GB ({frac * 100:.3f}%)")
    rows.append(("whisper_lora_e2e_upload_pct", frac * 100,
                 f"measured payload {upload / 1e6:.1f} MB vs "
                 f"{dense_bytes / 1e9:.2f} GB dense; "
                 f"agg {agg_s:.2f}s, loss[0]={losses[0]:.2f}"))

    # compose with top-k on the adapter vector: another ~4x
    size = upload // 4
    comp = TopKCompressor(SparseConfig(k=max(1, size // 4)), size)
    payload = comp.compress_rows(cids, np.asarray(pe.ravel_rows(stacked)),
                                 0)
    topk_frac = payload.shape[1] * 4 / dense_bytes
    print(f"#   + top-k 25% on the adapter delta: "
          f"{payload.shape[1] * 4 / 1e6:.1f} MB/client "
          f"({topk_frac * 100:.4f}% of dense)")
    rows.append(("whisper_lora_topk_upload_pct", topk_frac * 100,
                 "rank-2 attn LoRA + top-k 25% of adapter coords"))


def main(quick=False):
    rows = []
    print("# update compression: sub-1% rounds under secure aggregation")
    bench_whisper_fraction(rows)
    bench_lora_quickstart(rows)
    bench_topk_service(rows)
    if not quick:
        bench_whisper_lora_e2e(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the 3 GB whisper materialization — the "
                         "CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    out = write_bench_json("compression", rows, quick=args.quick)
    print(f"# wrote {out}")
