"""Paper Fig. 11 (left): spam-classification accuracy per iteration,
federated baseline vs federated + local DP — §5.1 protocol: 32 clients per
round, 10 iterations, 100 splits @ 20%, batch 8, AdamW 5e-4; DP with clip
0.5 and the RDP accountant's epsilon reported (paper: ~2 at delta=1e-5)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SpamWorld
from repro.core.dp import DPConfig, compute_rdp, get_privacy_spent
from repro.fl import ManagementService, TaskConfig
from repro.fl.simulator import make_heterogeneous_clients, run_sync_simulation


def run_variant(world, dp: DPConfig, n_rounds=10, clients_per_round=32,
                pool=64, label="fl"):
    svc = ManagementService()
    tid = svc.create_task(
        TaskConfig(f"spam-{label}", "spam-app", "train",
                   clients_per_round=clients_per_round, n_rounds=n_rounds,
                   vg_size=8, dp=dp),
        world.model0)
    clients = make_heterogeneous_clients(pool, world.make_trainer,
                                         base_train_s=1.0)
    res = run_sync_simulation(svc, tid, clients, eval_fn=world.test_accuracy)
    accs = [h["eval_accuracy"] for h in res.metrics_history]
    eps = svc.epsilon(tid)
    return accs, res.round_durations, eps


def required_z_for_epsilon(target_eps=2.0, q=0.32, steps=10, delta=1e-5):
    """Binary-search the noise multiplier giving the paper's quoted eps=2."""
    lo, hi = 0.05, 20.0
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        eps, _ = get_privacy_spent(compute_rdp(q, mid, steps), delta)
        if eps > target_eps:
            lo = mid
        else:
            hi = mid
    return hi


def main(rounds=10, quick=False):
    rows = []
    if quick:
        rounds = 5
        world = SpamWorld(n_train=4000, n_splits=20, frac=0.5)
        cpr, pool = 8, 16
    else:
        world = SpamWorld()
        cpr, pool = 32, 64
    base_acc, base_dur, _ = run_variant(
        world, DPConfig(mechanism="off"), n_rounds=rounds,
        clients_per_round=cpr, pool=pool, label="base")
    # Honest accounting note (EXPERIMENTS.md §Paper-validation): the paper
    # reports eps=2 with clip 0.5 and "noise scale 0.08" (z = 0.16). A
    # standard subsampled-RDP accountant gives eps ~ 1.6e2 for that z; eps=2
    # at q=0.32, T=10 needs z ~ 1.2. We run the DP variant at the z that
    # actually yields the paper's quoted eps, and report both.
    z_paper_quote = 0.08 / 0.5
    z_for_eps2 = required_z_for_epsilon(2.0, q=32 / 100, steps=rounds)
    # (a) the paper's exact setting (clip 0.5, z=0.16) — reproduces the
    #     "slight decrease + convergence issues" of Fig. 11 left
    dpp_acc, _, _ = run_variant(
        world, DPConfig(mechanism="local", clip_norm=0.5,
                        noise_multiplier=z_paper_quote, delta=1e-5),
        n_rounds=rounds, clients_per_round=cpr, pool=pool, label="dp-paper")
    # (b) the z that actually yields the quoted eps=2 per our accountant
    dp_cfg = DPConfig(mechanism="local", clip_norm=0.5,
                      noise_multiplier=z_for_eps2, delta=1e-5)
    dp_acc, dp_dur, _ = run_variant(world, dp_cfg, n_rounds=rounds,
                                    clients_per_round=cpr, pool=pool,
                                    label="dp-eps2")
    eps_quote, _ = get_privacy_spent(
        compute_rdp(0.32, z_paper_quote, rounds), 1e-5)
    eps_run, order = get_privacy_spent(
        compute_rdp(0.32, z_for_eps2, rounds), 1e-5)
    print(f"# fig11-left: final acc base={base_acc[-1]:.3f} "
          f"dp(z=0.16 paper)={dpp_acc[-1]:.3f} (eps={eps_quote:.1f}) "
          f"dp(z={z_for_eps2:.2f})={dp_acc[-1]:.3f} (eps={eps_run:.2f}"
          f"@order{order})")
    print(f"# accuracy/base    : {[round(a, 3) for a in base_acc]}")
    print(f"# accuracy/dp-paper: {[round(a, 3) for a in dpp_acc]}")
    print(f"# accuracy/dp-eps2 : {[round(a, 3) for a in dp_acc]}")
    rows.append(("fig11_left_base_final_acc",
                 np.mean(base_dur) * 1e6, f"{base_acc[-1]:.4f}"))
    rows.append(("fig11_left_dp_paper_z016_final_acc", 0.0,
                 f"{dpp_acc[-1]:.4f}"))
    rows.append(("fig11_left_dp_paper_acc_drop", 0.0,
                 f"{base_acc[-1] - dpp_acc[-1]:.4f}"))
    rows.append(("fig11_left_dp_eps2_final_acc",
                 np.mean(dp_dur) * 1e6, f"{dp_acc[-1]:.4f}"))
    rows.append(("fig11_left_dp_epsilon", 0.0, f"{eps_run:.3f}"))
    rows.append(("fig11_left_z_for_eps2", 0.0, f"{z_for_eps2:.3f}"))
    rows.append(("fig11_left_eps_at_paper_z016", 0.0, f"{eps_quote:.1f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
