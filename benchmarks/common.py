"""Shared benchmark harness pieces: the paper's §5.1 spam-classification
training setup (BERT-tiny-class model, 100 splits, 20% per round, batch 8,
AdamW 5e-4), reusable across Fig. 11 benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import deserialize_pytree
from repro.configs import get_config
from repro.data import ClientDataAccess, batches, spam_dataset
from repro.models import (classifier_init, classify_logits, classify_loss,
                          init_params)
from repro.optim import adamw
from repro.optim.adamw import apply_updates


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / repeat * 1e6, out  # us


class SpamWorld:
    """Paper §5.1 setup on synthetic enron-like data."""

    def __init__(self, vocab=4096, d_model=128, seq_len=32, n_train=10_000,
                 lr=5e-4, batch_size=8, n_splits=50, frac=0.2, seed=0):
        # paper: 100 splits of enron (~330/split), 20% => ~67 samples/round.
        # synthetic: 50 splits of 10k => 200/split, 20% => 40 samples/round
        # (same order of local work per client per round).
        self.cfg = get_config("bert-tiny-spam").replace(vocab_size=vocab,
                                                        d_model=d_model)
        key = jax.random.PRNGKey(seed)
        self.model0 = {
            "trunk": init_params(self.cfg, key),
            "head": classifier_init(self.cfg, jax.random.fold_in(key, 1)),
        }
        self.train = spam_dataset(n_samples=n_train, vocab_size=vocab,
                                  seq_len=seq_len, seed=seed)
        self.test = spam_dataset(n_samples=800, vocab_size=vocab,
                                 seq_len=seq_len, seed=seed + 77)
        self.access = ClientDataAccess(self.train, n_splits=n_splits,
                                       frac=frac, seed=seed)
        self.batch_size = batch_size
        opt = adamw(lr=lr)
        cfg = self.cfg

        @jax.jit
        def local_step(model, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda m: classify_loss(cfg, m["trunk"], m["head"],
                                        batch))(model)
            upd, opt_state = opt.update(grads, opt_state, model)
            return apply_updates(model, upd), opt_state, loss

        self._local_step = local_step
        self._opt = opt

        @jax.jit
        def _acc(model, batch):
            logits = classify_logits(cfg, model["trunk"], model["head"],
                                     batch)
            return jnp.mean(jnp.argmax(logits, -1) == batch["label"])

        self._acc = _acc
        self._test_batch = {k: jnp.asarray(v) for k, v in self.test.items()}

    def test_accuracy(self, model) -> float:
        return float(self._acc(model, self._test_batch))

    def make_trainer(self, i: int):
        """Paper-protocol client trainer for the SDK/simulator."""
        def trainer(blob, round_idx):
            model = deserialize_pytree(blob, like=self.model0)
            d = self.access.sample(client_seed=round_idx * 9973 + i)
            opt_state = self._opt.init(model)
            new, n, loss = model, 0, jnp.zeros(())
            for b in batches(d, self.batch_size, seed=round_idx):
                b = {k: jnp.asarray(v) for k, v in b.items()}
                new, opt_state, loss = self._local_step(new, opt_state, b)
                n += len(b["label"])
            update = jax.tree.map(
                lambda a, b_: np.asarray(a, np.float32)
                - np.asarray(b_, np.float32), new, model)
            return update, max(n, 1), {"loss": float(loss)}
        return trainer
