"""Shared benchmark harness pieces: the paper's §5.1 spam-classification
training setup (BERT-tiny-class model, 100 splits, 20% per round, batch 8,
AdamW 5e-4), reusable across Fig. 11 benchmarks, plus the machine-readable
results writer every bench's ``__main__`` feeds."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _git_rev() -> str:
    """Short commit hash of the tree the bench ran in ('unknown' outside a
    checkout) — the provenance stamp for every BENCH_*.json."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def host_info() -> dict:
    """Machine fingerprint persisted with every bench run — perf numbers
    without the box they ran on are not comparable across the trajectory."""
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
    }


def write_bench_json(bench: str, rows, quick=False, out_dir=None) -> str:
    """Persist a bench run as ``BENCH_<bench>.json`` (machine-readable
    sibling of the human CSV lines every bench prints).

    ``rows``: the ``(name, value, note)`` tuples the bench ``main()``
    returns. If a previous run's file exists, each metric also records
    ``prev`` and ``delta_pct`` against it, so regressions are one ``jq``
    away instead of a diff of stdout logs. Returns the path written.
    Quick (smoke) runs and full runs land in the same file but are
    tagged, so a CI smoke never masquerades as a real baseline."""
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    prev = {}
    prev_quick = None
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            prev_quick = old.get("quick")
            prev = {r["name"]: r["value"] for r in old.get("rows", [])}
        except (ValueError, KeyError):
            pass                      # corrupt previous file: no baseline
    out_rows = []
    for name, value, note in rows:
        row = {"name": str(name), "value": float(value), "note": str(note)}
        # only compare like with like — a quick smoke vs a full run is
        # a shape change, not a perf delta
        if name in prev and prev_quick == bool(quick):
            row["prev"] = prev[name]
            if prev[name]:
                row["delta_pct"] = round(
                    (row["value"] - prev[name]) / abs(prev[name]) * 100, 2)
        out_rows.append(row)
    with open(path, "w") as f:
        json.dump({"bench": bench, "quick": bool(quick),
                   "unix_time": time.time(), "git_rev": _git_rev(),
                   "host": host_info(), "rows": out_rows}, f, indent=1)
        f.write("\n")
    return path

from repro.checkpoint import deserialize_pytree
from repro.configs import get_config
from repro.data import ClientDataAccess, batches, spam_dataset
from repro.models import (classifier_init, classify_logits, classify_loss,
                          init_params)
from repro.optim import adamw
from repro.optim.adamw import apply_updates


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / repeat * 1e6, out  # us


class SpamWorld:
    """Paper §5.1 setup on synthetic enron-like data."""

    def __init__(self, vocab=4096, d_model=128, seq_len=32, n_train=10_000,
                 lr=5e-4, batch_size=8, n_splits=50, frac=0.2, seed=0,
                 **cfg_overrides):
        # paper: 100 splits of enron (~330/split), 20% => ~67 samples/round.
        # synthetic: 50 splits of 10k => 200/split, 20% => 40 samples/round
        # (same order of local work per client per round).
        # cfg_overrides: extra ArchConfig.replace fields (d_ff, head_dim, …)
        # for reduced "sim-scale" worlds in scale studies.
        self.cfg = get_config("bert-tiny-spam").replace(vocab_size=vocab,
                                                        d_model=d_model,
                                                        **cfg_overrides)
        key = jax.random.PRNGKey(seed)
        self.model0 = {
            "trunk": init_params(self.cfg, key, max_positions=seq_len),
            "head": classifier_init(self.cfg, jax.random.fold_in(key, 1)),
        }
        self.train = spam_dataset(n_samples=n_train, vocab_size=vocab,
                                  seq_len=seq_len, seed=seed)
        self.test = spam_dataset(n_samples=800, vocab_size=vocab,
                                 seq_len=seq_len, seed=seed + 77)
        self.access = ClientDataAccess(self.train, n_splits=n_splits,
                                       frac=frac, seed=seed)
        self.batch_size = batch_size
        self.lr = lr
        opt = adamw(lr=lr)
        cfg = self.cfg

        @jax.jit
        def local_step(model, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda m: classify_loss(cfg, m["trunk"], m["head"],
                                        batch))(model)
            upd, opt_state = opt.update(grads, opt_state, model)
            return apply_updates(model, upd), opt_state, loss

        self._local_step = local_step
        self._opt = opt

        @jax.jit
        def _acc(model, batch):
            logits = classify_logits(cfg, model["trunk"], model["head"],
                                     batch)
            return jnp.mean(jnp.argmax(logits, -1) == batch["label"])

        self._acc = _acc
        self._test_batch = {k: jnp.asarray(v) for k, v in self.test.items()}

    def test_accuracy(self, model) -> float:
        return float(self._acc(model, self._test_batch))

    def engine_batch_fn(self, local_steps: int, batch_size: int):
        """Uniform-shape per-client data: sample the client's §5.1 split,
        then draw exactly local_steps x batch_size items (with replacement)
        so every client's round is the same stacked shape — the contract
        the vectorized cohort paths need. Deterministic in (cid, round)."""
        splits = self.access.splits
        frac = self.access.frac

        def batch_fn(cid, round_idx):
            # §5.1 protocol, flattened to one cheap RNG draw: pick the
            # client's split, restrict to its 20% window, then draw the
            # (steps, B) round batch with replacement. Deterministic in
            # (cid, round); called once per client per round by BOTH the
            # serial and vectorized paths, so it must stay off the
            # per-client critical path (~50us, no choice(replace=False)).
            tail = str(cid).rsplit("-", 1)[-1]
            i = int(tail) if tail.isdigit() else zlib.crc32(
                str(cid).encode()) % 100_003
            rng = np.random.RandomState((round_idx * 131071 + i * 131 + 7)
                                        % (2 ** 31 - 1))
            split = splits[rng.randint(len(splits))]
            k = max(1, int(len(split) * frac))
            pool = split[rng.randint(0, len(split), size=k)]
            idx = pool[rng.randint(0, k, size=(local_steps, batch_size))]
            return {k_: v[idx] for k_, v in self.train.items()}
        return batch_fn

    def make_engine(self, local_steps: int = 5, batch_size: int | None = None,
                    mesh=None, axis: str = "data"):
        """CohortEngine running the paper-§5.1 local protocol (AdamW at
        self.lr) with uniform local work, ready for the simulator fast
        paths and the cohort benchmark."""
        from repro.core.cohort_engine import CohortEngine, LocalTrainSpec
        cfg = self.cfg
        bs = batch_size or self.batch_size
        spec = LocalTrainSpec(
            loss_fn=lambda m, b: classify_loss(cfg, m["trunk"], m["head"], b),
            optimizer=adamw(lr=self.lr), local_steps=local_steps)
        return CohortEngine(spec, self.engine_batch_fn(local_steps, bs),
                            template_params=self.model0, mesh=mesh,
                            axis=axis)

    def make_trainer(self, i: int):
        """Paper-protocol client trainer for the SDK/simulator."""
        def trainer(blob, round_idx):
            model = deserialize_pytree(blob, like=self.model0)
            d = self.access.sample(client_seed=round_idx * 9973 + i)
            opt_state = self._opt.init(model)
            new, n, loss = model, 0, jnp.zeros(())
            for b in batches(d, self.batch_size, seed=round_idx):
                b = {k: jnp.asarray(v) for k, v in b.items()}
                new, opt_state, loss = self._local_step(new, opt_state, b)
                n += len(b["label"])
            update = jax.tree.map(
                lambda a, b_: np.asarray(a, np.float32)
                - np.asarray(b_, np.float32), new, model)
            return update, max(n, 1), {"loss": float(loss)}
        return trainer
