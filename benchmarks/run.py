"""Benchmark harness: one module per paper figure/claim.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig11_left,...]

Prints ``name,us_per_call,derived`` CSV rows (plus '#' commentary lines)
and persists every suite's rows to ``benchmarks/results/BENCH_<suite>.json``
(host info + git rev + delta vs the previous committed run).

  bench_spam      -> Fig. 11 left   (FL vs FL+DP accuracy, epsilon)
  bench_async     -> Fig. 11 center (sync vs async vs over-participation)
  bench_scaling   -> Fig. 11 right  (duration vs concurrent clients)
  bench_secureagg -> §3.1.2 VG cost model (O(n^2) -> O(n*g))
  bench_kernels   -> kernel microbenchmarks
  bench_fleet     -> fleet-scale control plane (10^6 devices, wave agg)
  bench_compression -> LoRA + top-k sub-1% rounds under secure agg
  bench_trace     -> flight-recorder overhead (<2%) + bit-identity gate
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_async, bench_cohort, bench_compression,
                        bench_fleet, bench_kernels, bench_scaling,
                        bench_secureagg, bench_spam, bench_trace)
from benchmarks.common import write_bench_json

SUITES = [
    ("fig11_left", bench_spam),
    ("fig11_center", bench_async),
    ("fig11_right", bench_scaling),
    ("secureagg_vg", bench_secureagg),
    ("kernels", bench_kernels),
    ("cohort_engine", bench_cohort),
    ("fleet", bench_fleet),
    ("compression", bench_compression),
    ("trace", bench_trace),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in SUITES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.main(quick=args.quick)
            for r in rows:
                print(",".join(str(x) for x in r))
            print(f"# wrote {write_bench_json(name, rows, args.quick)}")
            print(f"# suite {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
