"""Multi-tenant control plane: what scheduling many tasks over ONE fleet
costs, and whether the fairness policy actually shares it.

Measured (the control-plane ISSUE acceptance):

1. **Throughput** — N concurrent tasks (mixed sync/async) over one shared
   device population, driven by the ControlPlane's deficit-weighted
   round-robin: total rounds completed, virtual makespan, and real wall
   time (the scheduler + directory bookkeeping overhead per round —
   trainers are trivial so the control plane IS the cost).
2. **Fairness** — the spread of weight-normalized lease-seconds across
   the sync tasks: a working policy keeps max/min close to 1 even when
   the tasks' weights differ.
3. **Safety** — the directory's lease-interval audit must report zero
   overlapping sync leases (asserted, not just reported).

Run: ``PYTHONPATH=src python -m benchmarks.bench_multitask [--quick]``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.fl import ControlPlane, TaskConfig
from repro.fl.simulator import (make_heterogeneous_clients,
                                run_multi_task_simulation)


def _trainer_factory(i):
    def trainer(blob, round_idx):
        return {"w": np.full(64, 0.01, np.float32)}, 10, {"loss": 1.0}
    return trainer


def run_fleet(n_clients, n_sync, n_async, n_rounds, cpr, seed=0) -> dict:
    model0 = {"w": np.zeros(64, np.float32)}
    plane = ControlPlane(seed=seed)
    sync_ids, task_ids = [], []
    for i in range(n_sync):
        # deliberately unequal weights: fairness must normalize them away
        tid = plane.create_task(
            TaskConfig(f"sync-{i}", "bench", "wf", clients_per_round=cpr,
                       n_rounds=n_rounds, vg_size=max(2, cpr // 4),
                       weight=float(1 + i % 2)), model0)
        sync_ids.append(tid)
        task_ids.append(tid)
    for i in range(n_async):
        tid = plane.create_task(
            TaskConfig(f"async-{i}", "bench", "wf", clients_per_round=cpr,
                       n_rounds=n_rounds, mode="async", buffer_size=cpr),
            model0)
        task_ids.append(tid)
    for tid in task_ids:
        plane.deploy(tid)
    clients = make_heterogeneous_clients(n_clients, _trainer_factory)
    t0 = time.perf_counter()
    res = run_multi_task_simulation(plane, clients, seed=seed)
    wall = time.perf_counter() - t0
    assert not res.lease_overlaps, res.lease_overlaps[:3]
    rounds = sum(len(r.round_durations) for r in res.per_task.values())
    norm = [res.fairness[t]["normalized"] for t in sync_ids
            if res.fairness[t]["normalized"] > 0]
    spread = (max(norm) / min(norm)) if len(norm) > 1 else 1.0
    return {"wall_s": wall, "rounds": rounds,
            "makespan_s": res.total_time, "fairness_spread": spread,
            "grant_us": wall / max(1, rounds) * 1e6,
            "completed": sum(
                1 for t in task_ids
                if plane.service.get_task(t).status.value == "completed")}


def main(quick=False):
    shapes = ([(40, 2, 1, 3, 8)] if quick
              else [(200, 3, 1, 6, 16), (1000, 4, 2, 8, 32)])
    rows = []
    print("# multi-tenant control plane: N tasks over one shared fleet")
    print("#  clients | sync+async | rounds | makespan s | wall s | "
          "fair max/min")
    for n_clients, n_sync, n_async, n_rounds, cpr in shapes:
        r = run_fleet(n_clients, n_sync, n_async, n_rounds, cpr)
        print(f"#   {n_clients:6d} | {n_sync}+{n_async:9d} | "
              f"{r['rounds']:6d} | {r['makespan_s']:10.2f} | "
              f"{r['wall_s']:6.2f} | {r['fairness_spread']:.2f}")
        tag = f"multitask_c{n_clients}_t{n_sync + n_async}"
        rows.append((f"{tag}_grant_us", r["grant_us"],
                     f"rounds={r['rounds']} "
                     f"completed={r['completed']}/{n_sync + n_async}"))
        rows.append((f"{tag}_fairness_spread", r["fairness_spread"],
                     "weight-normalized lease-seconds max/min (1.0=fair)"))
        rows.append((f"{tag}_makespan_s", r["makespan_s"],
                     f"virtual; wall={r['wall_s']:.2f}s"))
        assert r["completed"] == n_sync + n_async
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes — the CI / make-verify smoke run")
    args = ap.parse_args()
    rows = main(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
    from benchmarks.common import write_bench_json
    print(f"# wrote {write_bench_json('multitask', rows, quick=args.quick)}")
