"""Paper Fig. 11 (right): scaling test — duration of one iteration of a
dummy task (each client sends an all-ones array of size 5; the server
aggregates) for growing numbers of concurrent clients. We measure the REAL
server-side cost (registration + VG construction + secure aggregation of
all payloads) on this machine, plus the simulated client wall time."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import protect_cohort, vg_sums
from repro.core.quantize import dequantize_sum, quantize
from repro.core.virtual_groups import recommended_vg_size


def dummy_iteration(n_clients: int, vg_size: int | None = None,
                    size: int = 5, repeat: int = 3):
    """-> (steady_state_s, first_iter_s) for one secure-agg iteration of the
    dummy task over n concurrent clients (vectorized cohort protocol)."""
    vg = vg_size or recommended_vg_size(n_clients)
    while n_clients % vg:
        vg -= 1
    seed = jnp.asarray([1, 2], jnp.uint32)
    xs = jnp.ones((n_clients, size), jnp.float32)

    def iteration():
        qs = quantize(xs, 1.0, 16)
        payloads = protect_cohort(qs, vg, seed)
        interim = vg_sums(payloads, vg)                 # stage 1 per VG
        total = jnp.sum(interim, axis=0, dtype=jnp.uint32)
        return dequantize_sum(total, n_clients, 1.0, 16)

    t0 = time.perf_counter()
    agg = jax.block_until_ready(iteration())
    first = time.perf_counter() - t0
    assert abs(float(agg[0]) - 1.0) < 1e-2
    t0 = time.perf_counter()
    for _ in range(repeat):
        agg = jax.block_until_ready(iteration())
    return (time.perf_counter() - t0) / repeat, first


def main(quick=False):
    counts = [32, 64, 128, 256, 512, 1024, 2048] if not quick else [32, 128]
    rows = []
    print("# fig11-right: dummy-task iteration duration vs concurrent "
          "clients (steady-state / first-iteration-with-compile)")
    for n in counts:
        dt, first = dummy_iteration(n)
        print(f"#   n={n:5d}: {dt * 1e3:.2f}ms (first {first:.2f}s)")
        rows.append((f"fig11_right_n{n}", dt * 1e6,
                     f"first_iter_s={first:.3f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
